"""Quickstart: the complete ONNX-to-accelerator design flow in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py [--fifo-slack 2.0]

1. build the paper's CNN (symbolic batch dim) and serialize it as ONNX-like
   JSON,
2. Reader -> IR -> float JAX target (bit-exact reference),
3. mixed-precision D16-W8 streaming target (Pallas line-buffer conv actors)
   with value_info-sized FIFOs (``--fifo-slack`` scales the depths),
4. serve batch 1/3/8 from the one batch-polymorphic artifact,
5. merge W8/W4/W2 working points into one adaptive accelerator and switch
   at runtime,
6. explore the design space under a resource budget and serve the computed
   Pareto front adaptively (ONNX -> constrained points -> server).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.dse import ResourceBudget
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fifo-slack", type=float, default=1.0,
                    help="headroom multiplier on every derived FIFO depth")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = cnn.init_params(CNN, key)
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 28, 28, 1))

    # 1. model -> ONNX-like IR (serializable; symbolic batch dim "N")
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    graph.save("/tmp/mnist_cnn.onnx.json")
    print(f"IR: {len(graph.nodes)} nodes, input {graph.inputs[0].shape} ->",
          "/tmp/mnist_cnn.onnx.json")

    # 2. float reference target: raw interpretation is bit-exact; the default
    #    compile pipeline fuses Conv+BN+Relu into FusedConv actors
    flow = DesignFlow(graph)
    raw = flow.run(targets=("jax",), passes=())
    model_logits, _ = cnn.forward(params, x, CNN)
    print("float target (passes=()) bit-exact vs model:",
          bool(jnp.all(raw.executables["jax"](x) == model_logits)))
    compiled = flow.run(targets=("jax",))
    ref_logits = compiled.executables["jax"](x)
    print("compiled graph:", [n.op for n in compiled.graph.topo_order()],
          "| max |delta| vs model = "
          f"{float(jnp.max(jnp.abs(ref_logits - model_logits))):.2e}")

    # 3. D16-W8 streaming accelerator (Pallas line-buffer conv actors) with
    #    value_info-sized FIFOs
    res = flow.run(targets=("stream",), dtconfig=DatatypeConfig(16, 8),
                   calib_inputs=(x,), fifo_slack=args.fifo_slack)
    q_logits = res.executables["stream"](x)
    print("D16-W8 stream target: max |delta| vs float = "
          f"{float(jnp.max(jnp.abs(q_logits - ref_logits))):.4f}, "
          f"zero weights = {100 * res.stats['zero_weight_frac']:.1f}%")
    topo = res.writers["stream"].topology()
    res.writers["stream"].save_topology("/tmp/mnist_cnn.xdf.json")
    print(f"streaming topology (MDC input, slack={topo['fifo_slack']}, "
          f"{topo['total_fifo_bytes']} FIFO bytes) ->",
          "/tmp/mnist_cnn.xdf.json")

    # 4. one artifact, any request size: the batched executable re-jits per
    #    concrete batch with an LRU of traced shapes
    serve = res.batched["stream"]
    for b in (1, 3, 8):
        print(f"batch {b}: logits {tuple(serve(x[:b]).shape)}")
    print("traced batches resident:", serve.cached_batches)

    # 5. adaptive accelerator: three working points, one weight buffer
    acc = flow.compose_adaptive([WorkingPoint("hi", 8), WorkingPoint("mid", 4),
                                 WorkingPoint("lo", 2)])
    for name in ("hi", "mid", "lo"):
        y = acc(name, x)
        print(f"working point {name}: argmax[0]={int(jnp.argmax(y[0]))}")
    print("sharing report:", acc.sharing_report())

    # 6. constrained DSE: screen rungs against a byte budget, score the
    #    survivors on the calibration batch, serve the resulting front —
    #    the one documented path from ONNX to an adaptive server
    front = flow.explore((np.asarray(x),),
                         budget=ResourceBudget(total_bytes=400_000))
    print("Pareto front:", ", ".join(
        f"{p.point.name}({p.total_bytes}B, agree={p.agreement:.2f})"
        for p in front.points))
    front.save("/tmp/mnist_cnn.front.json")
    served = flow.run(targets=("qjax",), calib_inputs=(np.asarray(x),),
                      **front.run_kwargs())
    srv = served.serve_adaptive(points=front, max_batch=8, max_wait=0.0,
                                selector=front.selector())
    tk = srv.submit(np.asarray(x[:2]))
    srv.pump(flush=True)
    print(f"served from the front: logits {tuple(srv.result(tk).shape)} "
          f"at point {srv.reports[-1].bits}-bit")


if __name__ == "__main__":
    main()
