"""Weight-memory integrity demo: SDC detection, scrubbing, self-healing.

    PYTHONPATH=src python examples/integrity_demo.py
    PYTHONPATH=src python examples/integrity_demo.py --soak --seconds 8 --seed 7

One shared :class:`PackedWeights` buffer backs every W8/W4/W2 working point
on every replica — which makes it the fleet's single point of *silent*
failure: a bit flip there corrupts all replicas at once while availability
stays at 100%.  This demo walks the defenses end-to-end:

1. every region (int8 master codes, f32 channel scales, each cached W4/W2
   packed view) is CRC-sealed at pack time; a rate-bounded
   :class:`Scrubber` per replica re-hashes them round-robin;
2. a flipped W4/W2 **view** is repaired in place — re-derived bit-exactly
   from the intact master codes, no restart, no reload;
3. a flipped **master code** is unrepairable: the scrubber quarantines it,
   the server dies with a typed :class:`IntegrityError` (zero
   post-detection corrupted results), the sentinel ejects the replica with
   a ``quarantined`` cause, and the factory heals it with a pristine
   master before readmission;
4. semantic :class:`CanarySet` probes ride the sentinel's real
   submit/result path, catching corruption checksums cannot see.

``--soak`` runs a seeded, time-bounded bit-flip soak instead (the CI smoke
mode): continuous view-region SEUs plus one mid-run master-code SEU, under
live traffic.  It exits non-zero if ANY served result is corrupted (checked
against golden outputs), any ticket is lost, or the fleet/buffer fails to
end clean.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint, shared_point_executables
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from repro.runtime.fleet import FleetRouter, HealthState
from repro.runtime.integrity import BitFlipInjector, CanarySet, Scrubber
from repro.runtime.serve import AccelServer

MAX_BATCH = 8
POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


def build_points():
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    pool = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)))
    res = DesignFlow(graph).run(targets=("qjax",),
                                dtconfig=DatatypeConfig(16, 8),
                                calib_inputs=(pool,))
    pts = shared_point_executables(res.writers["qjax"], POINTS)
    packed = pts["w8"].packed
    for t in packed.tensors.values():    # derive the W4/W2 view regions
        t.packed_view(4)
        t.packed_view(2)
    return pts, packed, pool


def goldens(packed, pts, pool):
    master = {n: (np.array(t.codes), np.array(t.scale))
              for n, t in packed.tensors.items()}
    outputs = {name: {s: np.asarray(exe(pool[:s])) for s in (1, 2, 4)}
               for name, exe in pts.items()}
    return master, outputs


def restore_master(packed, master):
    """Heal-path restore: pristine codes/scales, views re-derived."""
    for n, t in packed.tensors.items():
        codes, scale = master[n]
        t.codes = jnp.asarray(codes)
        t.scale = jnp.asarray(scale)
        t.seal()
        for (bits, align) in list(t._packed):
            t.repair_view(bits, align=align)


def fleet(pts, packed, master, pool, scrubbers, *, seed=0):
    def make_factory(name):
        def factory():
            if packed.verify():          # healing a quarantined buffer
                restore_master(packed, master)
            srv = AccelServer(pts["w8"], max_batch=MAX_BATCH, max_wait=0.002,
                              point_executables=dict(pts), pipeline_depth=2)
            old = scrubbers.pop(name, None)
            if old is not None:
                old.stop()
            sc = Scrubber(packed, rate_bytes_s=20e6, interval_s=0.002)
            srv.attach_scrubber(sc)      # quarantine -> fatal IntegrityError
            sc.start()
            scrubbers[name] = sc
            return srv
        return factory

    canaries = CanarySet.capture(pts, [(pool[:1],)], k=1,
                                 rtol=1e-3, atol=1e-4)
    return FleetRouter({n: make_factory(n) for n in ("a", "b", "c")},
                       canaries=canaries, retries=3, backoff_s=0.005,
                       probe_interval_s=0.02, heal_cooldown_s=0.2,
                       default_deadline_s=60.0, seed=seed)


def wait_for(cond, seconds, poll=0.01):
    deadline = time.monotonic() + seconds
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(poll)
    return cond()


def all_healthy(router):
    return all(r["state"] == HealthState.HEALTHY.value and r["alive"]
               for r in router.stats()["replicas"].values())


def print_integrity(stats):
    it = stats["integrity"]
    print(f"  integrity: scrubbed={it['scrubbed_bytes'] / 1e6:.1f}MB "
          f"passes={it['scrub_passes']} detected={it['detected_flips']} "
          f"repaired={it['repaired_views']} "
          f"quarantines={it['quarantines']} "
          f"canary_failures={stats['canary_failures']}")
    for name, rep in stats["replicas"].items():
        print(f"  replica {name}: state={rep['state']} "
              f"eject_cause={rep['eject_cause']} gen={rep['generation']} "
              f"readmissions={rep['readmissions']}")


def demo(args):
    pts, packed, pool = build_points()
    master, _ = goldens(packed, pts, pool)
    scrubbers = {}
    router = fleet(pts, packed, master, pool, scrubbers)
    regions = packed.regions()
    print(f"== packed buffer: {len(regions)} CRC-sealed regions, "
          f"{sum(r.nbytes for r in regions)} bytes/scrub period ==")
    try:
        with router:
            router(pool[:2])

            print("== SEU 1: flip a bit in a W4 packed view ==")
            v4 = next(r for r in regions if r.kind == "view" and r.bits == 4)
            BitFlipInjector(packed, seed=args.seed).flip(region=v4)
            assert wait_for(lambda: packed.verify() == [], 10.0, poll=0.002)
            print(f"  {v4.label()}: detected and repaired in place from the "
                  "master codes (bit-exact, no restart)")
            router(pool[:2])

            print("== SEU 2: flip a bit in the int8 master codes ==")
            codes = next(r for r in regions if r.kind == "codes")
            BitFlipInjector(packed, seed=args.seed + 1).flip(region=codes)
            ejected = wait_for(
                lambda: all(r["eject_cause"] == "quarantined"
                            for r in router.stats()["replicas"].values()),
                20.0)
            print(f"  {codes.label()}: unrepairable -> every replica died "
                  f"typed + ejected 'quarantined' ({ejected})")
            healed = wait_for(lambda: all_healthy(router), 30.0)
            print(f"  factories restored the pristine master -> fleet "
                  f"healed and readmitted ({healed})")
            router(pool[:2])
            print("== final fleet state ==")
            print_integrity(router.stats())
    finally:
        for sc in scrubbers.values():
            sc.stop()


def soak(args):
    pts, packed, pool = build_points()
    master, golden_out = goldens(packed, pts, pool)
    scrubbers = {}
    router = fleet(pts, packed, master, pool, scrubbers, seed=args.seed)
    view_seu = BitFlipInjector(packed, rate=args.flip_rate, seed=args.seed,
                               kinds=("view",))
    codes_seu = BitFlipInjector(packed, seed=args.seed + 1,
                                kinds=("codes",))
    rng = np.random.default_rng(args.seed)
    t_end = time.monotonic() + args.seconds
    codes_at = time.monotonic() + args.seconds / 2
    submitted = ok = err = corrupted = shed = step = 0
    print(f"== seeded bit-flip soak: {args.seconds}s, view flip_rate="
          f"{args.flip_rate}/round + 1 master-code SEU, seed={args.seed} ==")
    try:
        with router:
            router(pool[:1])              # warm the trace caches
            while time.monotonic() < t_end:
                step += 1
                view_seu.maybe_flip(step)
                if codes_seu.injected_flips == 0 \
                        and time.monotonic() >= codes_at:
                    codes_seu.flip(step)
                sizes = [int(s) for s in rng.choice([1, 2, 4], size=6)]
                tickets = []
                for s in sizes:
                    try:
                        tickets.append((s, router.submit(pool[:s])))
                    except Exception:
                        # the master SEU quarantines EVERY replica at once:
                        # the fleet sheds (fail-stop) while the sentinel
                        # heals — shed is not lost and never corrupted
                        shed += 1
                        time.sleep(0.05)
                submitted += len(tickets)
                for s, t in tickets:
                    try:
                        val = t.result(timeout=120)
                    except Exception:
                        err += 1          # typed failure: never corrupted
                        continue
                    ok += 1
                    out = np.asarray(val[0] if isinstance(val, tuple)
                                     else val)
                    if not any(np.allclose(out, g[s], rtol=1e-4, atol=1e-5)
                               for g in golden_out.values()):
                        corrupted += 1
            fleet_clean = wait_for(lambda: all_healthy(router), 30.0)
            buffer_clean = wait_for(lambda: packed.verify() == [], 10.0)
            stats = router.stats()
    finally:
        for sc in scrubbers.values():
            sc.stop()
    lost = submitted - ok - err
    print(f"== soak done: submitted={submitted} ok={ok} "
          f"typed_failures={err} shed={shed} lost={lost} "
          f"corrupted_served={corrupted} "
          f"view_flips={view_seu.injected_flips} "
          f"codes_flips={codes_seu.injected_flips} ==")
    print_integrity(stats)
    if corrupted:
        raise SystemExit(f"soak served {corrupted} corrupted results")
    if lost:
        raise SystemExit(f"soak lost {lost} tickets")
    if not (fleet_clean and buffer_clean):
        raise SystemExit("soak did not end with a healthy fleet and a "
                         "clean buffer")
    print("zero corrupted results, zero lost tickets, fleet healed clean")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--soak", action="store_true",
                    help="seeded time-bounded bit-flip soak (CI smoke mode)")
    ap.add_argument("--seconds", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--flip-rate", type=float, default=0.2,
                    help="per-round probability of a view-region SEU")
    args = ap.parse_args()
    if args.soak:
        soak(args)
    else:
        demo(args)


if __name__ == "__main__":
    main()
