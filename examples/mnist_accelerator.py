"""End-to-end driver: train the paper's MNIST CNN, run the full mixed-precision
exploration (Table II), and deploy the Pareto points as ONE adaptive
accelerator with a CPS-style runtime energy policy.

    PYTHONPATH=src python examples/mnist_accelerator.py [--quick]
"""
import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _ROOT)                      # for `benchmarks.*`
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.data.mnist import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks.table2_mixed_precision import run as explore, train_cnn
    print("== training the accelerator model on procedural MNIST ==")
    rows = explore(full=not args.quick)
    print(f"{'datatype':10s} {'zeros%':>7s} {'acc%':>6s} {'us/img':>8s} "
          f"{'energy uJ':>10s}")
    for r in rows:
        print(f"{r['datatype']:10s} {r['zero_weights_pct']:7.1f} "
              f"{r['accuracy_pct']:6.1f} {r['us_per_image']:8.1f} "
              f"{r['est_energy_uj']:10.2f}")

    # pick Pareto points (accuracy vs energy) and compose the adaptive design
    print("\n== composing the adaptive accelerator (MDC step) ==")
    params = train_cnn(256, 2)
    test_x, test_y = make_dataset(128, seed=99)
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(g)
    pts = [WorkingPoint("accurate", 8), WorkingPoint("balanced", 4),
           WorkingPoint("frugal", 2)]
    acc = flow.compose_adaptive(pts)
    print("sharing report:", acc.sharing_report())

    policy = RuntimePolicy(pts, thresholds=[0.66, 0.33])
    tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)
    print("\n== runtime: energy budget drains, accelerator reconfigures ==")
    for budget in (1.0, 0.5, 0.15):
        pt = policy.select(budget)
        logits = acc(pt.name, tx)
        a = float(jnp.mean((jnp.argmax(logits, -1) == ty)))
        print(f"budget={budget:.2f} -> point={pt.name:9s} acc={100 * a:.1f}%")


if __name__ == "__main__":
    main()
