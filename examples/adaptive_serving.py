"""Adaptive mixed-precision LM serving (the paper's CPS adaptivity at scale).

    PYTHONPATH=src python examples/adaptive_serving.py --arch qwen1.5-0.5b

Serves batched greedy decode from an AdaptiveLMServer: one int8 master weight
buffer, W8/W4/W2 working points switched by the draining energy budget.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    sys.argv = [sys.argv[0], "--arch", args.arch, "--steps", str(args.steps),
                "--batch", str(args.batch), "--smoke"]
    from repro.launch.serve import main as serve_main
    serve_main()


if __name__ == "__main__":
    main()
