"""Fault-tolerant fleet serving demo.

    PYTHONPATH=src python examples/fleet_demo.py [--requests 40]
    PYTHONPATH=src python examples/fleet_demo.py --soak --seconds 10 --seed 7

Three :class:`AccelServer` replicas — each its own pump thread, all serving
W8/W4/W2 point executables over the SAME shared packed-weight buffer —
behind a :class:`FleetRouter`:

1. the health layer heartbeats every replica (EWMA latency/error scoring,
   circuit breakers, straggler watchdog) and walks the
   healthy -> suspect -> ejected -> probing -> readmitted state machine;
2. chaos is injected mid-run: one replica's pump is crashed outright and
   another gets a latency-spike window — requests fail over with bounded
   backoff+jitter retries and tail-latency hedging, so the burst completes
   with zero lost tickets;
3. the crashed replica is healed (its factory rebuilds a fresh server)
   after a cooldown, canary-probed, and readmitted;
4. a fleet-level brownout selector degrades the WHOLE fleet down the
   precision ladder when aggregate p95/backlog breaches the objective and
   restores W8 on recovery.

``--soak`` runs a seeded, time-bounded chaos soak instead: probabilistic
failures and delays (the generalized ``FailureInjector`` rate modes) are
injected continuously and the run asserts zero lost tickets at the end —
the CI smoke uses this mode.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import (BrownoutSelector, ServiceObjective,
                                 WorkingPoint, shared_point_executables)
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from repro.runtime.fleet import ChaosExecutable, FleetRouter
from repro.runtime.ft import FailureInjector
from repro.runtime.serve import AccelServer

MAX_BATCH = 8
POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


def build_points():
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    pool = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)))
    res = DesignFlow(graph).run(targets=("qjax",),
                                dtconfig=DatatypeConfig(16, 8),
                                calib_inputs=(pool,))
    return shared_point_executables(res.writers["qjax"], POINTS), pool


def make_server(pts, wrap=lambda exe: exe):
    wrapped = {p.name: wrap(pts[p.name]) for p in POINTS}
    return AccelServer(wrapped["w8"], max_batch=MAX_BATCH, max_wait=0.002,
                       point_executables=wrapped)


def print_fleet(stats):
    print(f"  availability={stats['availability']:.4f} "
          f"succeeded={stats['succeeded']} failed={stats['failed']} "
          f"retries={stats['retries']} hedges={stats['hedges']} "
          f"shed={stats['shed']}")
    for name, rep in stats["replicas"].items():
        print(f"  replica {name}: state={rep['state']} "
              f"served={rep['served']} failures={rep['failures']} "
              f"ejections={rep['ejections']} "
              f"readmissions={rep['readmissions']} gen={rep['generation']}")
    if "brownout" in stats:
        b = stats["brownout"]
        print(f"  brownout: point={b['point']} shifts={b['shifts']}")


def demo(args):
    pts, pool = build_points()
    brownout = BrownoutSelector(
        POINTS, ServiceObjective(p95_latency_s=0.05, window=12,
                                 min_samples=6, hold=6))

    killer = ChaosExecutable(pts["w8"], crash_at=[3])
    spikes = FailureInjector(delay_at=list(range(2, 7)), delay_s=0.3)
    spike_counter = [0]

    router = FleetRouter(
        {"a": lambda: make_server(pts),
         "b": lambda: make_server(
             {**pts, "w8": killer} if killer.calls == 0 else pts),
         "c": lambda: make_server(pts, lambda exe: ChaosExecutable(
             exe, spikes, counter=spike_counter))},
        brownout=brownout, retries=3, backoff_s=0.005, hedge_after_s=0.1,
        probe=[pool[:1]], probe_interval_s=0.02, heal_cooldown_s=0.2,
        default_deadline_s=60.0)

    rng = np.random.default_rng(0)
    print(f"== burst of {args.requests} requests with a pump crash on 'b' "
          "and latency spikes on 'c' ==")
    with router:
        tickets = [router.submit(pool[:int(s)])
                   for s in rng.choice([1, 2, 2, 4, 8], size=args.requests)]
        ok = err = 0
        for t in tickets:
            try:
                t.result(timeout=120)
                ok += 1
            except Exception as e:
                err += 1
                print(f"  typed failure: {type(e).__name__}: {e}")
        print(f"== burst done: {ok} ok, {err} typed failures, 0 hung ==")
        # clean tail: heal + readmit 'b', recover the precision ladder
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            router.submit(pool[:2]).result(timeout=120)
            s = router.stats()
            if (s["replicas"]["b"]["readmissions"] >= 1
                    and s["brownout"]["point"] == "w8"):
                break
        print("== after recovery tail ==")
        print_fleet(router.stats())


def soak(args):
    pts, pool = build_points()
    inj = FailureInjector(rate=args.fail_rate, seed=args.seed,
                          delay_rate=args.delay_rate, delay_s=0.05)
    counter = [0]
    router = FleetRouter(
        {"a": lambda: make_server(pts),
         "b": lambda: make_server(pts, lambda exe: ChaosExecutable(
             exe, inj, counter=counter)),
         "c": lambda: make_server(pts)},
        retries=3, backoff_s=0.005, probe=[pool[:1]],
        probe_interval_s=0.02, heal_cooldown_s=0.1,
        default_deadline_s=60.0, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t_end = time.monotonic() + args.seconds
    submitted = ok = err = 0
    print(f"== seeded chaos soak: {args.seconds}s, fail_rate="
          f"{args.fail_rate}, delay_rate={args.delay_rate}, "
          f"seed={args.seed} ==")
    with router:
        while time.monotonic() < t_end:
            tickets = [router.submit(pool[:int(s)])
                       for s in rng.choice([1, 2, 4, 8], size=8)]
            submitted += len(tickets)
            for t in tickets:
                try:
                    t.result(timeout=120)
                    ok += 1
                except Exception:
                    err += 1
        stats = router.stats()
    lost = submitted - ok - err
    print(f"== soak done: submitted={submitted} ok={ok} "
          f"typed_failures={err} lost={lost} "
          f"injected_failures={inj.injected_failures} "
          f"injected_delays={inj.injected_delays} ==")
    print_fleet(stats)
    if lost != 0:
        raise SystemExit(f"soak lost {lost} tickets")
    print("zero lost tickets: every request resolved")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--soak", action="store_true",
                    help="seeded time-bounded chaos soak (CI smoke mode)")
    ap.add_argument("--seconds", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-rate", type=float, default=0.05)
    ap.add_argument("--delay-rate", type=float, default=0.05)
    args = ap.parse_args()
    if args.soak:
        soak(args)
    else:
        demo(args)


if __name__ == "__main__":
    main()
