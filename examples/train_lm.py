"""End-to-end LM training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-0.5b --steps 60

Trains the reduced config on the synthetic token stream through the
fault-tolerant loop (async checkpoints every 20 steps), injects a failure at
step 30, restarts from the checkpoint, and verifies the loss curve.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.data.tokens import DataConfig
from repro.models.params import init_params
from repro.optim.adamw import OptConfig
from repro.runtime import ft
from repro.runtime.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), max_seq=args.seq)
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        cfg, OptConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=0)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"training {cfg.name}, {args.steps} steps, injected failure at "
          f"step {args.fail_at}, checkpoints -> {ckpt_dir}")
    res = ft.run_training(
        step, state, data, args.steps, ckpt_dir, ckpt_every=20,
        injector=ft.FailureInjector(fail_at=[args.fail_at]))
    losses = [m["loss"] for m in res.metrics_log]
    print(f"restarts={res.restarts} "
          f"loss: start={losses[0]:.4f} end={losses[-1]:.4f}")
    assert res.restarts == 1 and losses[-1] < losses[0]
    print("OK: recovered from the failure and the loss decreased")


if __name__ == "__main__":
    main()
