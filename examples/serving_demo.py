"""Batch-coalescing accelerator serving demo.

    PYTHONPATH=src python examples/serving_demo.py [--requests 24]

One compiled, batch-polymorphic MNIST-CNN accelerator serves a stream of
asynchronously sized requests (the paper's CPS scenario: an edge accelerator
facing evolving workloads):

1. requests of mixed sizes land in the server's bounded queue,
2. the scheduler coalesces them into bucket-sized batches aligned with the
   executable's LRU of traced shapes (pad-to-bucket, slice-back),
3. a RuntimePolicy watches the draining energy budget and selects a precision
   working point (W8/W4/W2) per scheduled batch — the paper's
   no-weight-reload precision switch,
4. per-request results are demuxed back, and the server reports throughput,
   latency percentiles, padding waste and jit-cache hit-rate.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(graph)
    h, w = CNN.image_hw
    pool = np.asarray(
        jax.random.uniform(
            jax.random.PRNGKey(1), (args.max_batch, h, w, CNN.in_channels)
        )
    )

    # working points: one graph, three precision builds (W8/W4/W2 weights)
    points = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    point_exes = {}
    for pt in points:
        res = flow.run(
            dtconfig=DatatypeConfig(16, pt.weight_bits), calib_inputs=(pool,)
        )
        point_exes[pt.name] = res.batched["jax"]
    policy = RuntimePolicy(points, thresholds=[0.66, 0.33])

    res = flow.run()
    srv = res.serve(
        max_batch=args.max_batch,
        max_wait=0.002,
        policy=policy,
        point_executables=point_exes,
    )
    print(
        f"serving {args.requests} mixed-size requests through one "
        f"batch-polymorphic artifact (max_batch={args.max_batch})"
    )

    # the stream: sizes skewed small, energy budget draining 1.0 -> ~0
    sizes = rng.choice([1, 1, 2, 2, 3, 4, 8], size=args.requests)
    tickets = []
    for i, size in enumerate(sizes):
        budget = 1.0 - i / max(args.requests - 1, 1)
        tickets.append((srv.submit(pool[:size], budget=budget), int(size)))
        srv.pump()  # serve whatever the scheduler deems ready
    srv.pump(flush=True)  # stream end

    for ticket, size in tickets:
        y = srv.result(ticket)
        assert y.shape[0] == size
    print(f"all {len(tickets)} requests answered with their own rows")

    for i, r in enumerate(srv.reports):
        print(
            f"batch {i}: {r.requests} requests, {r.rows} rows -> "
            f"bucket {r.bucket} (+{r.padding} pad), point {r.point}"
        )
    s = srv.stats()
    print(
        f"stats: {s['executed_batches']} batches for {s['submitted']} "
        f"requests | padding waste {s['padding_waste']:.1%} | jit hit-rate "
        f"{s['hit_rate']:.1%} | points {s['points']}"
    )
    print(
        f"latency p50 {s['p50_latency_s'] * 1e3:.1f}ms "
        f"p95 {s['p95_latency_s'] * 1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
