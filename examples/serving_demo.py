"""Async multi-tenant accelerator serving demo.

    PYTHONPATH=src python examples/serving_demo.py [--requests 24]

Two tenants share one device through the async :class:`AccelServer` (the
paper's CPS scenario scaled up: one reconfigurable accelerator, several
resident workloads, runtime precision adaptation):

1. each tenant registers its own graph + bounded queue + QoS weight —
   ``interactive`` (weight 2, tight p95 SLO) and ``bulk`` (weight 1, relaxed
   SLO); the background pump thread serves both via weighted round-robin,
2. ``submit()`` returns a future-style ticket immediately; the pump
   coalesces requests into bucket-sized batches aligned with each
   executable's LRU of traced shapes (pad-to-bucket, slice-back),
3. every completed request feeds its latency into the tenant's SLO
   controller, which walks the W8/W4/W2 precision ladder — downshift when
   the windowed p95 violates the SLO, recover when there is headroom — and
   every batch feeds its execution time into the measured bucket policy,
4. per-request results are demuxed back to their tickets, and the server
   reports per-tenant throughput, latency percentiles, precision shifts and
   the per-bucket latency model.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from repro.runtime.serve import AccelServer, ServiceObjective


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="requests per tenant")
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(graph)
    h, w = CNN.image_hw
    pool = np.asarray(
        jax.random.uniform(
            jax.random.PRNGKey(1), (args.max_batch, h, w, CNN.in_channels)
        )
    )

    # working points: one graph, three precision builds (W8/W4/W2 weights);
    # both tenants share the same executables — switching points re-builds
    # nothing, so the SLO controllers just pick different entries
    points = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    point_exes = {}
    for pt in points:
        res = flow.run(
            dtconfig=DatatypeConfig(16, pt.weight_bits), calib_inputs=(pool,)
        )
        point_exes[pt.name] = res.batched["jax"]

    # two tenants, two contracts: interactive wants low p95 and gets 2x the
    # device share; bulk tolerates latency and takes the leftover slots
    srv = AccelServer(max_batch=args.max_batch, max_wait=0.002)
    for name, weight, p95_ms in (("interactive", 2, 40.0), ("bulk", 1, 400.0)):
        srv.add_tenant(
            name,
            point_exes["w8"],
            max_batch=args.max_batch,
            max_wait=0.002,
            policy=RuntimePolicy(points),
            point_executables=point_exes,
            weight=weight,
            slo=ServiceObjective(
                p95_latency_s=p95_ms / 1e3, window=8, min_samples=4, hold=4
            ),
        )
    print(
        f"serving {args.requests} mixed-size requests per tenant through "
        f"two resident graphs (WRR 2:1, max_batch={args.max_batch})"
    )

    # the stream: both tenants burst at once; tickets resolve as the
    # background pump drains the queues
    sizes = rng.choice([1, 1, 2, 2, 3, 4, 8], size=args.requests)
    with srv:  # start() the pump; stop(drain=True) on exit
        tickets = [
            (srv.submit(pool[: int(size)], tenant=name), name, int(size))
            for size in sizes
            for name in ("interactive", "bulk")
        ]
        for ticket, name, size in tickets:
            y = ticket.result(timeout=120)
            assert y.shape[0] == size
    print(f"all {len(tickets)} tickets answered with their own rows")

    stats = srv.stats()
    for name, s in stats["tenants"].items():
        slo = s["slo"]
        print(
            f"{name}: {s['executed_batches']} batches for {s['submitted']} "
            f"requests | weight {s['weight']} | p50 "
            f"{s.get('p50_latency_s', 0.0) * 1e3:.1f}ms p95 "
            f"{s.get('p95_latency_s', 0.0) * 1e3:.1f}ms (SLO "
            f"{slo['p95_slo_s'] * 1e3:.0f}ms) | point {slo['point']} | "
            f"shifts {slo['shifts']} | points served {s['points']}"
        )
        buckets = {
            b: f"{t * 1e3:.1f}ms"
            for b, t in sorted(s["bucket_latency_s"].items())
        }
        print(f"{name}: measured bucket latency {buckets}")
    print(
        f"total: {stats['executed_batches']} batches | padding waste "
        f"{stats['padding_waste']:.1%} | p95 "
        f"{stats.get('p95_latency_s', 0.0) * 1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
