#!/usr/bin/env bash
# Tier-1 test entry point — one command locally and in CI.
#   scripts/test.sh [extra pytest args]
#   TIER1_ARGS="-k scheduler" scripts/test.sh
# Forces the CPU backend so local GPU/TPU machines and CI runners execute
# the identical numerical path (batch-coalescing differential tests assert
# ulp-level agreement).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
exec python -m pytest -x -q ${TIER1_ARGS:-} "$@"
