#!/usr/bin/env bash
# Tier-1 test entry point — one command locally and in CI.
#   scripts/test.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
