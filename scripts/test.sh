#!/usr/bin/env bash
# Tier-1 test entry point — one command locally and in CI.
#   scripts/test.sh [extra pytest args]
#   TIER1_ARGS="-k scheduler" scripts/test.sh
# Forces the CPU backend so local GPU/TPU machines and CI runners execute
# the identical numerical path (batch-coalescing differential tests assert
# ulp-level agreement).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
# A deadlocked pump thread must fail the run, not hang it: apply a per-test
# wall clock whenever the pytest-timeout plugin is available (CI installs it
# via requirements-dev.txt; environments without it just run unbounded).
TIMEOUT_ARGS=""
if python -c "import pytest_timeout" 2>/dev/null; then
  TIMEOUT_ARGS="--timeout=300 --timeout-method=thread"
fi
exec python -m pytest -x -q ${TIMEOUT_ARGS} ${TIER1_ARGS:-} "$@"
