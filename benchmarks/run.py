# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--quick]

  table1_frameworks       - Table I analogue (execution-style comparison)
  table2_mixed_precision  - Table II reproduction (Dx-Wy exploration)
  adaptive_switch         - MDC runtime-adaptivity benchmark
  serve_throughput        - coalesced vs naive per-request serving
  qpath_latency           - fake-quant f32 vs packed-kernel execution path
  dse_pareto              - resource-constrained Pareto fronts of working points
  fleet_chaos             - replicated serving under injected faults
  integrity_sdc           - SDC detection/scrub/self-heal under bit-flip chaos
  roofline                - §Roofline table aggregated from dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for CI-speed runs")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    full = not args.quick

    failures = []

    def section(name, fn):
        if args.only and args.only != name:
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, repr(e)))
            traceback.print_exc()

    from benchmarks import (adaptive_switch, dse_pareto, fleet_chaos,
                            integrity_sdc, qpath_latency, roofline_table,
                            serve_throughput, table1_frameworks,
                            table2_mixed_precision)

    section("table1_frameworks", lambda: [
        print("table1_frameworks," + ",".join(f"{k}={v}" for k, v in r.items()))
        for r in table1_frameworks.run(full)])
    section("table2_mixed_precision", lambda: [
        print("table2_mixed_precision," + ",".join(f"{k}={v}"
                                                   for k, v in r.items()))
        for r in table2_mixed_precision.run(full)])
    section("adaptive_switch", lambda: [
        print("adaptive_switch," + ",".join(f"{k}={v}" for k, v in r.items()))
        for r in adaptive_switch.run(full)])
    section("serve_throughput", lambda: [
        print("serve_throughput," + ",".join(f"{k}={v}" for k, v in r.items()))
        for r in serve_throughput.run(full)])
    section("qpath_latency", lambda: [
        print("qpath_latency," + ",".join(f"{k}={v}" for k, v in r.items()))
        for r in qpath_latency.run(full)])
    section("dse_pareto", lambda: [
        print("dse_pareto," + ",".join(f"{k}={v}" for k, v in r.items()))
        for r in dse_pareto.run(full)])
    section("fleet_chaos", lambda: print(
        "fleet_chaos," + ",".join(f"{k}={v}"
                                  for k, v in fleet_chaos.run(full).items())))
    section("integrity_sdc", lambda: print(
        "integrity_sdc," + ",".join(
            f"{k}={v}" for k, v in integrity_sdc.run(full).items()
            if k != "flips")))
    section("roofline", roofline_table.main)

    if failures:
        for name, err in failures:
            print(f"BENCH FAILURE: {name}: {err}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
