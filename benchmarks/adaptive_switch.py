"""Adaptive-accelerator benchmark: reconfiguration cost + per-point resources.

The paper's MDC motivation: switching working points at runtime should be
cheap (no weight reload).  Measures: (a) decode-step time per working point,
(b) the switch overhead (first call after a point change vs steady state),
(c) the weight-sharing ratio of the merged accelerator.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.adaptive import WorkingPoint
from repro.models.params import init_params
from repro.runtime import model_api
from repro.runtime.serve import AdaptiveLMServer


def run(full: bool = True) -> List[Dict]:
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
    pts = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    srv = AdaptiveLMServer(params, cfg, pts)
    tok = jnp.zeros((4, 1), jnp.int32)
    state = model_api.init_decode_state(params, {}, cfg, 4, 64)

    rows = []
    budgets = {"w8": 1.0, "w4": 0.5, "w2": 0.1}
    for pt in pts:
        b = budgets[pt.name]
        t0 = time.perf_counter()
        _, state, m = srv.decode(tok, state, b)   # includes compile (switch cost)
        switch_s = time.perf_counter() - t0
        times = []
        for _ in range(10 if full else 3):
            t0 = time.perf_counter()
            logits, state, m = srv.decode(tok, state, b)
            jax.block_until_ready(logits)
            times.append(time.perf_counter() - t0)
        rows.append({"point": pt.name,
                     "us_per_decode": round(min(times) * 1e6, 1),
                     "first_call_ms": round(switch_s * 1e3, 1),
                     "weight_bytes_read": m.weight_bytes_read})
    from repro.quant.ptq import quant_memory_bytes
    merged = quant_memory_bytes(srv.qparams, 8, packed=True)
    separate = sum(quant_memory_bytes(srv.qparams, p.weight_bits, packed=True)
                   for p in pts)
    rows.append({"point": "merged", "us_per_decode": "-",
                 "first_call_ms": "-",
                 "weight_bytes_read": merged,
                 "sharing_ratio": round(separate / merged, 2)})
    return rows


def main() -> None:
    for r in run():
        print("adaptive_switch," + ",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
