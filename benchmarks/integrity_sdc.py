"""Weight-memory SDC benchmark: bit-flip chaos against the integrity layer.

Three :class:`~repro.runtime.serve.AccelServer` replicas serve W8/W4/W2
point executables over the SAME shared
:class:`~repro.quant.pack.PackedWeights` buffer behind a
:class:`~repro.runtime.fleet.FleetRouter` with semantic canaries, while a
seeded :class:`~repro.runtime.integrity.BitFlipInjector` corrupts the live
buffers and each replica runs a rate-bounded
:class:`~repro.runtime.integrity.Scrubber` over them:

* **phase A — repairable SEUs**: single-bit flips in cached W4/W2 packed
  views land mid-traffic (alongside a pump-killing crash on replica B —
  combined bit-flip + crash chaos).  Every flip must be detected and the
  view re-derived BIT-EXACTLY from the intact master codes within the scrub
  window, with no server restart;
* **phase B — unrepairable SEU**: a flip in the int8 master codes.  Every
  scrubber quarantines, every pump dies with a typed
  :class:`~repro.runtime.integrity.IntegrityError` (zero post-detection
  results served from the poisoned buffer), the sentinel ejects each
  replica with a ``quarantined`` cause and heals through the factories,
  which restore the master from a pristine copy — the fleet readmits and
  serving resumes.

Every successful result over the whole run is compared against golden
outputs captured before any chaos; a mismatch counts as a *corrupted
result served* and fails the run.

Pass/fail criteria (reported, enforced with ``--check``):

* every injected flip detected within ``WINDOW_PASSES`` scrub passes;
* ZERO corrupted results served (post-detection or otherwise);
* every W4/W2 view repair round-trips bit-exactly from the master codes;
* the master-code flip ends in ``quarantined`` ejections and a healed,
  fully readmitted fleet;
* availability >= 0.99 over the whole run (bit-flip + crash chaos).

Emits machine-readable JSON via ``--out`` (default ``BENCH_integrity.json``).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint, shared_point_executables
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from repro.runtime.fleet import (ChaosExecutable, FleetRouter, HealthState,
                                 NoReplicaAvailable)
from repro.runtime.integrity import BitFlipInjector, CanarySet, Scrubber
from repro.runtime.serve import AccelServer

MAX_BATCH = 8
POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
TOP_RUNG = POINTS[0].name
SIZES = (1, 2, 4)
WINDOW_PASSES = 6          # detection bound, in full scrub passes
SCRUB_RATE = 20e6          # bytes/sec — far above the tiny CNN's period
SCRUB_INTERVAL = 0.002


def _build_points():
    """One qjax artifact; every replica's rungs read its ONE packed buffer."""
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    pool = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)))
    res = DesignFlow(graph).run(targets=("qjax",),
                                dtconfig=DatatypeConfig(16, 8),
                                calib_inputs=(pool,))
    pts = shared_point_executables(res.writers["qjax"], POINTS)
    return pts, pool


def _golden_outputs(pts, pool) -> Dict[str, Dict[int, np.ndarray]]:
    """Known-good outputs per point per request size, captured before any
    chaos — the yardstick every served result is checked against."""
    return {name: {s: np.asarray(exe(pool[:s])) for s in SIZES}
            for name, exe in pts.items()}


def _matches(golden, size: int, val) -> bool:
    out = np.asarray(val[0] if isinstance(val, tuple) else val)
    return any(np.allclose(out, g[size], rtol=1e-4, atol=1e-5)
               for g in golden.values())


def run(full: bool = True) -> Dict:
    pts, pool = _build_points()
    packed = pts[TOP_RUNG].packed          # the ONE shared buffer
    for t in packed.tensors.values():      # derive the sub-byte view regions
        t.packed_view(4)
        t.packed_view(2)
    golden = _golden_outputs(pts, pool)
    golden_codes = {n: np.array(t.codes) for n, t in packed.tensors.items()}
    golden_scale = {n: np.array(t.scale) for n, t in packed.tensors.items()}
    golden_views = {(n, bits, align): np.array(buf)
                    for n, t in packed.tensors.items()
                    for (bits, align), buf in t._packed.items()}

    def restore_master():
        """Heal-path weight restore: pristine master + re-derived views."""
        for n, t in packed.tensors.items():
            t.codes = jnp.asarray(golden_codes[n])
            t.scale = jnp.asarray(golden_scale[n])
            t.seal()
            for (bits, align) in list(t._packed):
                t.repair_view(bits, align=align)

    scrubbers: List[Scrubber] = []         # every scrubber ever started
    live_scrub: Dict[str, Scrubber] = {}

    def make_factory(name: str, wrap=None):
        def factory():
            if packed.verify():            # healing a quarantined buffer:
                restore_master()           # restore before serving again
            mk = wrap if wrap is not None else (lambda exe: exe)
            wrapped = {p.name: mk(pts[p.name]) for p in POINTS}
            srv = AccelServer(wrapped[TOP_RUNG], max_batch=MAX_BATCH,
                              max_wait=0.002, point_executables=wrapped,
                              pipeline_depth=2)
            old = live_scrub.pop(name, None)
            if old is not None:
                old.stop()
            sc = Scrubber(packed, rate_bytes_s=SCRUB_RATE,
                          interval_s=SCRUB_INTERVAL)
            sc.tag = f"{name}:{len(scrubbers)}"      # forensics in the row
            srv.attach_scrubber(sc)
            sc.start()
            scrubbers.append(sc)
            live_scrub[name] = sc
            return srv
        return factory

    # replica B: generation 0 crashes its pump mid-run (fail-stop chaos
    # riding alongside the bit-flip chaos); healed rebuilds are clean
    b_generation = [0]
    b_counter = [0]

    def factory_b():
        gen = b_generation[0]
        b_generation[0] += 1
        wrap = (lambda exe: ChaosExecutable(exe, crash_at=[5],
                                            counter=b_counter)
                ) if gen == 0 else None
        return make_factory("b", wrap=wrap)()

    canaries = CanarySet.capture(pts, [(pool[:1],)], k=1,
                                 rtol=1e-3, atol=1e-4)
    router = FleetRouter(
        {"a": make_factory("a"), "b": factory_b, "c": make_factory("c")},
        retries=3, backoff_s=0.005,
        default_deadline_s=60.0,
        canaries=canaries,
        probe_interval_s=0.02,
        probe_timeout_s=10.0,
        heal_cooldown_s=0.2,
        seed=0)

    rng = np.random.default_rng(0)
    injector = BitFlipInjector(packed, seed=1, kinds=("view",))
    n_view_flips = 5 if full else 2
    per_flip_traffic = 12 if full else 6
    counters = {"ok": 0, "err": 0, "shed": 0, "corrupted": 0}

    def serve(n: int) -> None:
        tickets = []
        for _ in range(n):
            s = int(rng.choice(SIZES))
            try:
                tickets.append((s, router.submit(pool[:s])))
            except (NoReplicaAvailable, RuntimeError):
                counters["shed"] += 1
        for s, tk in tickets:
            try:
                val = tk.result(timeout=60)
            except TimeoutError:
                raise                      # a hung ticket fails the run
            except Exception:
                counters["err"] += 1
                continue
            counters["ok"] += 1
            if not _matches(golden, s, val):
                counters["corrupted"] += 1

    def passes() -> int:
        return max((sc.scrub_passes for sc in scrubbers), default=0)

    flips = []
    t0 = time.perf_counter()
    with router:
        serve(per_flip_traffic)            # warmup: trace every bucket/point

        # ---- phase A: repairable view SEUs under live traffic -------------
        for i in range(n_view_flips):
            rec = injector.flip(i)
            key = (rec.region.tensor, rec.region.bits, rec.region.align)
            p0 = passes()
            deadline = time.monotonic() + 15.0
            while packed.verify(bits=None) and time.monotonic() < deadline:
                time.sleep(SCRUB_INTERVAL)
            used = passes() - p0
            repaired = packed.verify() == []
            t = packed.tensors[rec.region.tensor]
            with t._lock:
                buf = np.array(t._packed[(rec.region.bits, rec.region.align)])
            bitexact = bool(np.array_equal(buf, golden_views[key]))
            flips.append({"region": rec.region.label(), "passes": used,
                          "detected": repaired, "bitexact": bitexact})
            serve(per_flip_traffic)        # traffic continues post-repair

        stats_a = router.stats()

        # ---- phase B: unrepairable master-code SEU ------------------------
        # barrier: phase A is fast enough (~100ms of flips) that replica b's
        # crash heal — gated on heal_cooldown_s — may still be pending; wait
        # for the crash chaos to fully resolve so the codes flip hits a fleet
        # of three LIVE pumps and every ejection below names the quarantine
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline:
            reps = router.stats()["replicas"]
            if all(r["state"] == HealthState.HEALTHY.value and r["alive"]
                   for r in reps.values()):
                break
            time.sleep(0.01)
        # drain is done (serve() claims every ticket); flip the int8 master
        BitFlipInjector(packed, seed=2, kinds=("codes",)).flip(99)
        # eject_cause persists across readmission, so "every replica shows a
        # quarantined last-ejection" is race-free to wait on
        deadline = time.monotonic() + 20.0
        quarantined_causes: List[str] = []
        while time.monotonic() < deadline:
            reps = router.stats()["replicas"]
            quarantined_causes = [r["eject_cause"] for r in reps.values()
                                  if r["eject_cause"] is not None]
            if sum(c == "quarantined" for c in quarantined_causes) \
                    == len(reps):
                break
            time.sleep(0.01)
        # heal: the sentinel rebuilds through the factories (which restore
        # the pristine master); wait until the whole fleet is readmitted
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            reps = router.stats()["replicas"]
            if all(r["state"] == HealthState.HEALTHY.value and r["alive"]
                   for r in reps.values()):
                break
            time.sleep(0.01)
        serve(per_flip_traffic)            # post-heal traffic must be clean
        stats = router.stats()
    wall = time.perf_counter() - t0
    for sc in scrubbers:
        sc.stop()

    detected_total = sum(sc.detected_flips for sc in scrubbers)
    repaired_total = sum(sc.repaired_views for sc in scrubbers)
    quarantines_total = sum(sc.quarantines for sc in scrubbers)
    submitted = counters["ok"] + counters["err"]
    return {
        "mode": "integrity_sdc",
        "replicas": len(stats["replicas"]),
        "view_flips": n_view_flips,
        "flips": flips,
        "window_passes": WINDOW_PASSES,
        "scrub_rate_mb_s": SCRUB_RATE / 1e6,
        "detected_flips": detected_total,
        "repaired_views": repaired_total,
        "quarantines": quarantines_total,
        "quarantined_causes": quarantined_causes,
        "quarantine_detail": [
            {"scrubber": sc.tag, "regions": sorted(sc.quarantined),
             "detected": sc.detected_flips, "repaired": sc.repaired_views}
            for sc in scrubbers],
        "canary_failures": stats["canary_failures"],
        "served_ok": counters["ok"],
        "served_err": counters["err"],
        "shed": counters["shed"],
        "corrupted_served": counters["corrupted"],
        "submitted": submitted,
        "availability": round(stats["availability"], 4),
        "availability_phase_a": round(stats_a["availability"], 4),
        "b_generation": stats["replicas"]["b"]["generation"],
        "b_readmissions": stats["replicas"]["b"]["readmissions"],
        "fleet_healthy_final": all(
            r["state"] == HealthState.HEALTHY.value
            for r in stats["replicas"].values()),
        "scrubbed_mb": round(sum(sc.scrubbed_bytes
                                 for sc in scrubbers) / 1e6, 2),
        "probes": stats["probes"],
        "retries": stats["retries"],
        "wall_s": round(wall, 3),
    }


def evaluate(row: Dict) -> Dict:
    detect_ok = (all(f["detected"] and f["passes"] <= row["window_passes"]
                     for f in row["flips"])
                 and row["detected_flips"] >= row["view_flips"] + 1)
    zero_corrupted = row["corrupted_served"] == 0
    repair_ok = (all(f["bitexact"] for f in row["flips"])
                 and row["repaired_views"] >= row["view_flips"])
    # phase B runs against a fully-healed fleet, so EVERY replica's last
    # ejection must name the quarantine (not a coincident pump death)
    quarantine_ok = (row["quarantines"] >= 1
                     and len(row["quarantined_causes"]) == row["replicas"]
                     and all(c == "quarantined"
                             for c in row["quarantined_causes"])
                     and row["fleet_healthy_final"])
    avail_ok = row["availability"] >= 0.99
    crash_ok = (row["b_generation"] >= 2 and row["b_readmissions"] >= 1)
    return {
        "pass": (detect_ok and zero_corrupted and repair_ok
                 and quarantine_ok and avail_ok and crash_ok),
        "detect_ok": detect_ok,
        "zero_corrupted": zero_corrupted,
        "repair_ok": repair_ok,
        "quarantine_ok": quarantine_ok,
        "availability_ok": avail_ok,
        "availability": row["availability"],
        "crash_readmit_ok": crash_ok,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 view flips, short traffic")
    ap.add_argument("--out", default="BENCH_integrity.json",
                    help="JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an integrity criterion fails")
    args = ap.parse_args()
    row = run(full=not args.quick)
    print("integrity_sdc," + ",".join(
        f"{k}={v}" for k, v in row.items() if k != "flips"))
    crit = evaluate(row)
    print("integrity_sdc,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {
        "backend": jax.default_backend(),
        "quick": args.quick,
        "row": row,
        "criterion": crit,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"integrity criterion failed: {crit}")


if __name__ == "__main__":
    main()
