"""Design-space exploration benchmark: Pareto fronts under resource budgets.

For each Table-I CNN topology (``mnist-cnn``, ``separable-cnn``) the
:class:`~repro.dse.DesignSpaceExplorer` runs twice:

* **unconstrained** — the full front the runtime ladder can walk (W8/W4/W2
  rungs costed in the roofline byte/latency terms, scored by top-1
  agreement against the float reference on the calibration batch);
* **constrained** — a ``weight_bytes`` ceiling placed strictly below the
  unconstrained front's top point, so the explorer must drop W8 and re-pick
  its compile configuration under the tightened budget.

Pass/fail criteria (reported, enforced with ``--check``):

* every front is non-empty and serializes/round-trips through JSON;
* the unconstrained front keeps >= 3 mutually non-dominated points (the
  adaptive ladder has somewhere to go);
* the constrained front's maximum weight bytes are strictly smaller than
  the unconstrained front's (the ceiling actually binds);
* each point's ``weight_bytes`` equals the packed-buffer accounting
  (``PackedWeights.view_bytes`` with the front's per-layer caps) — the
  predicted-bytes terms stay tied to the measured substrate.

Emits machine-readable JSON via ``--out`` (default ``BENCH_dse.json``).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.configs.separable_cnn import CONFIG as SEP
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir, separable_cnn_to_ir
from repro.dse import ParetoFront, ResourceBudget
from repro.models import cnn

CALIB_ROWS_FULL = 64
CALIB_ROWS_QUICK = 32


def _topologies():
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    yield "mnist-cnn", g, (CNN.image_hw[0], CNN.image_hw[1], CNN.in_channels)

    sep_params = cnn.init_separable_params(SEP, jax.random.PRNGKey(1))
    g_sep = separable_cnn_to_ir(
        SEP, {k: np.asarray(v) for k, v in sep_params.items()})
    yield ("separable-cnn", g_sep,
           (SEP.image_hw[0], SEP.image_hw[1], SEP.in_channels))


def _front_row(name: str, kind: str, front: ParetoFront,
               explore_s: float) -> Dict:
    return {
        "topology": name, "run": kind,
        "n_points": len(front),
        "points": "/".join(p.point.name for p in front.points),
        "max_weight_bytes": max(p.weight_bytes for p in front.points),
        "total_bytes": max(p.total_bytes for p in front.points),
        "fifo_slack": front.fifo_slack,
        "act_bits": front.act_bits,
        "agreement": "/".join(f"{p.agreement:.3f}" for p in front.points),
        "explore_s": round(explore_s, 3),
    }


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows: List[Dict] = []
    n = CALIB_ROWS_FULL if full else CALIB_ROWS_QUICK
    for name, graph, item_shape in _topologies():
        calib = rng.random((n, *item_shape), np.float32)
        flow = DesignFlow(graph)

        t0 = time.perf_counter()
        free = flow.explore((calib,))
        t_free = time.perf_counter() - t0
        rows.append(_front_row(name, "unconstrained", free, t_free))

        # ceiling strictly below the free front's top point: W8 must fall off
        ceiling = max(p.weight_bytes for p in free.points) - 1
        t0 = time.perf_counter()
        tight = flow.explore((calib,),
                             budget=ResourceBudget(weight_bytes=ceiling))
        t_tight = time.perf_counter() - t0
        row = _front_row(name, "constrained", tight, t_tight)
        row["weight_bytes_ceiling"] = ceiling
        rows.append(row)

        # predicted-bytes terms must match the packed-substrate accounting
        writer = flow.run(("qjax",), calib_inputs=(calib,),
                          **free.run_kwargs()).writers["qjax"]
        caps = free.per_layer_bits
        rows[-2]["bytes_match"] = all(
            p.weight_bytes == writer.packed.view_bytes(p.point.weight_bits,
                                                       caps=caps)
            for p in free.points)

        # fronts must survive serialization (what CI artifacts/serving load)
        rows[-2]["roundtrip"] = (
            ParetoFront.from_json(free.to_json()).to_json() == free.to_json())
    return rows


def evaluate(rows: List[Dict]) -> Dict:
    by = {(r["topology"], r["run"]): r for r in rows}
    checks = {}
    ok = True
    for name in ("mnist-cnn", "separable-cnn"):
        free = by.get((name, "unconstrained"))
        tight = by.get((name, "constrained"))
        if free is None or tight is None:
            return {"pass": False, "reason": f"missing rows for {name}"}
        c = {
            "front_nonempty": free["n_points"] > 0 and tight["n_points"] > 0,
            "free_points_ge_3": free["n_points"] >= 3,
            "constrained_smaller": (tight["max_weight_bytes"]
                                    < free["max_weight_bytes"]),
            "bytes_match": bool(free.get("bytes_match")),
            "roundtrip": bool(free.get("roundtrip")),
        }
        ok = ok and all(c.values())
        checks[name] = c
    return {"pass": ok, **{f"{n}.{k}": v for n, cs in checks.items()
                           for k, v in cs.items()}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller calibration batch (CI smoke)")
    ap.add_argument("--out", default="BENCH_dse.json",
                    help="machine-readable JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when a front criterion fails")
    args = ap.parse_args()
    rows = run(full=not args.quick)
    for r in rows:
        print("dse_pareto," + ",".join(f"{k}={v}" for k, v in r.items()))
    crit = evaluate(rows)
    print("dse_pareto,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {"backend": jax.default_backend(), "quick": args.quick,
           "rows": rows, "criterion": crit}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"dse_pareto criterion failed: {crit}")


if __name__ == "__main__":
    main()
