"""Serving-throughput benchmark: naive vs caller-pumped vs async-pumped.

A mixed-size request stream is served from identical batch-polymorphic
artifacts (the paper's one-accelerator-serves-evolving-workloads story):

* ``naive``      — every request executes alone, at its own size; each
  distinct size costs a trace and every request pays full dispatch overhead.
* ``sync_pump``  — the :class:`~repro.runtime.serve.AccelServer` packs
  requests up to ``max_batch``, pads to LRU-aligned buckets and slices
  results back per request; the caller thread drives ``pump()``.
* ``async_pump`` — same server with the background pump thread
  (``start()``): ``submit`` returns tickets immediately and host batch
  assembly overlaps device execution (``pipeline_depth`` batches stay
  dispatched-but-unforced).

A second section serves a two-tenant burst (weighted round-robin 2:1) and
reports per-tenant p50/p95 with the measured-latency bucket policy active
(``bucket_latency_s`` is the per-bucket execution EWMA the policy consults;
the static ladder heuristic only handles cold start).

Pass/fail criteria (reported, enforced with ``--check``):

* async_pump >= 1.3x sync_pump requests/s on the burst-backlog workload on
  a compiled backend (parity within 10% on the CPU reference backend, where
  the overlap window is bounded by host compute);
* both tenants report latency percentiles and a warm bucket-latency model.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.runtime.scheduler import percentile
from repro.runtime.serve import AccelServer

MAX_BATCH = 8


def _stream(n: int, rng) -> List[int]:
    """Mixed request sizes, skewed small (edge traffic: mostly singles)."""
    return [int(s) for s in rng.choice([1, 1, 1, 2, 2, 3, 4, 5, 8], size=n)]


def _row(
    mode: str, n: int, wall: float, lat: List[float], exe, padding_waste: float
) -> Dict:
    tel = exe.telemetry()
    return {
        "mode": mode,
        "requests": n,
        "req_per_s": round(n / wall, 1),
        "p50_ms": round(percentile(lat, 0.50) * 1e3, 2),
        "p95_ms": round(percentile(lat, 0.95) * 1e3, 2),
        "padding_waste": round(padding_waste, 3),
        "hit_rate": round(tel["hit_rate"], 3),
        "traces": tel["misses"],
    }


def _artifact(flow: DesignFlow):
    return flow.run().batched["jax"]


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(graph)
    n = 96 if full else 24
    sizes = _stream(n, rng)
    h, w = CNN.image_hw
    pool = np.asarray(
        jax.random.uniform(
            jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)
        )
    )
    xs = [pool[:s] for s in sizes]

    # Arrival model: a burst — all n requests are queued when serving starts
    # (the backlogged-server regime where scheduling policy matters; with an
    # idle server all modes degenerate to per-request execution).  Latency
    # is completion time since the burst for every mode.

    # naive: per-request FIFO execution on a fresh artifact (no coalescing)
    naive_exe = _artifact(flow)
    lat, t0 = [], time.perf_counter()
    for x in xs:
        jax.block_until_ready(naive_exe(x))
        lat.append(time.perf_counter() - t0)
    naive = _row("naive", n, time.perf_counter() - t0, lat, naive_exe, 0.0)

    # sync_pump: the server packs the backlog; the caller drives the pump
    srv = AccelServer(
        _artifact(flow), max_batch=MAX_BATCH, max_wait=0.001, queue_depth=n
    )
    t0 = time.perf_counter()
    tickets = [srv.submit(x) for x in xs]
    srv.pump(flush=True)  # drain the backlog (tail included)
    for t in tickets:
        jax.block_until_ready(srv.result(t))
    wall = time.perf_counter() - t0
    stats = srv.stats()
    sync = _row(
        "sync_pump", n, wall, srv.latencies, srv.executable, stats["padding_waste"]
    )
    sync["batches"] = stats["executed_batches"]

    # async_pump: background thread assembles/dispatches while the caller is
    # still submitting and while earlier batches execute on the device
    asrv = AccelServer(
        _artifact(flow),
        max_batch=MAX_BATCH,
        max_wait=0.001,
        queue_depth=n,
        pipeline_depth=3,
    )
    with asrv:
        t0 = time.perf_counter()
        tickets = [asrv.submit(x) for x in xs]
        for t in tickets:
            t.result(timeout=120)
        wall = time.perf_counter() - t0
        stats = asrv.stats()
        arow = _row(
            "async_pump",
            n,
            wall,
            asrv.latencies,
            asrv.executable,
            stats["padding_waste"],
        )
        arow["batches"] = stats["executed_batches"]
    return [naive, sync, arow]


def run_two_tenant(full: bool = True) -> Dict:
    """Two resident graphs multiplexed on one device, WRR 2:1, measured
    bucket policy active; returns the per-tenant stats breakdown."""
    rng = np.random.default_rng(7)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(graph)
    n = 48 if full else 16
    h, w = CNN.image_hw
    pool = np.asarray(
        jax.random.uniform(
            jax.random.PRNGKey(2), (MAX_BATCH, h, w, CNN.in_channels)
        )
    )
    srv = AccelServer(max_batch=MAX_BATCH, max_wait=0.001)
    srv.add_tenant(
        "interactive",
        _artifact(flow),
        max_batch=MAX_BATCH,
        max_wait=0.001,
        queue_depth=2 * n,
        weight=2,
    )
    srv.add_tenant(
        "bulk",
        _artifact(flow),
        max_batch=MAX_BATCH,
        max_wait=0.001,
        queue_depth=2 * n,
        weight=1,
    )
    with srv:
        tickets = [
            srv.submit(pool[: int(s)], tenant=name)
            for s in _stream(n, rng)
            for name in ("interactive", "bulk")
        ]
        for t in tickets:
            t.result(timeout=120)
    agg = srv.stats()
    out = {"mode": "two_tenant", "requests": 2 * n}
    for name, s in agg["tenants"].items():
        out[f"{name}_p50_ms"] = round(s.get("p50_latency_s", 0.0) * 1e3, 2)
        out[f"{name}_p95_ms"] = round(s.get("p95_latency_s", 0.0) * 1e3, 2)
        out[f"{name}_weight"] = s["weight"]
        # warm EWMA entries == the measured bucket policy is live (the
        # ladder heuristic only covers buckets with no measurement yet)
        out[f"{name}_measured_buckets"] = len(s["bucket_latency_s"])
    return out


def evaluate(rows: List[Dict], two_tenant: Dict) -> Dict:
    sync = next(r for r in rows if r["mode"] == "sync_pump")
    arow = next(r for r in rows if r["mode"] == "async_pump")
    ratio = arow["req_per_s"] / max(sync["req_per_s"], 1e-9)
    backend = jax.default_backend()
    # on a compiled backend the pump overlaps host assembly with device
    # execution; the CPU reference backend shares those cycles, so the bar
    # there is parity (the async path must not cost throughput)
    target = 1.3 if backend != "cpu" else 0.9
    measured = [v for k, v in two_tenant.items() if k.endswith("_measured_buckets")]
    percentiles = [v for k, v in two_tenant.items() if k.endswith("_p95_ms")]
    tenants_ok = (
        len(measured) == 2
        and all(m >= 1 for m in measured)
        and all(p > 0 for p in percentiles)
    )
    return {
        "pass": ratio >= target and tenants_ok,
        "backend": backend,
        "async_vs_sync": round(ratio, 2),
        "target": target,
        "tenants_ok": tenants_ok,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="24-request stream")
    ap.add_argument("--out", default="BENCH_serve.json", help="JSON output path")
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit non-zero when the async-vs-sync criterion fails",
    )
    args = ap.parse_args()
    rows = run(full=not args.quick)
    two = run_two_tenant(full=not args.quick)
    for r in rows + [two]:
        print("serve_throughput," + ",".join(f"{k}={v}" for k, v in r.items()))
    naive, sync, arow = rows
    speedup = sync["req_per_s"] / max(naive["req_per_s"], 1e-9)
    print(f"serve_throughput,mode=summary,coalesced_speedup={speedup:.2f}x")
    crit = evaluate(rows, two)
    print(
        "serve_throughput,mode=criterion,"
        + ",".join(f"{k}={v}" for k, v in crit.items())
    )
    doc = {
        "backend": jax.default_backend(),
        "quick": args.quick,
        "rows": rows,
        "two_tenant": two,
        "criterion": crit,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"serve throughput criterion failed: {crit}")


if __name__ == "__main__":
    main()
