"""Serving-throughput benchmark: coalesced scheduling vs naive per-request.

A mixed-size request stream is served twice from identical batch-polymorphic
artifacts (the paper's one-accelerator-serves-evolving-workloads story):

* ``naive``     — every request executes alone, at its own size; each
  distinct size costs a trace and every request pays full dispatch overhead.
* ``coalesced`` — the :class:`~repro.runtime.serve.AccelServer` packs
  requests up to ``max_batch``, pads to LRU-aligned buckets and slices
  results back per request.

Reported per mode: requests/s, p50/p95 latency, padding waste (zero rows /
executed rows), jit-cache hit-rate and trace count — throughput per trace is
the figure of merit (Guo et al. frame throughput-per-resource; the traced
executable *is* the resource here).
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.runtime.scheduler import percentile
from repro.runtime.serve import AccelServer

MAX_BATCH = 8


def _stream(n: int, rng) -> List[int]:
    """Mixed request sizes, skewed small (edge traffic: mostly singles)."""
    return [int(s) for s in rng.choice([1, 1, 1, 2, 2, 3, 4, 5, 8], size=n)]


def _row(
    mode: str, n: int, wall: float, lat: List[float], exe, padding_waste: float
) -> Dict:
    tel = exe.telemetry()
    return {
        "mode": mode,
        "requests": n,
        "req_per_s": round(n / wall, 1),
        "p50_ms": round(percentile(lat, 0.50) * 1e3, 2),
        "p95_ms": round(percentile(lat, 0.95) * 1e3, 2),
        "padding_waste": round(padding_waste, 3),
        "hit_rate": round(tel["hit_rate"], 3),
        "traces": tel["misses"],
    }


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(graph)
    n = 96 if full else 24
    sizes = _stream(n, rng)
    h, w = CNN.image_hw
    pool = np.asarray(
        jax.random.uniform(
            jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)
        )
    )
    xs = [pool[:s] for s in sizes]

    # Arrival model: a burst — all n requests are queued when serving starts
    # (the backlogged-server regime where scheduling policy matters; with an
    # idle server both modes degenerate to per-request execution).  Latency
    # is completion time since the burst for both modes.

    # naive: per-request FIFO execution on a fresh artifact (no coalescing)
    naive_exe = flow.run().batched["jax"]
    lat, t0 = [], time.perf_counter()
    for x in xs:
        jax.block_until_ready(naive_exe(x))
        lat.append(time.perf_counter() - t0)
    naive = _row("naive", n, time.perf_counter() - t0, lat, naive_exe, 0.0)

    # coalesced: the AccelServer packs the same backlog into bucketed batches
    srv = AccelServer(
        flow.run().batched["jax"], max_batch=MAX_BATCH, max_wait=0.001, queue_depth=n
    )
    t0 = time.perf_counter()
    tickets = [srv.submit(x) for x in xs]
    srv.pump(flush=True)         # drain the backlog (tail included)
    for t in tickets:
        jax.block_until_ready(srv.result(t))
    wall = time.perf_counter() - t0
    stats = srv.stats()
    coal = _row(
        "coalesced", n, wall, srv.latencies, srv.executable, stats["padding_waste"]
    )
    coal["batches"] = stats["executed_batches"]
    return [naive, coal]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="24-request stream")
    rows = run(full=not ap.parse_args().quick)
    for r in rows:
        print("serve_throughput," + ",".join(f"{k}={v}" for k, v in r.items()))
    naive, coal = rows
    speedup = coal["req_per_s"] / max(naive["req_per_s"], 1e-9)
    print(f"serve_throughput,mode=summary,coalesced_speedup={speedup:.2f}x")


if __name__ == "__main__":
    main()
