"""Aggregate dry-run artifacts into the §Roofline table (CSV + markdown)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")

COLS = ("arch", "shape", "mesh", "kind", "bound", "compute_s", "memory_s",
        "collective_s", "step_s", "useful_flops_ratio", "mfu")


def load(mesh: Optional[str] = "16x16", tag: Optional[str] = None) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        meshtag = parts[2] if len(parts) > 2 else ""
        with open(path) as f:
            r = json.load(f)
        rmesh = r.get("mesh", "")
        rest = meshtag[len(rmesh):]
        file_tag = rest[1:] if rest.startswith("_") else None
        if tag != file_tag:
            continue
        if mesh and rmesh != mesh:
            continue
        rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def csv_rows(rows: List[Dict]) -> List[str]:
    out = []
    for r in rows:
        vals = []
        for c in COLS:
            v = r.get(c, "")
            if isinstance(v, float):
                v = f"{v:.3e}" if "_s" in c else f"{v:.3f}"
            vals.append(str(v))
        out.append(",".join(vals))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | bound | compute (s) | memory (s) | collective (s) "
           "| useful FLOPs | MFU |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | **{r['bound']}** "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu']:.3f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    print("roofline," + ",".join(COLS))
    for line in csv_rows(load("16x16")):
        print("roofline," + line)
    mp = load("2x16x16")
    if mp:
        print(f"# multi-pod cells compiled: {len(mp)}")


if __name__ == "__main__":
    main()
