"""Table II reproduction: mixed-precision exploration of the MNIST accelerator.

Paper columns -> TPU proxies (DESIGN.md §2): LUT/FF/DSP -> MXU FLOPs,
BRAM -> packed weight bytes, latency/throughput -> measured wall time of the
streaming executable (relative ordering), power/energy -> roofline energy
model (pJ/byte HBM + pJ/FLOP).

Beyond the paper's uniform ``Dx-Wy`` grid, the table now includes
*heterogeneous per-layer* rows (the paper's stated WIP goal — a possibly
different datatype per layer): two hand-picked ``PrecisionMap`` points and
one found by the greedy sensitivity explorer (``D16-Wauto``).  Weight bytes
are computed from the pass-transformed graph, so Conv+BN fusion's removal of
the BN statistic tensors shows up in the storage column, and each row also
reports ``fifo_bytes`` — the aggregate streaming-buffer memory of the sized
topology (``StreamWriter.topology()['total_fifo_bytes']``), the BRAM-column
analogue.  The graph is compiled once with a *symbolic* batch dim and served
through the batch-polymorphic executable, so the same artifact handles the
calibration and evaluation batch sizes without re-reading the model.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.ir import Graph
from repro.core.reader import cnn_to_ir
from repro.data.mnist import make_dataset
from repro.models import cnn
from repro.quant.qtypes import TABLE2_POINTS, DatatypeConfig, PrecisionMap

# energy model constants (v5e-class, pJ)
PJ_PER_FLOP = 0.35
PJ_PER_BYTE = 15.0

# heterogeneous per-layer working points (node names from cnn_to_ir)
HETERO_POINTS = (
    # W8 backbone, deeper conv dropped to W4
    PrecisionMap(DatatypeConfig(16, 8), {"conv1": DatatypeConfig(16, 4)}),
    # aggressive W4 default, first conv protected at W8, classifier at W2
    PrecisionMap(DatatypeConfig(16, 4), {"conv0": DatatypeConfig(16, 8),
                                         "fc": DatatypeConfig(16, 2)}),
)


def train_cnn(n_train=1024, epochs=6, seed=0):
    imgs, labels = make_dataset(n_train, seed=seed)

    @jax.jit
    def step(params, x, y):
        (loss, aux), g = jax.value_and_grad(cnn.loss_fn, has_aux=True)(
            params, x, y, CNN)
        params = {k: v - 0.05 * g[k] for k, v in params.items()}
        for k, v in aux.items():
            params[k] = 0.9 * params[k] + 0.1 * v
        return params, loss

    params = cnn.init_params(CNN, jax.random.PRNGKey(seed))
    for _ in range(epochs):
        for i in range(0, n_train, 64):
            params, _ = step(params, jnp.asarray(imgs[i:i + 64]),
                             jnp.asarray(labels[i:i + 64]))
    return params


def model_flops(batch: int) -> int:
    h, w = CNN.image_hw
    total, cin = 0, CNN.in_channels
    for cout in CNN.conv_channels:
        total += 2 * h * w * CNN.kernel_size ** 2 * cin * cout
        h, w, cin = h // 2, w // 2, cout
    total += 2 * CNN.fc_in * CNN.n_classes
    return total * batch


def weight_bytes(graph: Graph, dt) -> int:
    """Packed weight storage of the compiled graph under per-layer bits."""
    from repro.quant.ptq import effective_weight_dt
    default = dt.default if isinstance(dt, PrecisionMap) else dt
    n = 0
    for name, v in graph.initializers.items():
        node_dt = effective_weight_dt(graph, name, default)
        bits = node_dt.weight_bits if v.ndim >= 2 else 32
        n += v.size * bits // 8
    return n


def run(full: bool = True) -> List[Dict]:
    params = train_cnn(1024 if full else 256, 6 if full else 2)
    test_x, test_y = make_dataset(512 if full else 128, seed=99)
    tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(g)
    points = list(TABLE2_POINTS) + list(HETERO_POINTS)
    auto_pm, _ = flow.explore_mixed_precision((tx[:64],), tol=0.02)
    points.append(auto_pm)
    rows = []
    for dt in points:
        res = flow.run(targets=("stream",), dtconfig=dt, calib_inputs=(tx[:64],))
        exe = res.batched["stream"]
        logits = exe(tx)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == ty)))
        # latency: best-of-5 jitted wall time (relative ordering on CPU)
        exe(tx).block_until_ready()
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            exe(tx).block_until_ready()
            times.append(time.perf_counter() - t0)
        us = min(times) * 1e6 / len(test_y)
        fl = model_flops(1)
        wb = weight_bytes(res.graph, dt)
        fifo_b = res.writers["stream"].topology()["total_fifo_bytes"]
        act_bits = dt.default.act_bits if isinstance(dt, PrecisionMap) else dt.act_bits
        act_bytes = 2 * 28 * 28 * 16 * (act_bits / 8)
        energy_uj = (fl * PJ_PER_FLOP + (wb + act_bytes) * PJ_PER_BYTE) * 1e-6
        if dt is auto_pm:
            per = ",".join(f"{k}:{v.weight_bits}"
                           for k, v in sorted(dt.per_node.items()))
            label = f"D{act_bits}-Wauto[{per}]"
        else:
            label = dt.name
        rows.append({
            "datatype": label,
            "zero_weights_pct": round(100 * res.stats.get("zero_weight_frac", 0.0), 1),
            "weight_bytes": wb,
            "fifo_bytes": fifo_b,
            "accuracy_pct": round(100 * acc, 1),
            "us_per_image": round(us, 1),
            "est_energy_uj": round(energy_uj, 2),
        })
    return rows


def main() -> None:
    for r in run():
        print("table2_mixed_precision," + ",".join(f"{k}={v}"
                                                   for k, v in r.items()))


if __name__ == "__main__":
    main()
