"""Table I analogue: architecture-style comparison on the same classifier.

The paper compares streaming frameworks (FINN, HLS4ML).  Without an FPGA the
comparable axis is the *execution style* on our own substrate:

  single-engine  - one fused jit of the whole model (the 'single computational
                   engine' style, §II)
  streaming      - per-layer actor pipeline from the StreamWriter (Pallas
                   line-buffer conv actors)
  streaming-q    - streaming + D16-W8 quantized dataflow (FINN/HLS4ML style
                   reduced precision)

Reported per row: us/image, accuracy, model FLOPs, weight bytes.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.data.mnist import make_dataset
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from benchmarks.table2_mixed_precision import model_flops, train_cnn, weight_bytes


def _time(fn, *args) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return min(times)


def run(full: bool = True) -> List[Dict]:
    params = train_cnn(1024 if full else 256, 6 if full else 2)
    test_x, test_y = make_dataset(512 if full else 128, seed=99)
    tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)
    B = len(test_y)
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()}, batch=B)
    flow = DesignFlow(g)

    rows = []

    # single computational engine: fused jit of the plain model
    engine = jax.jit(lambda x: cnn.forward(params, x, CNN)[0])
    acc = float(jnp.mean((jnp.argmax(engine(tx), -1) == ty)))
    rows.append({"style": "single-engine", "datatype": "D32-W32",
                 "accuracy_pct": round(100 * acc, 1),
                 "us_per_image": round(_time(engine, tx) * 1e6 / B, 1),
                 "model_flops": model_flops(1),
                 "weight_bytes": weight_bytes(DatatypeConfig(32, 32))})

    for name, dt in (("streaming", DatatypeConfig(32, 32)),
                     ("streaming-q", DatatypeConfig(16, 8))):
        res = flow.run(targets=("stream",), dtconfig=dt, calib_inputs=(tx[:64],))
        exe = jax.jit(res.executables["stream"])
        acc = float(jnp.mean((jnp.argmax(exe(tx), -1) == ty)))
        rows.append({"style": name, "datatype": dt.name,
                     "accuracy_pct": round(100 * acc, 1),
                     "us_per_image": round(_time(exe, tx) * 1e6 / B, 1),
                     "model_flops": model_flops(1),
                     "weight_bytes": weight_bytes(dt)})
    return rows


def main() -> None:
    for r in run():
        print("table1_frameworks," + ",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
