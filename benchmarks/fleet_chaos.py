"""Fleet chaos benchmark: replicated serving under injected faults.

Three :class:`~repro.runtime.serve.AccelServer` replicas front the SAME
shared :class:`~repro.quant.pack.PackedWeights` buffer (W8/W4/W2 point
executables from one ``qjax`` writer — replication multiplies pump threads,
not weight memory) behind a :class:`~repro.runtime.fleet.FleetRouter`.  A
burst of mixed-size requests is served while the chaos layer injects:

* a **pump-killing crash** on replica B mid-burst (a
  :class:`~repro.runtime.fleet.ReplicaCrash` escapes the per-batch
  containment and takes the whole pump thread down, like a segfaulting
  device runtime) — B must be ejected, healed via its factory after the
  cooldown, probed, and readmitted;
* a **latency-spike window** on replica C (schedule-driven delays through
  the generalized :class:`~repro.runtime.ft.FailureInjector`), driving the
  shared :class:`~repro.core.adaptive.BrownoutSelector` down the
  W8 -> W4/W2 ladder; a recovery tail of clean traffic must walk it back
  to W8.

Pass/fail criteria (reported, enforced with ``--check``):

* ZERO lost tickets: every submitted request resolves — success or typed
  failure — within its bound (no hung waiter);
* availability >= 99% over the whole run (retries/hedging mask the crash
  and the spikes);
* the crashed replica is readmitted after heal (``readmissions >= 1`` and
  a rebuilt server generation);
* the brownout trajectory is observable in fleet stats: at least one
  downshift during the spike window AND the fleet back at the top rung
  (W8) by the end of the recovery tail.

Emits machine-readable JSON via ``--out`` (default ``BENCH_fleet.json``) so
CI tracks the robustness trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import (BrownoutSelector, ServiceObjective,
                                 WorkingPoint, shared_point_executables)
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig
from repro.runtime.fleet import ChaosExecutable, FleetRouter
from repro.runtime.ft import FailureInjector
from repro.runtime.serve import AccelServer

MAX_BATCH = 8
POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
TOP_RUNG = POINTS[0].name


def _build_points():
    """One qjax artifact; every replica's rungs read its ONE packed buffer."""
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    graph = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    pool = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (MAX_BATCH, h, w, CNN.in_channels)))
    res = DesignFlow(graph).run(targets=("qjax",),
                                dtconfig=DatatypeConfig(16, 8),
                                calib_inputs=(pool,))
    pts = shared_point_executables(res.writers["qjax"], POINTS)
    return pts, pool


def _measure_base(exe, x) -> float:
    """Median warm per-batch latency — the yardstick every chaos magnitude
    and SLO threshold scales from, so the gate holds on any backend."""
    jax.block_until_ready(exe(x))            # compile
    samples = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(exe(x))
        samples.append(time.perf_counter() - t0)
    return max(float(np.median(samples)), 1e-4)


def run(full: bool = True) -> Dict:
    pts, pool = _build_points()
    base = _measure_base(pts[TOP_RUNG], pool)
    delay_s = max(20.0 * base, 0.25)         # an unmistakable spike
    n_burst = 90 if full else 36
    n_tail = 60 if full else 30

    slo = ServiceObjective(p95_latency_s=max(4.0 * base, 0.02),
                           window=12, min_samples=6, hold=6)
    brownout = BrownoutSelector(POINTS, slo)

    def server(wrap=lambda exe: exe):
        wrapped = {p.name: wrap(pts[p.name]) for p in POINTS}
        return AccelServer(wrapped[TOP_RUNG], max_batch=MAX_BATCH,
                           max_wait=0.002, point_executables=wrapped,
                           pipeline_depth=2)

    # replica B: generation 0 crashes its pump mid-burst; the healed
    # rebuild (generation 1+) is clean
    b_generation = [0]

    def factory_b():
        gen = b_generation[0]
        b_generation[0] += 1
        if gen == 0:
            counter = [0]
            return server(lambda exe: ChaosExecutable(
                exe, crash_at=[4], counter=counter))
        return server()

    # replica C: a windowed latency spike (calls 3..8 across its rungs)
    c_counter = [0]
    c_injector = FailureInjector(delay_at=list(range(3, 9)), delay_s=delay_s)

    def factory_c():
        return server(lambda exe: ChaosExecutable(
            exe, c_injector, counter=c_counter))

    router = FleetRouter(
        {"a": server, "b": factory_b, "c": factory_c},
        brownout=brownout,
        retries=3, backoff_s=0.005,
        hedge_after_s=max(8.0 * base, 0.1),
        default_deadline_s=120.0,
        probe=[pool[:1]],
        probe_interval_s=0.02,
        probe_timeout_s=delay_s + 10.0,
        heal_cooldown_s=0.2,
        seed=0)

    rng = np.random.default_rng(0)
    sizes = [int(s) for s in rng.choice([1, 1, 2, 2, 3, 4, 8], size=n_burst)]
    resolved_ok = resolved_err = 0
    t0 = time.perf_counter()
    with router:
        tickets = [router.submit(pool[:s]) for s in sizes]
        for t in tickets:
            try:
                t.result(timeout=120)
                resolved_ok += 1
            except TimeoutError:
                raise                        # a hung ticket fails the run
            except Exception:
                resolved_err += 1
        burst_wall = time.perf_counter() - t0
        min_rung = brownout.telemetry()["point"]

        # recovery tail: clean traffic walks the ladder back up and gives
        # the sentinel time to heal + readmit the crashed replica
        deadline = time.monotonic() + 60.0
        tail = 0
        while time.monotonic() < deadline:
            tk = router.submit(pool[:2])
            try:
                tk.result(timeout=120)
                resolved_ok += 1
            except TimeoutError:
                raise
            except Exception:
                resolved_err += 1
            tail += 1
            stats = router.stats()
            recovered = stats["brownout"]["point"] == TOP_RUNG
            readmitted = stats["replicas"]["b"]["readmissions"] >= 1
            if tail >= n_tail and recovered and readmitted:
                break
        stats = router.stats()
    wall = time.perf_counter() - t0

    submitted = n_burst + tail
    trajectory = stats["brownout"]["shifts"]
    return {
        "mode": "fleet_chaos",
        "replicas": len(stats["replicas"]),
        "submitted": submitted,
        "resolved_ok": resolved_ok,
        "resolved_err": resolved_err,
        "lost": submitted - resolved_ok - resolved_err,
        "availability": round(stats["availability"], 4),
        "retries": stats["retries"],
        "hedges": stats["hedges"],
        "hedge_wins": stats["hedge_wins"],
        "probes": stats["probes"],
        "burst_wall_s": round(burst_wall, 3),
        "wall_s": round(wall, 3),
        "base_latency_ms": round(base * 1e3, 3),
        "injected_delay_ms": round(delay_s * 1e3, 1),
        "injected_delays": c_injector.injected_delays,
        "b_ejections": stats["replicas"]["b"]["ejections"],
        "b_readmissions": stats["replicas"]["b"]["readmissions"],
        "b_generation": stats["replicas"]["b"]["generation"],
        "brownout_trajectory": trajectory,
        "brownout_min_rung": min_rung,
        "brownout_final": stats["brownout"]["point"],
    }


def evaluate(row: Dict) -> Dict:
    zero_lost = row["lost"] == 0
    avail_ok = row["availability"] >= 0.99
    readmit_ok = (row["b_readmissions"] >= 1 and row["b_ejections"] >= 1
                  and row["b_generation"] >= 2)
    names = [p.name for p in POINTS]
    downs = [s for s in row["brownout_trajectory"]
             if names.index(s[1]) > names.index(s[0])]
    brownout_ok = bool(downs) and row["brownout_final"] == TOP_RUNG
    return {
        "pass": zero_lost and avail_ok and readmit_ok and brownout_ok,
        "zero_lost": zero_lost,
        "availability_ok": avail_ok,
        "availability": row["availability"],
        "readmit_ok": readmit_ok,
        "brownout_ok": brownout_ok,
        "downshifts": len(downs),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="36-request burst")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when a chaos criterion fails")
    args = ap.parse_args()
    row = run(full=not args.quick)
    print("fleet_chaos," + ",".join(
        f"{k}={v}" for k, v in row.items() if not k.startswith("_")))
    crit = evaluate(row)
    print("fleet_chaos,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {
        "backend": jax.default_backend(),
        "quick": args.quick,
        "row": {k: v for k, v in row.items() if not k.startswith("_")},
        "criterion": crit,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"fleet chaos criterion failed: {crit}")


if __name__ == "__main__":
    main()
