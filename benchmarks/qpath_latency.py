"""Hot-path latency: fake-quant-f32 execution vs the packed-weight engine.

The same pass-compiled graph is executed two ways across the Table-I
topologies and batch buckets:

* ``fake_quant`` — the legacy ``"jax"`` writer: weights fake-quantized to
  float copies at build time, a plain f32 ``@``/``conv`` per actor and a
  separate round/clip activation-quant op per FIFO;
* ``packed``     — the ``"qjax"`` writer: int8 master codes streamed through
  the dequant-fused qmatmul kernels (compiled Pallas on TPU; off-TPU the jnp
  ref fallback, where XLA folds the constant dequant), with bias/ReLU and the
  activation quant fused into the kernel epilogue.

Pass/fail criterion (reported, enforced with ``--check``): on a compiled
backend (qpath == "pallas") the packed path must be >= 1.3x faster on the
MNIST-CNN topology at batch 8; on the CPU ref fallback the criterion is
parity within 10% (speedup >= 0.9).  Emits machine-readable JSON via
``--out`` (default ``BENCH_qpath.json``) so CI tracks the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig

DT = DatatypeConfig(16, 8)          # the streaming-q working point
MLP_LAYERS = [784, 256, 128, 10]    # HLS4ML-style FC stack (Table I)
CRITERION_TOPOLOGY, CRITERION_BATCH = "mnist-cnn", 8


def _time_pair(f1, f2, x, iters: int = 15):
    """Interleaved min-of-N for both paths: alternating the measurements
    cancels slow machine drift that back-to-back loops fold into whichever
    path runs second (which is exactly the 5-10% this benchmark resolves)."""
    jax.block_until_ready(f1(x))                # compile/trace warm-up
    jax.block_until_ready(f2(x))
    b1 = b2 = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f1(x))
        b1 = min(b1, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f2(x))
        b2 = min(b2, time.perf_counter() - t0)
    return b1, b2


def _topologies(rng):
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g_cnn = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    yield "mnist-cnn", g_cnn, (h, w, CNN.in_channels)

    mlp_params = {}
    for i in range(len(MLP_LAYERS) - 1):
        fan_in, fan_out = MLP_LAYERS[i], MLP_LAYERS[i + 1]
        mlp_params[f"fc{i}/w"] = rng.standard_normal(
            (fan_in, fan_out)).astype(np.float32) / np.sqrt(fan_in)
        mlp_params[f"fc{i}/b"] = np.zeros(fan_out, np.float32)
    name = "mlp-" + "-".join(str(s) for s in MLP_LAYERS)
    yield name, mlp_to_ir(MLP_LAYERS, mlp_params), (MLP_LAYERS[0],)


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    batches = (1, 8, 32) if full else (8,)
    rows = []
    for name, graph, item_shape in _topologies(rng):
        calib = rng.random((2, *item_shape), np.float32)
        flow = DesignFlow(graph)
        res = flow.run(targets=("jax", "qjax"), dtconfig=DT,
                       calib_inputs=(calib,))
        fq, pk = res.batched["jax"], res.batched["qjax"]
        qpath = res.writers["qjax"].qpath
        for b in batches:
            x = rng.random((b, *item_shape), np.float32)
            t_fq, t_pk = _time_pair(fq, pk, x)
            rows.append({
                "topology": name, "batch": b, "qpath": qpath,
                "fake_quant_us": round(t_fq * 1e6, 1),
                "packed_us": round(t_pk * 1e6, 1),
                "speedup": round(t_fq / max(t_pk, 1e-12), 3),
            })
    return rows


def evaluate(rows: List[Dict]) -> Dict:
    """The acceptance criterion over the MNIST-CNN @ batch-8 row."""
    row = next((r for r in rows if r["topology"] == CRITERION_TOPOLOGY
                and r["batch"] == CRITERION_BATCH), None)
    if row is None:
        return {"pass": False, "reason": "criterion row missing"}
    target = 1.3 if row["qpath"] == "pallas" else 0.9
    return {"pass": row["speedup"] >= target, "target_speedup": target,
            "achieved_speedup": row["speedup"], "qpath": row["qpath"],
            "topology": CRITERION_TOPOLOGY, "batch": CRITERION_BATCH}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch-8 bucket only (CI smoke)")
    ap.add_argument("--out", default="BENCH_qpath.json",
                    help="machine-readable JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the speedup criterion fails")
    args = ap.parse_args()
    rows = run(full=not args.quick)
    for r in rows:
        print("qpath_latency," + ",".join(f"{k}={v}" for k, v in r.items()))
    crit = evaluate(rows)
    print("qpath_latency,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {"backend": jax.default_backend(), "datatype": DT.name,
           "quick": args.quick, "rows": rows, "criterion": crit}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"qpath criterion failed: {crit}")


if __name__ == "__main__":
    main()
