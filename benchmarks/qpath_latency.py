"""Hot-path latency: fake-quant-f32 execution vs the packed-weight engine
vs the fully-integer (int8 activation code) engine.

The same pass-compiled graph is executed three ways across the Table-I
topologies and batch buckets:

* ``fake_quant`` — the legacy ``"jax"`` writer: weights fake-quantized to
  float copies at build time, a plain f32 ``@``/``conv`` per actor and a
  separate round/clip activation-quant op per FIFO;
* ``packed``     — the ``"qjax"`` writer at D16: int8 master codes streamed
  through the dequant-fused qmatmul kernels (compiled Pallas on TPU; off-TPU
  the jnp ref fallback, where XLA folds the constant dequant), with
  bias/ReLU and the activation quant fused into the kernel epilogue;
* ``int8_act``   — the ``"qjax"`` writer at D8: the fully-integer hot path.
  Calibrated per-FIFO activation-code scales, int8 codes flowing between
  layers (int32 MACs; on CPU the exact-in-f32 integer dot), and at W4/W2
  sub-byte packed weight buffers unpacked in-VMEM.

Each topology also reports the *resident streamed weight bytes* per working
point (``PackedWeights.view_bytes``): W4 <= 0.55x and W2 <= 0.30x of W8 is
the packed-storage acceptance band.

Pass/fail criterion (reported, enforced with ``--check``) on the MNIST-CNN
topology at batch 8: the packed path must be >= 1.3x faster than fake-quant
on a compiled backend (parity within 10% on the CPU ref fallback), and the
int8-act path must be no slower than the f32-act packed path within 10%
(ratio >= 0.9) on either backend.  Emits machine-readable JSON via ``--out``
(default ``BENCH_qpath.json``) so CI tracks the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig

DT = DatatypeConfig(16, 8)          # the streaming-q working point (f32 act)
DT_INT8 = DatatypeConfig(8, 8)      # the fully-integer working point
MLP_LAYERS = [784, 256, 128, 10]    # HLS4ML-style FC stack (Table I)
CRITERION_TOPOLOGY, CRITERION_BATCH = "mnist-cnn", 8


def _time_many(fns, x, iters: int = 15) -> List[float]:
    """Interleaved min-of-N across all paths: alternating the measurements
    cancels slow machine drift that back-to-back loops fold into whichever
    path runs last (which is exactly the 5-10% this benchmark resolves)."""
    for f in fns:
        jax.block_until_ready(f(x))             # compile/trace warm-up
    best = [float("inf")] * len(fns)
    for _ in range(iters):
        for i, f in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _topologies(rng):
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g_cnn = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    yield "mnist-cnn", g_cnn, (h, w, CNN.in_channels)

    mlp_params = {}
    for i in range(len(MLP_LAYERS) - 1):
        fan_in, fan_out = MLP_LAYERS[i], MLP_LAYERS[i + 1]
        mlp_params[f"fc{i}/w"] = rng.standard_normal(
            (fan_in, fan_out)).astype(np.float32) / np.sqrt(fan_in)
        mlp_params[f"fc{i}/b"] = np.zeros(fan_out, np.float32)
    name = "mlp-" + "-".join(str(s) for s in MLP_LAYERS)
    yield name, mlp_to_ir(MLP_LAYERS, mlp_params), (MLP_LAYERS[0],)


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    batches = (1, 8, 32) if full else (8,)
    rows = []
    for name, graph, item_shape in _topologies(rng):
        calib = rng.random((2, *item_shape), np.float32)
        res = DesignFlow(graph).run(targets=("jax", "qjax"), dtconfig=DT,
                                    calib_inputs=(calib,))
        res8 = DesignFlow(graph).run(targets=("qjax",), dtconfig=DT_INT8,
                                     calib_inputs=(calib,))
        fq, pk = res.batched["jax"], res.batched["qjax"]
        i8 = res8.batched["qjax"]
        qw, qw8 = res.writers["qjax"], res8.writers["qjax"]
        qpath = qw.qpath
        assert qw8.int8_act_on, "D8 point must enable the integer hot path"
        storage = {f"w{b}_bytes": qw.packed.view_bytes(b) for b in (8, 4, 2)}
        for b in batches:
            x = rng.random((b, *item_shape), np.float32)
            t_fq, t_pk, t_i8 = _time_many((fq, pk, i8), x)
            rows.append({
                "topology": name, "batch": b, "qpath": qpath,
                "fake_quant_us": round(t_fq * 1e6, 1),
                "packed_us": round(t_pk * 1e6, 1),
                "int8act_us": round(t_i8 * 1e6, 1),
                "speedup": round(t_fq / max(t_pk, 1e-12), 3),
                "int8act_vs_packed": round(t_pk / max(t_i8, 1e-12), 3),
                **storage,
            })
    return rows


def evaluate(rows: List[Dict]) -> Dict:
    """The acceptance criteria over the MNIST-CNN @ batch-8 row."""
    row = next((r for r in rows if r["topology"] == CRITERION_TOPOLOGY
                and r["batch"] == CRITERION_BATCH), None)
    if row is None:
        return {"pass": False, "reason": "criterion row missing"}
    target = 1.3 if row["qpath"] == "pallas" else 0.9
    packed_ok = row["speedup"] >= target
    int8_ok = row["int8act_vs_packed"] >= 0.9
    bytes_ok = (row["w4_bytes"] <= 0.55 * row["w8_bytes"]
                and row["w2_bytes"] <= 0.30 * row["w8_bytes"])
    return {"pass": packed_ok and int8_ok and bytes_ok,
            "target_speedup": target, "achieved_speedup": row["speedup"],
            "int8act_vs_packed": row["int8act_vs_packed"],
            "int8act_target": 0.9, "packed_bytes_ok": bytes_ok,
            "qpath": row["qpath"], "topology": CRITERION_TOPOLOGY,
            "batch": CRITERION_BATCH}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch-8 bucket only (CI smoke)")
    ap.add_argument("--out", default="BENCH_qpath.json",
                    help="machine-readable JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the speedup criterion fails")
    args = ap.parse_args()
    rows = run(full=not args.quick)
    for r in rows:
        print("qpath_latency," + ",".join(f"{k}={v}" for k, v in r.items()))
    crit = evaluate(rows)
    print("qpath_latency,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {"backend": jax.default_backend(),
           "datatype": {"packed": DT.name, "int8_act": DT_INT8.name},
           "quick": args.quick, "rows": rows, "criterion": crit}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"qpath criterion failed: {crit}")


if __name__ == "__main__":
    main()
