"""Hot-path latency: fake-quant-f32 execution vs the packed-weight engine
vs the fully-integer (int8 activation code) engine.

The same pass-compiled graph is executed three ways across the Table-I
topologies and batch buckets:

* ``fake_quant`` — the legacy ``"jax"`` writer: weights fake-quantized to
  float copies at build time, a plain f32 ``@``/``conv`` per actor and a
  separate round/clip activation-quant op per FIFO;
* ``packed``     — the ``"qjax"`` writer at D16: int8 master codes streamed
  through the dequant-fused qmatmul kernels (compiled Pallas on TPU; off-TPU
  the jnp ref fallback, where XLA folds the constant dequant), with
  bias/ReLU and the activation quant fused into the kernel epilogue;
* ``int8_act``   — the ``"qjax"`` writer at D8: the fully-integer hot path.
  Calibrated per-FIFO activation-code scales, int8 codes flowing between
  layers (int32 MACs; on CPU the exact-in-f32 integer dot), and at W4/W2
  sub-byte packed weight buffers unpacked in-VMEM.

Each topology also reports the *resident streamed weight bytes* per working
point (``PackedWeights.view_bytes``): W4 <= 0.55x and W2 <= 0.30x of W8 is
the packed-storage acceptance band, plus the ``im2col_bytes`` scratch term
(:func:`repro.launch.roofline.im2col_scratch_bytes`): the patch tensor the
im2col conv lowering would materialize at that batch, previously invisible
in every byte model.

Topologies with depthwise nodes (the MobileNet-style ``separable-cnn``) are
additionally timed with the D8 writer forced to ``dw_mode="im2col"`` — the
dense block-diagonal patch lowering kept as the differential reference — so
each row carries ``dw_direct_us`` / ``dw_im2col_us`` / ``dw_speedup``
together with the depthwise slice of the byte model (``dw_im2col_bytes`` vs
``dw_direct_bytes``, the padded activation the direct kernel streams
instead).

Pass/fail criteria (reported, enforced with ``--check``):

* MNIST-CNN @ batch 8 — the packed path must be >= 1.3x faster than
  fake-quant on a compiled backend (parity within 10% on the CPU ref
  fallback), and the int8-act path must be no slower than the f32-act packed
  path within 10% (ratio >= 0.9) on either backend;
* separable-cnn @ batch 8 — the direct depthwise lowering must be >= 1.5x
  faster than im2col+qgemm on a compiled backend (parity within 10% on the
  CPU ref fallback), and the depthwise im2col scratch must exceed the direct
  path's streamed activation bytes by >= 4x (the byte band that makes the
  kill-im2col claim measurable, not asserted).

Emits machine-readable JSON via ``--out`` (default ``BENCH_qpath.json``) so
CI tracks the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.configs.separable_cnn import CONFIG as SEP
from repro.core.flow import DesignFlow
from repro.core.ir import static_elems
from repro.core.reader import cnn_to_ir, mlp_to_ir, separable_cnn_to_ir
from repro.launch.roofline import im2col_scratch_bytes
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig

DT = DatatypeConfig(16, 8)          # the streaming-q working point (f32 act)
DT_INT8 = DatatypeConfig(8, 8)      # the fully-integer working point
MLP_LAYERS = [784, 256, 128, 10]    # HLS4ML-style FC stack (Table I)
CRITERION_TOPOLOGY, CRITERION_BATCH = "mnist-cnn", 8
DW_CRITERION_TOPOLOGY = "separable-cnn"
DW_OPS = ("DepthwiseConv", "FusedDepthwiseConv")


def _time_many(fns, x, iters: int = 15) -> List[float]:
    """Interleaved min-of-N across all paths: alternating the measurements
    cancels slow machine drift that back-to-back loops fold into whichever
    path runs last (which is exactly the 5-10% this benchmark resolves)."""
    for f in fns:
        jax.block_until_ready(f(x))             # compile/trace warm-up
    best = [float("inf")] * len(fns)
    for _ in range(iters):
        for i, f in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _dw_byte_model(graph, batch: int):
    """(total im2col bytes, depthwise im2col bytes, depthwise direct bytes)
    for a pass-compiled graph at int8-code width — the per-row scratch
    accounting the direct kernel eliminates."""
    per_node = im2col_scratch_bytes(graph, batch=batch, act_bytes=1)
    dw_im2col = dw_direct = 0
    for n in graph.topo_order():
        if n.op not in DW_OPS:
            continue
        dw_im2col += per_node[n.name]
        # the direct kernel streams the (unpadded) input activation once
        dw_direct += batch * static_elems(graph.value_info[n.inputs[0]].shape[1:])
    return per_node["_total"], dw_im2col, dw_direct


def _topologies(rng):
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g_cnn = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    h, w = CNN.image_hw
    yield "mnist-cnn", g_cnn, (h, w, CNN.in_channels)

    sep_params = cnn.init_separable_params(SEP, jax.random.PRNGKey(1))
    g_sep = separable_cnn_to_ir(
        SEP, {k: np.asarray(v) for k, v in sep_params.items()})
    sh, sw = SEP.image_hw
    yield "separable-cnn", g_sep, (sh, sw, SEP.in_channels)

    mlp_params = {}
    for i in range(len(MLP_LAYERS) - 1):
        fan_in, fan_out = MLP_LAYERS[i], MLP_LAYERS[i + 1]
        mlp_params[f"fc{i}/w"] = rng.standard_normal(
            (fan_in, fan_out)).astype(np.float32) / np.sqrt(fan_in)
        mlp_params[f"fc{i}/b"] = np.zeros(fan_out, np.float32)
    name = "mlp-" + "-".join(str(s) for s in MLP_LAYERS)
    yield name, mlp_to_ir(MLP_LAYERS, mlp_params), (MLP_LAYERS[0],)


def run(full: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    batches = (1, 8, 32) if full else (8,)
    rows = []
    for name, graph, item_shape in _topologies(rng):
        calib = rng.random((2, *item_shape), np.float32)
        res = DesignFlow(graph).run(targets=("jax", "qjax"), dtconfig=DT,
                                    calib_inputs=(calib,))
        res8 = DesignFlow(graph).run(targets=("qjax",), dtconfig=DT_INT8,
                                     calib_inputs=(calib,))
        fq, pk = res.batched["jax"], res.batched["qjax"]
        i8 = res8.batched["qjax"]
        qw, qw8 = res.writers["qjax"], res8.writers["qjax"]
        qpath = qw.qpath
        assert qw8.int8_act_on, "D8 point must enable the integer hot path"
        storage = {f"w{b}_bytes": qw.packed.view_bytes(b) for b in (8, 4, 2)}
        has_dw = any(n.op in DW_OPS for n in res8.graph.nodes)
        fns = [fq, pk, i8]
        if has_dw:
            # same D8 integer graph, depthwise forced through the dense
            # block-diagonal im2col+qgemm lowering (differential reference)
            res8_im = DesignFlow(graph).run(
                targets=("qjax",), dtconfig=DT_INT8, calib_inputs=(calib,),
                writer_kwargs={"qjax": {"dw_mode": "im2col"}})
            fns.append(res8_im.batched["qjax"])
        for b in batches:
            x = rng.random((b, *item_shape), np.float32)
            times = _time_many(tuple(fns), x)
            t_fq, t_pk, t_i8 = times[:3]
            total_im2col, dw_im2col, dw_direct = _dw_byte_model(res8.graph, b)
            row = {
                "topology": name, "batch": b, "qpath": qpath,
                "fake_quant_us": round(t_fq * 1e6, 1),
                "packed_us": round(t_pk * 1e6, 1),
                "int8act_us": round(t_i8 * 1e6, 1),
                "speedup": round(t_fq / max(t_pk, 1e-12), 3),
                "int8act_vs_packed": round(t_pk / max(t_i8, 1e-12), 3),
                "im2col_bytes": total_im2col,
                **storage,
            }
            if has_dw:
                t_im = times[3]
                row.update({
                    "dw_direct_us": round(t_i8 * 1e6, 1),
                    "dw_im2col_us": round(t_im * 1e6, 1),
                    "dw_speedup": round(t_im / max(t_i8, 1e-12), 3),
                    "dw_im2col_bytes": dw_im2col,
                    "dw_direct_bytes": dw_direct,
                })
            rows.append(row)
    return rows


def evaluate(rows: List[Dict]) -> Dict:
    """The acceptance criteria: MNIST-CNN @ batch 8 (packed/int8-act paths)
    plus separable-cnn @ batch 8 (direct depthwise vs im2col, byte band)."""
    row = next((r for r in rows if r["topology"] == CRITERION_TOPOLOGY
                and r["batch"] == CRITERION_BATCH), None)
    if row is None:
        return {"pass": False, "reason": "criterion row missing"}
    target = 1.3 if row["qpath"] == "pallas" else 0.9
    packed_ok = row["speedup"] >= target
    int8_ok = row["int8act_vs_packed"] >= 0.9
    bytes_ok = (row["w4_bytes"] <= 0.55 * row["w8_bytes"]
                and row["w2_bytes"] <= 0.30 * row["w8_bytes"])
    dw_row = next((r for r in rows if r["topology"] == DW_CRITERION_TOPOLOGY
                   and r["batch"] == CRITERION_BATCH), None)
    if dw_row is None or "dw_speedup" not in dw_row:
        return {"pass": False, "reason": "depthwise criterion row missing"}
    dw_target = 1.5 if dw_row["qpath"] == "pallas" else 0.9
    dw_ok = dw_row["dw_speedup"] >= dw_target
    # the im2col scratch the direct kernel kills must be a real byte cliff,
    # not a rounding artifact: >= 4x the activation bytes the kernel streams
    dw_bytes_ok = dw_row["dw_im2col_bytes"] >= 4 * dw_row["dw_direct_bytes"]
    return {"pass": (packed_ok and int8_ok and bytes_ok
                     and dw_ok and dw_bytes_ok),
            "target_speedup": target, "achieved_speedup": row["speedup"],
            "int8act_vs_packed": row["int8act_vs_packed"],
            "int8act_target": 0.9, "packed_bytes_ok": bytes_ok,
            "dw_target_speedup": dw_target,
            "dw_achieved_speedup": dw_row["dw_speedup"],
            "dw_bytes_ok": dw_bytes_ok,
            "qpath": row["qpath"], "topology": CRITERION_TOPOLOGY,
            "dw_topology": DW_CRITERION_TOPOLOGY,
            "batch": CRITERION_BATCH}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch-8 bucket only (CI smoke)")
    ap.add_argument("--out", default="BENCH_qpath.json",
                    help="machine-readable JSON output path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the speedup criterion fails")
    args = ap.parse_args()
    rows = run(full=not args.quick)
    for r in rows:
        print("qpath_latency," + ",".join(f"{k}={v}" for k, v in r.items()))
    crit = evaluate(rows)
    print("qpath_latency,mode=criterion,"
          + ",".join(f"{k}={v}" for k, v in crit.items()))
    doc = {"backend": jax.default_backend(),
           "datatype": {"packed": DT.name, "int8_act": DT_INT8.name},
           "quick": args.quick, "rows": rows, "criterion": crit}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {args.out}")
    if args.check and not crit["pass"]:
        raise SystemExit(f"qpath criterion failed: {crit}")


if __name__ == "__main__":
    main()
