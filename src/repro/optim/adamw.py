"""AdamW with cosine schedule, global-norm clipping and f32 moments.

Flat-dict pytrees throughout (matches repro.models.params).  Moments are
sharded ZeRO-1 style by the runtime (sharding.opt_state_spec)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: Dict[str, jax.Array]
    nu: Dict[str, jax.Array]
    count: jax.Array


def init_opt_state(params: Dict[str, jax.Array]) -> OptState:
    zeros = {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
    return OptState(mu=zeros,
                    nu={k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()},
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree: Dict[str, jax.Array]):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in tree.values()))


_NO_DECAY = ("norm/w", "norm_w", "/b", "bias", "A_log", "dt_bias", "/D",
             "bq", "bk", "bv", "b_up", "b_down")


def apply_updates(params: Dict[str, jax.Array], grads: Dict[str, jax.Array],
                  state: OptState, cfg: OptConfig
                  ) -> Tuple[Dict[str, jax.Array], OptState, Dict[str, jax.Array]]:
    count = state.count + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-6))
    lr = schedule(cfg, count)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    new_p, new_mu, new_nu = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * scale
        mu = b1 * state.mu[k] + (1 - b1) * g
        nu = b2 * state.nu[k] + (1 - b2) * g * g
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if cfg.weight_decay > 0 and not any(k.endswith(s) for s in _NO_DECAY):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_mu[k], new_nu[k] = mu, nu
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, OptState(new_mu, new_nu, count), metrics
