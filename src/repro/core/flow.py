"""End-to-end DesignFlow driver — the paper's Fig. 1, fully automated.

ONNX-like model  ->  Reader (IR)  ->  per-target Writer  ->  [PTQ exploration]
->  Multi-Dataflow compose  ->  deployable accelerator + reports.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.ir import Graph
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.stream_writer import StreamWriter
from repro.core.writers.dist_writer import DistWriter
from repro.core.adaptive import AdaptiveAccelerator, WorkingPoint
from repro.quant.qtypes import DatatypeConfig
from repro.quant.fixedpoint import zero_fraction
from repro.quant.ptq import weight_qtype

WRITERS = {"jax": JaxWriter, "stream": StreamWriter, "dist": DistWriter}


@dataclass
class FlowResult:
    graph: Graph
    writers: Dict[str, JaxWriter]
    executables: Dict[str, Callable]
    act_ranges: Dict[str, float]
    stats: Dict[str, float] = field(default_factory=dict)


class DesignFlow:
    """``DesignFlow(graph).run(targets, dtconfig, calib)`` — Fig. 1 automated."""

    def __init__(self, graph: Graph):
        graph.validate()
        self.graph = graph

    def calibrate(self, *calib_inputs) -> Dict[str, float]:
        """Run the float reference once, record per-FIFO activation ranges."""
        w = JaxWriter(self.graph)
        _, env = w.build(capture=True)(*calib_inputs)
        return {k: float(jnp.max(jnp.abs(v)))
                for k, v in env.items()
                if hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.floating)}

    def run(self, targets: Sequence[str] = ("jax",),
            dtconfig: Optional[DatatypeConfig] = None,
            calib_inputs: Optional[tuple] = None) -> FlowResult:
        act_ranges: Dict[str, float] = {}
        if calib_inputs is not None and dtconfig and dtconfig.act_bits < 32:
            act_ranges = self.calibrate(*calib_inputs)
        writers, exes = {}, {}
        for t in targets:
            w = WRITERS[t](self.graph, dtconfig, act_ranges)
            writers[t] = w
            exes[t] = w.build()
        stats = {}
        if dtconfig and dtconfig.weight_bits < 32:
            zeros, total = 0.0, 0
            for name, arr in self.graph.initializers.items():
                if arr.ndim >= 2:
                    qt = weight_qtype(jnp.asarray(arr), dtconfig.weight_bits)
                    zeros += float(zero_fraction(jnp.asarray(arr), qt)) * arr.size
                    total += arr.size
            stats["zero_weight_frac"] = zeros / max(total, 1)
        return FlowResult(self.graph, writers, exes, act_ranges, stats)

    def compose_adaptive(self, points: Sequence[WorkingPoint],
                         target: str = "stream") -> AdaptiveAccelerator:
        """Merge working points over one shared-weight substrate (MDC step)."""
        base = WRITERS[target](self.graph)

        def apply_fn(params, *inputs):
            g = Graph(self.graph.name, self.graph.nodes, self.graph.inputs,
                      self.graph.outputs, params)
            return WRITERS[target](g).build()(*inputs)

        return AdaptiveAccelerator(apply_fn, dict(base.weights), points)
