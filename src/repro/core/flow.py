"""End-to-end DesignFlow driver — the paper's Fig. 1, fully automated.

ONNX-like model  ->  Reader (IR)  ->  compiler passes (fusion, constant
folding, DCE, shape inference, per-layer precision)  ->  per-target Writer
->  [PTQ / mixed-precision exploration]  ->  Multi-Dataflow compose  ->
deployable accelerator + reports.

``run`` applies the default pass pipeline before handing the graph to the
writers; ``run(passes=())`` skips all rewrites (raw node-by-node
interpretation, the pre-refactor behaviour), and ``run(passes=[...])``
substitutes a custom pipeline.  Graphs read with a symbolic batch dim
compile to batch-polymorphic artifacts: ``FlowResult.batched[target]``
serves any leading-dim size from one compiled graph (LRU of traced
shapes), and ``fifo_slack`` scales the value_info-derived FIFO depths the
stream writer stamps on its topology.  ``dtconfig`` accepts either a uniform
:class:`~repro.quant.qtypes.DatatypeConfig` or a heterogeneous
:class:`~repro.quant.qtypes.PrecisionMap`; ``explore_mixed_precision``
searches for the latter greedily against the float reference.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field, fields
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp

from repro.core.ir import Graph
from repro.core.passes import (PassManager, default_pipeline,
                               explore_mixed_precision, strip_precision,
                               structural_pipeline)
from repro.core.writers.jax_writer import BatchedExecutable, JaxWriter
from repro.core.writers.stream_writer import StreamWriter
from repro.core.writers.dist_writer import DistWriter
from repro.core.writers.qjax_writer import QJaxWriter
from repro.core.adaptive import (AdaptiveAccelerator, PointSelector,
                                 RuntimePolicy, WorkingPoint,
                                 shared_point_executables)
from repro.quant.qtypes import DatatypeConfig, PrecisionMap
from repro.quant.ptq import graph_weight_stats

WRITERS = {"jax": JaxWriter, "stream": StreamWriter, "dist": DistWriter,
           "qjax": QJaxWriter}

# default adaptive ladder: the paper's W8/W4/W2 nested working points
DEFAULT_POINTS = (WorkingPoint("w8", 8), WorkingPoint("w4", 4),
                  WorkingPoint("w2", 2))

Precision = Union[DatatypeConfig, PrecisionMap]


@dataclass(frozen=True)
class WriterOptions:
    """Typed writer configuration — the one validated surface replacing the
    per-writer kwarg sprawl that used to thread through ``writer_kwargs=``
    dicts.  Every field is optional; a set field is forwarded to each target
    writer *that accepts it* (``fifo_slack`` to the stream writer,
    ``default_bits``/``use_kernel``/... to the qjax writer), so one options
    object configures a multi-target run.  ``DesignFlow.run`` validates the
    merged per-writer kwargs once, with unknown-key errors naming the
    writer."""

    fifo_slack: Optional[float] = None      # stream: FIFO depth headroom
    default_bits: Optional[int] = None      # qjax: build(bits=None) point
    use_kernel: Optional[bool] = None       # qjax: force/forbid Pallas path
    interpret: Optional[bool] = None        # qjax: Pallas interpret override
    int8_act: Optional[bool] = None         # qjax: fully-integer dataflow
    packed_weights: Optional[bool] = None   # qjax: sub-byte HBM residency
    dw_mode: Optional[str] = None           # qjax: "direct" | "im2col"

    def __post_init__(self):
        if self.dw_mode is not None and self.dw_mode not in ("direct",
                                                             "im2col"):
            raise ValueError(f"dw_mode must be 'direct' or 'im2col', "
                             f"got {self.dw_mode!r}")
        if self.fifo_slack is not None and self.fifo_slack <= 0:
            raise ValueError(f"fifo_slack must be positive, "
                             f"got {self.fifo_slack}")

    def set_fields(self) -> Dict[str, Any]:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if getattr(self, f.name) is not None}


def _writer_params(cls) -> set:
    """Optional constructor keywords a writer class accepts (everything past
    the positional graph/dtconfig/act_ranges triple)."""
    sig = inspect.signature(cls.__init__)
    return {name for name in sig.parameters
            if name not in ("self", "graph", "dtconfig", "act_ranges")}


@dataclass
class FlowResult:
    graph: Graph                      # the pass-transformed graph
    writers: Dict[str, JaxWriter]
    executables: Dict[str, Callable]  # raw interpreters (shape-polymorphic)
    act_ranges: Dict[str, float]
    stats: Dict[str, float] = field(default_factory=dict)
    # per-target batch-polymorphic artifacts: one compiled graph serving any
    # leading-dim size via an LRU of traced shapes
    batched: Dict[str, BatchedExecutable] = field(default_factory=dict)

    def serve(self, target: str = "jax", **kwargs):
        """A batch-coalescing :class:`~repro.runtime.serve.AccelServer` over
        this result's batched artifact for ``target`` — requests of varying
        sizes are queued, packed to buckets aligned with the artifact's LRU,
        executed once per batch and demuxed.  Keyword arguments (``max_batch``,
        ``max_wait``, ``buckets``, ``policy``, ``point_executables``, ...)
        pass through to the server."""
        from repro.runtime.serve import AccelServer   # lazy: runtime is heavy
        if target not in self.batched:
            raise KeyError(f"no batched artifact for target {target!r}; "
                           f"have {tuple(self.batched)}")
        # the graph knows its true input spec — lock request coalescing to it
        # rather than to whatever the first submitted request looks like
        kwargs.setdefault("signature", tuple(
            (tuple(int(d) for d in t.shape[1:]), str(t.dtype))
            for t in self.graph.inputs))
        return AccelServer(self.batched[target], **kwargs)

    def serve_adaptive(self, points=DEFAULT_POINTS,
                       target: str = "qjax",
                       policy: Optional[PointSelector] = None,
                       batch_cache: int = 8,
                       selector: Optional[PointSelector] = None, **kwargs):
        """An :class:`~repro.runtime.serve.AccelServer` whose per-batch
        precision working points ALL read one shared
        :class:`~repro.quant.pack.PackedWeights` buffer — switching is a
        static kernel-arg change: no re-build, no weight copy (requires the
        packed-weight ``"qjax"`` target in this result).

        ``points`` is a sequence of
        :class:`~repro.core.adaptive.WorkingPoint` or a
        :class:`~repro.dse.ParetoFront` (the explorer's output — the server
        then walks the computed front instead of the hardcoded ladder).  The
        working point per batch comes from ``selector`` (any
        :class:`~repro.core.adaptive.PointSelector`) or the legacy
        ``policy``; with neither, an open-loop
        :class:`~repro.core.adaptive.RuntimePolicy` over ``points`` is
        built."""
        from repro.dse.pareto import ParetoFront   # lazy: optional consumer
        if isinstance(points, ParetoFront):
            points = points.working_points()
        writer = self.writers.get(target)
        if writer is None or not hasattr(writer, "packed"):
            raise KeyError(
                f"serve_adaptive needs a packed-weight writer (target "
                f"'qjax'); this result has {tuple(self.writers)}")
        pts = shared_point_executables(writer, points,
                                       max_entries=batch_cache)
        if selector is not None:
            return self.serve(target, selector=selector,
                              point_executables=pts, **kwargs)
        return self.serve(target, policy=policy or RuntimePolicy(list(points)),
                          point_executables=pts, **kwargs)


def _split_precision(dtconfig: Optional[Precision]
                     ) -> Tuple[Optional[DatatypeConfig], int, int]:
    """(writer default config, min act bits, min weight bits)."""
    if dtconfig is None:
        return None, 32, 32
    if isinstance(dtconfig, PrecisionMap):
        return dtconfig.default, dtconfig.min_act_bits, dtconfig.min_weight_bits
    return dtconfig, dtconfig.act_bits, dtconfig.weight_bits


class DesignFlow:
    """``DesignFlow(graph).run(targets, dtconfig, calib)`` — Fig. 1 automated."""

    def __init__(self, graph: Graph,
                 passes: Optional[Sequence[Callable]] = None):
        graph.validate()
        self.graph = graph
        self.passes = passes          # None => default pipeline per run()

    # -- compiler ------------------------------------------------------------
    def transform(self, dtconfig: Optional[Precision] = None,
                  passes: Optional[Sequence[Callable]] = None) -> Graph:
        """Apply the pass pipeline; ``passes=()`` returns the raw graph."""
        if passes is None:
            passes = self.passes
        if passes is None:
            passes = default_pipeline(dtconfig)
        if not passes:
            return self.graph
        return PassManager(passes).run(self.graph)

    def calibrate(self, *calib_inputs, graph: Optional[Graph] = None
                  ) -> Dict[str, float]:
        """Run the float reference once, record per-FIFO activation ranges.

        The ranges feed every quantizing writer: the f32 fake-quant path
        derives each FIFO's Qm.n split from them, and the fully-integer
        ``qjax`` path turns them into per-FIFO int8 activation-*code* scales
        (:func:`repro.quant.ptq.act_code_qtype`) that the kernels fold into
        their per-channel weight scales — calibration is what lets codes,
        not floats, flow between layers."""
        w = JaxWriter(graph if graph is not None else self.graph)
        _, env = w.build(capture=True)(*calib_inputs)
        return {k: float(jnp.max(jnp.abs(v)))
                for k, v in env.items()
                if hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.floating)}

    def run(self, targets: Sequence[str] = ("jax",),
            dtconfig: Optional[Precision] = None,
            calib_inputs: Optional[tuple] = None,
            passes: Optional[Sequence[Callable]] = None,
            fifo_slack: float = 1.0,
            batch_cache: int = 8,
            writer_kwargs: Optional[Dict[str, Dict]] = None,
            options: Optional[WriterOptions] = None) -> FlowResult:
        """Compile the graph for ``targets``.

        ``fifo_slack`` scales every FIFO depth the stream writer derives from
        ``value_info`` (rate-mismatch headroom); ``batch_cache`` bounds the
        per-target LRU of traced batch shapes in ``FlowResult.batched``;
        ``options`` is the typed writer configuration
        (:class:`WriterOptions` — each set field reaches every target writer
        that accepts it); ``writer_kwargs`` is the legacy per-target kwarg
        escape hatch (it wins over ``options`` where both set a key;
        ``fifo_slack`` is sugar for ``{"stream": {"fifo_slack": ...}}``).
        The merged per-writer kwargs are validated here: an unknown key
        raises a :class:`ValueError` naming the writer instead of a bare
        ``TypeError`` deep in its constructor.
        """
        for t in targets:
            if t not in WRITERS:
                raise KeyError(f"unknown target {t!r}; have {tuple(WRITERS)}")
        default_dt, min_act, min_wt = _split_precision(dtconfig)
        g = self.transform(dtconfig, passes)
        act_ranges: Dict[str, float] = {}
        if calib_inputs is not None and min_act < 32:
            # calibrate on the *float* view of the compiled graph — with the
            # precision annotations stripped — so recorded ranges are true
            # activation ranges, not values already clipped by quantization
            act_ranges = self.calibrate(*calib_inputs,
                                        graph=strip_precision(g))
        stray = sorted(set(writer_kwargs or {}) - set(targets))
        if stray:
            raise KeyError(f"writer_kwargs for {stray} not in targets "
                           f"{tuple(targets)}")
        wkw = {t: dict((writer_kwargs or {}).get(t, {})) for t in targets}
        opt_fields = options.set_fields() if options is not None else {}
        for t in targets:
            accepted = _writer_params(WRITERS[t])
            for k, v in opt_fields.items():
                if k in accepted:
                    wkw[t].setdefault(k, v)
        if "stream" in wkw:
            wkw["stream"].setdefault("fifo_slack", fifo_slack)
        for t in targets:
            unknown = sorted(set(wkw[t]) - _writer_params(WRITERS[t]))
            if unknown:
                accepted = sorted(_writer_params(WRITERS[t]))
                raise ValueError(
                    f"unknown option(s) {unknown} for writer {t!r} "
                    f"({WRITERS[t].__name__}); it accepts "
                    f"{accepted if accepted else 'no options'}")
        writers, exes, batched = {}, {}, {}
        for t in targets:
            w = WRITERS[t](g, default_dt, act_ranges, **wkw[t])
            writers[t] = w
            exes[t] = w.build()
            batched[t] = w.build_batched(max_entries=batch_cache)
        stats = {}
        if dtconfig is not None and min_wt < 32:
            stats = graph_weight_stats(g, default_dt)
        return FlowResult(g, writers, exes, act_ranges, stats, batched)

    # -- design-space exploration -------------------------------------------
    def explore(self, calib_inputs: tuple, *, budget=None, **kwargs):
        """Resource-constrained design-space exploration: screen candidate
        working points analytically against ``budget`` (a
        :class:`~repro.dse.ResourceBudget`), validate survivors on the
        calibration batch, and return the pruned
        :class:`~repro.dse.ParetoFront`.

        The front plugs straight back into the flow::

            front = DesignFlow(graph).explore(calib, budget=budget)
            result = DesignFlow(graph).run(("qjax",), calib_inputs=calib,
                                           **front.run_kwargs())
            srv = result.serve_adaptive(points=front,
                                        selector=front.selector(slo))

        Extra keyword arguments reach
        :class:`~repro.dse.DesignSpaceExplorer` (``ladder``,
        ``act_bits_choices``, ``fifo_slack_choices``, ``per_layer``, ...).
        Raises :class:`~repro.dse.BudgetInfeasibleError` when nothing
        fits."""
        from repro.dse import DesignSpaceExplorer   # lazy: keeps flow light
        return DesignSpaceExplorer(self.graph, calib_inputs, budget=budget,
                                   **kwargs).explore()

    # -- mixed-precision exploration ----------------------------------------
    def explore_mixed_precision(self, calib_inputs: tuple, **kwargs
                                ) -> Tuple[PrecisionMap, List[Dict]]:
        """Greedy per-layer weight-precision search against the float
        reference (see :func:`repro.core.passes.explore_mixed_precision`).
        The returned PrecisionMap feeds straight back into ``run``."""
        g = PassManager(structural_pipeline()).run(self.graph)
        return explore_mixed_precision(g, calib_inputs, **kwargs)

    # -- adaptive / MDC -----------------------------------------------------
    def compose_adaptive(self, points: Sequence[WorkingPoint],
                         target: str = "stream") -> AdaptiveAccelerator:
        """Merge working points over one shared-weight substrate (MDC step)."""
        base = WRITERS[target](self.graph)

        def apply_fn(params, *inputs):
            g = Graph(self.graph.name, self.graph.nodes, self.graph.inputs,
                      self.graph.outputs, params)
            return WRITERS[target](g).build()(*inputs)

        return AdaptiveAccelerator(apply_fn, dict(base.weights), points)
