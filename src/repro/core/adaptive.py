"""Multi-Dataflow Composer analogue: runtime-adaptive multi-precision accelerators.

The paper's MDC merges several dataflow configurations into one reconfigurable
accelerator whose actors/weights are shared between configurations, switched
at runtime (e.g. drop precision when the energy budget is low).  TPU-native
realization (DESIGN.md §2):

* The *shared substrate* is one int8 master weight buffer + per-channel scales
  (``quant.ptq.quantize_tree_native``).  Lower-precision working points are
  *derived views* (nested truncation) of the master — zero extra parameter
  memory per configuration, which is exactly the weight sharing the paper
  targets for its future reconfigurable substrate.
* ``switch_mode="static"``  -> one compiled executable per working point,
  selected on the host (reconfiguration = picking a compiled function; no
  weight reload — analogous to CG reconfiguration latency).
* ``switch_mode="dynamic"`` -> a single executable with ``lax.switch`` over
  the working points (reconfiguration = a traced integer; one HLO).
* ``sharing_report()`` quantifies merged-vs-separate resources (the MDC
  LUT-sharing story, in bytes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.quant.ptq import (QuantizedParams, dequantize_tree,
                             quant_memory_bytes, quantize_tree_native)


@dataclass(frozen=True)
class WorkingPoint:
    """One merged configuration (a Pareto point from the exploration)."""
    name: str
    weight_bits: int            # 8 / 4 / 2 (derived views of the master)
    act_dtype: str = "bfloat16"  # activation stream dtype


class AdaptiveAccelerator:
    """The merged multi-dataflow executable."""

    def __init__(self, apply_fn: Callable, params: Dict[str, jax.Array],
                 points: Sequence[WorkingPoint], quant_embeddings: bool = False):
        """apply_fn(params, *inputs) -> outputs; params: full-precision tree."""
        self.points = list(points)
        self.apply_fn = apply_fn
        self.qparams: QuantizedParams = quantize_tree_native(
            params, quant_embeddings=quant_embeddings)
        self._compiled: Dict[str, Callable] = {}

    # -- static switching ---------------------------------------------------
    def executable(self, point: WorkingPoint) -> Callable:
        if point.name not in self._compiled:
            bits = point.weight_bits
            dt = jnp.dtype(point.act_dtype)

            def run(qtree, *inputs, _bits=bits, _dt=dt):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, _dt)
                cast = tuple(x.astype(_dt) if jnp.issubdtype(x.dtype, jnp.floating)
                             else x for x in inputs)
                return self.apply_fn(params, *cast)

            self._compiled[point.name] = jax.jit(run)
        return self._compiled[point.name]

    def __call__(self, point_name: str, *inputs):
        pt = next(p for p in self.points if p.name == point_name)
        return self.executable(pt)(self.qparams.tree(), *inputs)

    # -- dynamic switching (one HLO, traced config id) -----------------------
    def build_dynamic(self) -> Callable:
        branches = []
        for pt in self.points:
            bits, dt = pt.weight_bits, jnp.dtype(pt.act_dtype)

            def branch(qtree, inputs, _bits=bits, _dt=dt):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, _dt)
                cast = tuple(x.astype(_dt) if jnp.issubdtype(x.dtype, jnp.floating)
                             else x for x in inputs)
                out = self.apply_fn(params, *cast)
                return jax.tree.map(lambda o: o.astype(jnp.float32), out)

            branches.append(branch)

        @jax.jit
        def run(config_id, qtree, *inputs):
            return jax.lax.switch(config_id, branches, qtree, inputs)

        return run

    # -- resource sharing report (MDC merge accounting) ----------------------
    def sharing_report(self) -> Dict[str, float]:
        merged = quant_memory_bytes(self.qparams, 8, packed=True)
        separate = sum(quant_memory_bytes(self.qparams, p.weight_bits, packed=True)
                       for p in self.points)
        return {
            "n_configs": len(self.points),
            "merged_weight_bytes": merged,
            "separate_weight_bytes": separate,
            "sharing_ratio": separate / max(merged, 1),
            "extra_bytes_per_config": 0.0,  # derived views: no extra storage
        }


def shared_point_executables(writer, points: Sequence[WorkingPoint], *,
                             max_entries: int = 8,
                             on_compile=None) -> Dict[str, Callable]:
    """One batch-polymorphic executable per working point, ALL reading the
    writer's single :class:`~repro.quant.pack.PackedWeights` buffer.

    This is the MDC merge realized for the graph accelerators: the writer
    (a :class:`~repro.core.writers.qjax_writer.QJaxWriter`) quantized its
    weights once to int8 master codes, and each point executable differs only
    in the static ``bits`` kernel argument — switching W8 -> W4 -> W2 in
    ``AccelServer``/``RuntimePolicy`` re-builds nothing and copies no weights,
    so N points hold ~1/N of the per-point-copies weight memory.  Feed the
    result to ``AccelServer(point_executables=...)`` (or use
    ``FlowResult.serve_adaptive``)."""
    if not hasattr(writer, "packed"):
        raise TypeError(
            f"writer target {getattr(writer, 'target', '?')!r} does not hold "
            "packed weights; shared point executables need the 'qjax' writer")
    return {p.name: writer.build_batched(max_entries=max_entries,
                                         on_compile=on_compile,
                                         bits=p.weight_bits)
            for p in points}


@dataclass
class RuntimePolicy:
    """CPS-style runtime manager: pick the working point from the budget.

    Mirrors the paper's scenario — "when a limited energy budget is left a
    reduction in energy consumption is worth the cost of some accuracy loss".
    """
    points: List[WorkingPoint]
    thresholds: List[float] = field(default_factory=list)  # descending budgets

    def select(self, energy_budget_frac: float) -> WorkingPoint:
        ths = self.thresholds or [1.0 - (i + 1) / len(self.points)
                                  for i in range(len(self.points) - 1)]
        for pt, th in zip(self.points[:-1], ths):
            if energy_budget_frac > th:
                return pt
        return self.points[-1]
