"""Multi-Dataflow Composer analogue: runtime-adaptive multi-precision accelerators.

The paper's MDC merges several dataflow configurations into one reconfigurable
accelerator whose actors/weights are shared between configurations, switched
at runtime (e.g. drop precision when the energy budget is low).  TPU-native
realization (DESIGN.md §2):

* The *shared substrate* is one int8 master weight buffer + per-channel scales
  (``quant.ptq.quantize_tree_native``).  Lower-precision working points are
  *derived views* (nested truncation) of the master — zero extra parameter
  memory per configuration, which is exactly the weight sharing the paper
  targets for its future reconfigurable substrate.
* ``switch_mode="static"``  -> one compiled executable per working point,
  selected on the host (reconfiguration = picking a compiled function; no
  weight reload — analogous to CG reconfiguration latency).
* ``switch_mode="dynamic"`` -> a single executable with ``lax.switch`` over
  the working points (reconfiguration = a traced integer; one HLO).
* ``sharing_report()`` quantifies merged-vs-separate resources (the MDC
  LUT-sharing story, in bytes).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import jax
import jax.numpy as jnp

from repro.quant.ptq import (QuantizedParams, dequantize_tree,
                             quant_memory_bytes, quantize_tree_native)


@dataclass(frozen=True)
class WorkingPoint:
    """One merged configuration (a Pareto point from the exploration)."""
    name: str
    weight_bits: int            # 8 / 4 / 2 (derived views of the master)
    act_dtype: str = "bfloat16"  # activation stream dtype
    act_bits: Optional[int] = None  # activation code bits (DSE-emitted points)


class AdaptiveAccelerator:
    """The merged multi-dataflow executable."""

    def __init__(self, apply_fn: Callable, params: Dict[str, jax.Array],
                 points: Sequence[WorkingPoint], quant_embeddings: bool = False):
        """apply_fn(params, *inputs) -> outputs; params: full-precision tree."""
        self.points = list(points)
        self.apply_fn = apply_fn
        self.qparams: QuantizedParams = quantize_tree_native(
            params, quant_embeddings=quant_embeddings)
        self._compiled: Dict[str, Callable] = {}

    # -- static switching ---------------------------------------------------
    def executable(self, point: WorkingPoint) -> Callable:
        if point.name not in self._compiled:
            bits = point.weight_bits
            dt = jnp.dtype(point.act_dtype)

            def run(qtree, *inputs, _bits=bits, _dt=dt):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, _dt)
                cast = tuple(x.astype(_dt) if jnp.issubdtype(x.dtype, jnp.floating)
                             else x for x in inputs)
                return self.apply_fn(params, *cast)

            self._compiled[point.name] = jax.jit(run)
        return self._compiled[point.name]

    def __call__(self, point_name: str, *inputs):
        pt = next(p for p in self.points if p.name == point_name)
        return self.executable(pt)(self.qparams.tree(), *inputs)

    # -- dynamic switching (one HLO, traced config id) -----------------------
    def build_dynamic(self) -> Callable:
        branches = []
        for pt in self.points:
            bits, dt = pt.weight_bits, jnp.dtype(pt.act_dtype)

            def branch(qtree, inputs, _bits=bits, _dt=dt):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, _dt)
                cast = tuple(x.astype(_dt) if jnp.issubdtype(x.dtype, jnp.floating)
                             else x for x in inputs)
                out = self.apply_fn(params, *cast)
                return jax.tree.map(lambda o: o.astype(jnp.float32), out)

            branches.append(branch)

        @jax.jit
        def run(config_id, qtree, *inputs):
            return jax.lax.switch(config_id, branches, qtree, inputs)

        return run

    # -- resource sharing report (MDC merge accounting) ----------------------
    def sharing_report(self) -> Dict[str, float]:
        merged = quant_memory_bytes(self.qparams, 8, packed=True)
        separate = sum(quant_memory_bytes(self.qparams, p.weight_bits, packed=True)
                       for p in self.points)
        return {
            "n_configs": len(self.points),
            "merged_weight_bytes": merged,
            "separate_weight_bytes": separate,
            "sharing_ratio": separate / max(merged, 1),
            "extra_bytes_per_config": 0.0,  # derived views: no extra storage
        }


def shared_point_executables(writer, points: Sequence[WorkingPoint], *,
                             max_entries: int = 8,
                             on_compile=None) -> Dict[str, Callable]:
    """One batch-polymorphic executable per working point, ALL reading the
    writer's single :class:`~repro.quant.pack.PackedWeights` buffer.

    This is the MDC merge realized for the graph accelerators: the writer
    (a :class:`~repro.core.writers.qjax_writer.QJaxWriter`) quantized its
    weights once to int8 master codes, and each point executable differs only
    in the static ``bits`` kernel argument — switching W8 -> W4 -> W2 in
    ``AccelServer``/``RuntimePolicy`` re-builds nothing and copies no weights,
    so N points hold ~1/N of the per-point-copies weight memory.  Feed the
    result to ``AccelServer(point_executables=...)`` (or use
    ``FlowResult.serve_adaptive``)."""
    if not hasattr(writer, "packed"):
        raise TypeError(
            f"writer target {getattr(writer, 'target', '?')!r} does not hold "
            "packed weights; shared point executables need the 'qjax' writer")
    return {p.name: writer.build_batched(max_entries=max_entries,
                                         on_compile=on_compile,
                                         bits=p.weight_bits)
            for p in points}


# ---------------------------------------------------------------------------
# Point selection: ONE protocol for every runtime point-selection surface
# ---------------------------------------------------------------------------

@runtime_checkable
class PointSelector(Protocol):
    """The unified point-selection surface.

    Historically three competing surfaces picked the working point: the
    open-loop ``RuntimePolicy.select(energy_budget_frac)`` heuristic, the
    closed-loop ``SLOController.select()``, and per-call ``bits=`` kwargs on
    the writers.  They now meet in one protocol that
    :class:`~repro.runtime.serve.AccelServer` tenants consume directly
    (``selector=``):

    * ``points`` — the ladder, highest precision first (what an SLO walks);
    * ``select(budget)`` — the working point for the next batch.  Open-loop
      selectors read the batch's energy budget; closed-loop selectors ignore
      it (their signal is :meth:`observe`);
    * ``observe(latency_s)`` — feedback from every completed request.
      Open-loop selectors may no-op.

    Implementations: :class:`BudgetSelector` (open-loop energy heuristic),
    :class:`SLOController` (closed-loop p95 ladder walk),
    :class:`FixedSelector` (pin one point — the per-call ``bits=`` pattern).
    The legacy :class:`RuntimePolicy` entry point survives as a thin
    deprecation shim over :class:`BudgetSelector`.
    """

    points: Sequence[WorkingPoint]

    def select(self, budget: float = 1.0) -> WorkingPoint: ...

    def observe(self, latency_s: float) -> None: ...


@dataclass
class BudgetSelector:
    """CPS-style open-loop selector: pick the working point from the budget.

    Mirrors the paper's scenario — "when a limited energy budget is left a
    reduction in energy consumption is worth the cost of some accuracy loss".
    """
    points: List[WorkingPoint]
    thresholds: List[float] = field(default_factory=list)  # descending budgets

    def select(self, budget: float = 1.0) -> WorkingPoint:
        ths = self.thresholds or [1.0 - (i + 1) / len(self.points)
                                  for i in range(len(self.points) - 1)]
        for pt, th in zip(self.points[:-1], ths):
            if budget > th:
                return pt
        return self.points[-1]

    def observe(self, latency_s: float) -> None:
        """Open-loop: measured latency does not move the choice."""


class RuntimePolicy(BudgetSelector):
    """Deprecated alias of :class:`BudgetSelector`.

    Kept so existing call sites (``RuntimePolicy(points).select(frac)``)
    behave bit-identically; new code should construct a
    :class:`BudgetSelector` (or any other :class:`PointSelector`) and hand it
    to the server as ``selector=``.
    """

    def select(self, energy_budget_frac: float = 1.0) -> WorkingPoint:
        return super().select(energy_budget_frac)


@dataclass
class FixedSelector:
    """Pin one working point — the typed replacement for threading a
    ``bits=`` kwarg through every call: build the point's executable once and
    select it unconditionally."""
    point: WorkingPoint

    @property
    def points(self) -> List[WorkingPoint]:
        return [self.point]

    def select(self, budget: float = 1.0) -> WorkingPoint:
        return self.point

    def observe(self, latency_s: float) -> None:
        """Nothing to adapt: the point is pinned."""


# ---------------------------------------------------------------------------
# Closed-loop precision control against a latency SLO
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServiceObjective:
    """A tenant's latency contract plus the control-loop tuning knobs.

    ``p95_latency_s`` is the target the controller defends.  ``window`` /
    ``min_samples`` size the observation window a decision needs;
    ``hold`` is the minimum number of observations between two precision
    shifts (hysteresis — it bounds oscillation and upshift-probe rate);
    ``recover_margin`` is the headroom fraction under which the controller
    tries the next-higher-precision point again (p95 below
    ``recover_margin * p95_latency_s`` == "there is headroom").
    """
    p95_latency_s: float
    window: int = 64
    min_samples: int = 8
    hold: int = 16
    recover_margin: float = 0.5

    def __post_init__(self):
        if self.p95_latency_s <= 0:
            raise ValueError("p95_latency_s must be > 0")
        if not 0.0 < self.recover_margin < 1.0:
            raise ValueError("recover_margin must be in (0, 1)")


class SLOController:
    """Feedback controller: measured request latency -> precision ladder.

    The paper's runtime adaptivity story closed with a real signal: instead
    of an open-loop energy-budget heuristic, the serving layer feeds every
    completed request's latency back in, and the controller walks the
    working-point ladder (ordered highest precision first, e.g. W8/W4/W2) —
    *down* a step when the windowed p95 violates the SLO (lower-bit views
    stream fewer weight bytes, so they are the faster/cheaper points), back
    *up* when p95 shows ``recover_margin`` headroom.  Shifting clears the
    window so the next decision is made from observations of the new point
    only, and ``hold`` observations must accumulate before any further
    shift.
    """

    def __init__(self, points: Sequence[WorkingPoint], slo: ServiceObjective):
        if not points:
            raise ValueError("SLOController needs at least one working point")
        self.points = list(points)
        self.slo = slo
        self.idx = 0                      # start at the highest precision
        self.shifts: List[Tuple[str, str]] = []   # (from, to) telemetry
        self._window: Deque[float] = deque(maxlen=slo.window)
        self._since_shift = 0

    def select(self, budget: float = 1.0) -> WorkingPoint:
        """Closed loop: the measured-latency choice; ``budget`` is ignored
        (accepted so the controller satisfies :class:`PointSelector`)."""
        return self.points[self.idx]

    @property
    def p95(self) -> float:
        from repro.runtime.scheduler import percentile
        return percentile(self._window, 0.95)

    def observe(self, latency_s: float) -> None:
        """Feed one completed request's end-to-end latency."""
        self._window.append(latency_s)
        self._since_shift += 1
        if (len(self._window) < self.slo.min_samples
                or self._since_shift < self.slo.hold):
            return
        p95 = self.p95
        if p95 > self.slo.p95_latency_s and self.idx < len(self.points) - 1:
            self._shift(self.idx + 1)
        elif (p95 < self.slo.recover_margin * self.slo.p95_latency_s
                and self.idx > 0):
            self._shift(self.idx - 1)

    def _shift(self, new_idx: int) -> None:
        self.shifts.append((self.points[self.idx].name,
                            self.points[new_idx].name))
        self.idx = new_idx
        self._since_shift = 0
        self._window.clear()

    def telemetry(self) -> Dict:
        return {
            "point": self.points[self.idx].name,
            "p95_slo_s": self.slo.p95_latency_s,
            "window_p95_s": (self.p95 if self._window else None),
            "shifts": list(self.shifts),
        }


# ---------------------------------------------------------------------------
# Fleet-level graceful degradation (precision brownout)
# ---------------------------------------------------------------------------

class BrownoutSelector:
    """Fleet-wide graceful degradation: ONE :class:`PointSelector` shared by
    every replica of a :class:`~repro.runtime.fleet.FleetRouter`.

    Where :class:`SLOController` closes the loop for a single tenant, the
    brownout selector degrades the *whole fleet* together: every replica's
    pump thread consults the same instance (``select``) and feeds it every
    completed request's latency (``observe``), while the router's sentinel
    feeds the aggregate queue depth (``observe_depth``).  The ladder walks
    down a rung (W8 -> W4 -> W2: lower-bit views stream fewer weight bytes,
    so they are the cheaper points) when EITHER the windowed p95 violates
    the :class:`ServiceObjective` OR the fleet backlog crosses
    ``max_queue_depth`` — and walks back up when p95 shows
    ``recover_margin`` headroom with the backlog clear.  ``hold`` /
    ``min_samples`` hysteresis follows the objective, and shifting clears
    the window, exactly like the single-tenant controller.

    All state is lock-guarded: N replica pump threads plus the sentinel and
    request threads touch it concurrently.
    """

    def __init__(self, points: Sequence[WorkingPoint], slo: ServiceObjective,
                 *, max_queue_depth: Optional[int] = None):
        if not points:
            raise ValueError("BrownoutSelector needs at least one point")
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        self.points = list(points)
        self.slo = slo
        self.max_queue_depth = max_queue_depth
        self.idx = 0                               # highest precision first
        self.shifts: List[Tuple[str, str]] = []
        self._window: Deque[float] = deque(maxlen=slo.window)
        self._since_shift = 0
        self._depth = 0
        self._lock = threading.Lock()

    def select(self, budget: float = 1.0) -> WorkingPoint:
        """The fleet's current rung; ``budget`` is ignored (closed loop)."""
        with self._lock:
            return self.points[self.idx]

    @property
    def p95(self) -> float:
        from repro.runtime.scheduler import percentile
        return percentile(self._window, 0.95)

    def _depth_over(self) -> bool:
        return (self.max_queue_depth is not None
                and self._depth > self.max_queue_depth)

    def _maybe_shift(self) -> None:
        """Caller holds the lock."""
        if self._since_shift < self.slo.hold:
            return
        depth_over = self._depth_over()
        p95 = self.p95 if len(self._window) >= self.slo.min_samples else None
        if ((depth_over or (p95 is not None and p95 > self.slo.p95_latency_s))
                and self.idx < len(self.points) - 1):
            self._shift(self.idx + 1)
        elif (p95 is not None and not depth_over
                and p95 < self.slo.recover_margin * self.slo.p95_latency_s
                and self.idx > 0):
            self._shift(self.idx - 1)

    def observe(self, latency_s: float) -> None:
        """Feed one completed request's end-to-end latency (any replica)."""
        with self._lock:
            self._window.append(latency_s)
            self._since_shift += 1
            self._maybe_shift()

    def observe_depth(self, depth: int) -> None:
        """Feed the fleet's aggregate queue depth (the router's sentinel).

        A backlog crossing can downshift even before latency samples arrive
        — under overload, completions (the ``observe`` signal) lag exactly
        when shedding precision helps most."""
        with self._lock:
            self._depth = int(depth)
            self._since_shift += 1
            self._maybe_shift()

    def _shift(self, new_idx: int) -> None:
        self.shifts.append((self.points[self.idx].name,
                            self.points[new_idx].name))
        self.idx = new_idx
        self._since_shift = 0
        self._window.clear()

    def telemetry(self) -> Dict:
        with self._lock:
            return {
                "point": self.points[self.idx].name,
                "p95_slo_s": self.slo.p95_latency_s,
                "window_p95_s": (self.p95 if self._window else None),
                "queue_depth": self._depth,
                "max_queue_depth": self.max_queue_depth,
                "shifts": list(self.shifts),
            }
