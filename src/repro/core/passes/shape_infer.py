"""Shape/dtype inference pass.

Statically annotates every FIFO tensor in the graph (``Graph.value_info``)
with its shape and dtype.  The streaming writers size line buffers and FIFO
depths from these annotations, and the distributed writer derives output
sharding specs, so inference must agree exactly with what the executables
produce — ``tests/test_passes.py`` checks inferred vs. executed shapes.

The leading (batch) dim may be the symbolic :data:`repro.core.ir.BATCH`
marker; every rule propagates it untouched, so a batch-polymorphic graph gets
fully-static *per-item* annotations (spatial dims, channels) — exactly the
part FIFO sizing needs — while the executable stays free over the batch.
"""
from __future__ import annotations

import math
from itertools import zip_longest
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.ir import (BATCH, Dim, Graph, Node, TensorInfo, has_symbolic,
                           is_symbolic, static_elems)

Shape = Tuple[Dim, ...]

_RULES: Dict[str, Callable] = {}


def _rule(op: str):
    def deco(fn):
        _RULES[op] = fn
        return fn
    return deco


def _conv_spatial(size: int, k: int, s: int, pads, axis: int) -> int:
    if pads == "SAME":
        return math.ceil(size / s)
    if pads == "VALID":
        return (size - k) // s + 1
    # ONNX explicit pads [t, l, b, r]: axis 0 (H) -> t+b, axis 1 (W) -> l+r
    total = pads[axis] + pads[axis + len(pads) // 2]
    return (size + total - k) // s + 1


@_rule("Conv")
@_rule("FusedConv")
def _shape_conv(node: Node, ins: List[Shape]) -> List[Shape]:
    x, w = ins[0], ins[1]                      # NHWC, HWIO
    if int(node.attrs.get("group", 1)) != 1:
        raise ValueError(
            f"node {node.name}: grouped Conv must be normalized before "
            "inference (reader.normalize_groups rewrites depthwise groups "
            "to DepthwiseConv)")
    kh, kw = node.attrs.get("kernel_shape", w[:2])
    sh, sw = node.attrs.get("strides", (1, 1))
    pads = node.attrs.get("pads", "SAME")
    return [(x[0], _conv_spatial(x[1], kh, sh, pads, 0),
             _conv_spatial(x[2], kw, sw, pads, 1), w[3])]


@_rule("DepthwiseConv")
@_rule("FusedDepthwiseConv")
def _shape_depthwise(node: Node, ins: List[Shape]) -> List[Shape]:
    x, w = ins[0], ins[1]                      # NHWC, HWIO (kh, kw, 1, C)
    if int(w[2]) != 1:
        raise ValueError(
            f"node {node.name}: depthwise weights must be (kh, kw, 1, C), "
            f"got {tuple(w)}")
    if not is_symbolic(x[3]) and int(x[3]) != int(w[3]):
        raise ValueError(
            f"node {node.name}: depthwise channel mismatch — input has "
            f"{x[3]} channels, weights {w[3]}")
    kh, kw = node.attrs.get("kernel_shape", w[:2])
    sh, sw = node.attrs.get("strides", (1, 1))
    pads = node.attrs.get("pads", "SAME")
    return [(x[0], _conv_spatial(x[1], kh, sh, pads, 0),
             _conv_spatial(x[2], kw, sw, pads, 1), w[3])]


@_rule("MaxPool")
def _shape_maxpool(node: Node, ins: List[Shape]) -> List[Shape]:
    x = ins[0]
    k = tuple(node.attrs["kernel_shape"])
    s = tuple(node.attrs.get("strides", k))
    # reduce_window with VALID padding
    return [(x[0], (x[1] - k[0]) // s[0] + 1, (x[2] - k[1]) // s[1] + 1, x[3])]


@_rule("BatchNormalization")
@_rule("Relu")
@_rule("Softmax")
@_rule("Identity")
def _shape_elementwise(node: Node, ins: List[Shape]) -> List[Shape]:
    return [ins[0]]


@_rule("Gemm")
@_rule("FusedGemm")
@_rule("MatMul")
def _shape_matmul(node: Node, ins: List[Shape]) -> List[Shape]:
    x, w = ins[0], ins[1]
    return [(*x[:-1], w[-1])]


@_rule("Add")
def _shape_add(node: Node, ins: List[Shape]) -> List[Shape]:
    # numpy-style broadcast extended with the symbolic batch dim: BATCH
    # broadcasts with itself and with 1, never with a concrete size > 1.
    out: List[Dim] = []
    for a, b in zip_longest(reversed(ins[0]), reversed(ins[1]), fillvalue=1):
        if a == b or b == 1:
            out.append(a)
        elif a == 1:
            out.append(b)
        elif is_symbolic(a) or is_symbolic(b):
            raise ValueError(
                f"node {node.name}: cannot broadcast symbolic dim against "
                f"concrete size ({a} vs {b})")
        else:
            out.append(int(np.broadcast_shapes((a,), (b,))[0]))
    return [tuple(reversed(out))]


@_rule("Flatten")
def _shape_flatten(node: Node, ins: List[Shape]) -> List[Shape]:
    x = ins[0]
    return [(x[0], int(np.prod([int(d) for d in x[1:]])))]


@_rule("Reshape")
def _shape_reshape(node: Node, ins: List[Shape]) -> List[Shape]:
    target = list(node.attrs["shape"])
    if -1 not in target and has_symbolic(ins[0]):
        raise ValueError(
            f"node {node.name}: reshape of a batch-polymorphic tensor needs "
            f"a -1 wildcard to carry the symbolic batch (got {target})")
    if -1 in target:
        known = int(np.prod([d for d in target if d != -1]))
        if has_symbolic(ins[0]):
            # the -1 slot absorbs the symbolic batch; per-item volume must
            # already be covered by the concrete target dims
            if static_elems(ins[0]) != known:
                raise ValueError(
                    f"node {node.name}: reshape of a batch-polymorphic tensor "
                    "must keep the per-item volume in concrete dims "
                    f"({static_elems(ins[0])} != {known})")
            target[target.index(-1)] = BATCH
        else:
            target[target.index(-1)] = int(np.prod(ins[0])) // max(known, 1)
    return [tuple(target)]


@_rule("Split")
def _shape_split(node: Node, ins: List[Shape]) -> List[Shape]:
    x = list(ins[0])
    axis = node.attrs.get("axis", -1)
    if is_symbolic(x[axis]):
        raise ValueError(f"node {node.name}: cannot Split the symbolic "
                         "batch dim")
    x[axis] = x[axis] // len(node.outputs)
    return [tuple(x)] * len(node.outputs)


def infer_shapes(graph: Graph) -> Graph:
    """Annotate ``graph.value_info`` for every tensor; returns the graph."""
    vi: Dict[str, TensorInfo] = {}
    for t in graph.inputs:
        vi[t.name] = TensorInfo(t.name, tuple(t.shape), t.dtype)
    for k, v in graph.initializers.items():
        vi[k] = TensorInfo(k, tuple(v.shape), str(v.dtype))
    for n in graph.topo_order():
        ins = [tuple(vi[i].shape) for i in n.inputs]
        dtype = vi[n.inputs[0]].dtype if n.inputs else "float32"
        shapes = _RULES[n.op](n, ins)
        for oname, shape in zip(n.outputs, shapes):
            vi[oname] = TensorInfo(
                oname, tuple(d if is_symbolic(d) else int(d) for d in shape),
                dtype)
    graph.value_info = vi
    return graph
