"""Per-layer precision assignment + greedy mixed-precision exploration.

The paper's WIP goal is combining the toolchain with approximate computing —
"picking a (possibly different) datatype per layer".  Two pieces:

* :func:`make_assign_precision` — a pass that stamps a
  :class:`~repro.quant.qtypes.DatatypeConfig` onto every node
  (``Node.dtconfig``) from a :class:`~repro.quant.qtypes.PrecisionMap`
  (default point + per-node overrides).  Writers then quantize each actor's
  Weight/Bias actors and output FIFOs independently.
* :func:`explore_mixed_precision` — a greedy sensitivity-based explorer: all
  weight-carrying layers start at the highest rung of the bit ladder; each
  step tentatively lowers one layer by one rung, keeps the move that best
  preserves top-1 agreement with the float reference, and stops when no move
  stays within the tolerance.  The result is a heterogeneous PrecisionMap
  (NN2CAM-style multi-precision per-layer mapping).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.ir import Graph, Node
from repro.quant.ptq import top1_agreement
from repro.quant.qtypes import DatatypeConfig, PrecisionMap

# ops with weight initializers worth exploring per-layer
WEIGHT_OPS = ("Conv", "FusedConv", "DepthwiseConv", "FusedDepthwiseConv",
              "Gemm", "FusedGemm", "MatMul")


def _as_map(dt) -> Optional[PrecisionMap]:
    if dt is None:
        return None
    if isinstance(dt, PrecisionMap):
        return dt
    return PrecisionMap(dt)


def make_assign_precision(dtconfig) -> Callable[[Graph], Graph]:
    """Pass factory: annotate every node with its per-layer datatype.
    ``dtconfig`` is a DatatypeConfig (uniform) or PrecisionMap
    (heterogeneous); ``None`` leaves the graph untouched."""
    pm = _as_map(dtconfig)

    def assign_precision(graph: Graph) -> Graph:
        if pm is None:
            return graph
        nodes = [replace(n, dtconfig=pm.for_node(n.name)) for n in graph.nodes]
        return Graph(graph.name, nodes, graph.inputs, graph.outputs,
                     graph.initializers, graph.value_info)

    return assign_precision


def strip_precision(graph: Graph) -> Graph:
    """Drop every per-node precision annotation (the float view of an
    annotated graph — calibration must run on this, not on the quantized
    network)."""
    if all(n.dtconfig is None for n in graph.nodes):
        return graph
    nodes = [replace(n, dtconfig=None) for n in graph.nodes]
    return Graph(graph.name, nodes, graph.inputs, graph.outputs,
                 graph.initializers, graph.value_info)


def quantizable_layers(graph: Graph) -> List[Node]:
    inits = graph.initializers
    return [n for n in graph.topo_order()
            if n.op in WEIGHT_OPS
            and any(i in inits and inits[i].ndim >= 2 for i in n.inputs)]


def explore_mixed_precision(
        graph: Graph, calib_inputs: Tuple, *,
        act_bits: int = 16,
        ladder: Sequence[int] = (16, 8, 4, 2),
        tol: float = 0.02,
) -> Tuple[PrecisionMap, List[Dict]]:
    """Greedy per-layer weight-precision descent on a (pass-transformed)
    graph.  Returns ``(PrecisionMap, history)`` where history records each
    accepted move with its top-1 agreement vs. the float reference."""
    from repro.core.writers.jax_writer import JaxWriter

    ref_writer = JaxWriter(graph)                 # float reference
    ref_logits, env = ref_writer.build(capture=True)(*calib_inputs)
    act_ranges = {k: float(jnp.max(jnp.abs(v))) for k, v in env.items()
                  if hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.floating)}

    layers = [n.name for n in quantizable_layers(graph)]
    bits = {name: ladder[0] for name in layers}
    ladder = list(ladder)

    def evaluate(candidate: Dict[str, int]) -> float:
        pm = PrecisionMap(DatatypeConfig(act_bits, ladder[0]),
                          {n: DatatypeConfig(act_bits, b)
                           for n, b in candidate.items()})
        g = make_assign_precision(pm)(graph)
        w = JaxWriter(g, pm.default, act_ranges)
        return top1_agreement(w.build()(*calib_inputs), ref_logits)

    history: List[Dict] = []
    while True:
        best = None
        for name in layers:
            rung = ladder.index(bits[name])
            if rung + 1 >= len(ladder):
                continue
            trial = dict(bits)
            trial[name] = ladder[rung + 1]
            agree = evaluate(trial)
            if agree >= 1.0 - tol and (best is None or agree > best[1]):
                best = (name, agree, trial)
        if best is None:
            break
        name, agree, bits = best
        history.append({"layer": name, "weight_bits": bits[name],
                        "agreement": agree})
    pm = PrecisionMap(DatatypeConfig(act_bits, ladder[0]),
                      {n: DatatypeConfig(act_bits, b) for n, b in bits.items()})
    return pm, history
