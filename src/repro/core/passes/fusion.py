"""Conv + BatchNormalization (+ Relu) fusion pass.

Folds inference-mode BatchNormalization into the preceding Conv's Weight/Bias
actors and absorbs a trailing Relu, emitting a single ``FusedConv`` node —
the standard graph-level optimization for streaming accelerators (one actor,
one FIFO hop, no BN multiplier in the datapath).

The paper's CNN interleaves a MaxPool between the Conv and the BN
(``Conv -> MaxPool -> BN -> Relu``).  BN is a per-channel affine
``z = inv * y + c`` with ``inv = scale / sqrt(var + eps)``; an affine with
``inv > 0`` commutes with the per-channel max window, so the pass also fuses
*across* a single interposed MaxPool:

    BN(Pool(Conv(x))) = Pool(inv * Conv(x) + c) = Pool(FusedConv(x))
    Relu(Pool(y))     = Pool(Relu(y))                    (Relu is monotone)

guarded by an explicit ``inv > 0`` check per channel (negative BN scales fall
back to the unfused form).  All intermediate FIFOs must have exactly one
consumer and must not be graph outputs.

:func:`fuse_gemm_relu` is the MLP-topology analogue (Table I): a ``Gemm``
whose single consumer is a ``Relu`` becomes one ``FusedGemm`` actor, so the
fully-connected stack reaches the fused kernel epilogue (bias + ReLU +
activation quant in-VMEM) the same way FusedConv does.

``DepthwiseConv`` chains fuse identically (BN's per-channel affine
broadcasts over the HWIO depthwise weight's last dim), emitting
``FusedDepthwiseConv``.  :func:`reorder_relu_maxpool` is the remaining
window-commutation rewrite: leftover ``Relu -> MaxPool`` chains swap so the
inter-actor FIFO carries the pooled tensor.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, Optional

import numpy as np

from repro.core.ir import Graph, Node


def _single_consumer(graph: Graph, tensor: str) -> Optional[Node]:
    if tensor in set(graph.outputs):
        return None
    cs = graph.consumer_index().get(tensor, [])
    return cs[0] if len(cs) == 1 else None


def fuse_gemm_relu(graph: Graph) -> Graph:
    """Fold ``Gemm -> Relu`` chains into single ``FusedGemm`` nodes.

    Pure graph surgery (no weight rewrite): the FusedGemm keeps the Gemm's
    inputs and name, takes the Relu's output tensor, and records the fold in
    ``attrs["relu"]`` / ``attrs["fused_from"]`` — the same contract FusedConv
    uses, so every writer's fused-epilogue machinery applies unchanged."""
    drop = set()
    fused: Dict[str, Node] = {}
    for gemm in graph.nodes:
        if gemm.op != "Gemm":
            continue
        relu = _single_consumer(graph, gemm.outputs[0])
        if relu is None or relu.op != "Relu":
            continue
        attrs = dict(gemm.attrs)
        attrs["relu"] = True
        attrs["fused_from"] = [relu.name]
        fused[gemm.name] = Node("FusedGemm", gemm.name, list(gemm.inputs),
                                [relu.outputs[0]], attrs,
                                dtconfig=gemm.dtconfig)
        drop.add(relu.name)
    if not fused:
        return graph
    nodes = [fused.get(n.name, n) for n in graph.nodes if n.name not in drop]
    g = Graph(graph.name, nodes, graph.inputs, graph.outputs,
              graph.initializers)
    g.validate()
    return g


def reorder_relu_maxpool(graph: Graph) -> Graph:
    """Swap ``Relu -> MaxPool`` chains into ``MaxPool -> Relu``.

    Relu is monotone, so it commutes with the per-channel max window —
    ``Pool(Relu(x)) == Relu(Pool(x))`` elementwise.  Pooling first shrinks
    the tensor the Relu actor (and the FIFO feeding it) carries by the pool
    window's area, and leaves the Relu adjacent to whatever consumes it —
    where the Conv/Gemm fusion passes can claim it.  Runs after the fusion
    passes so it only reorders chains those passes left behind."""
    swaps: Dict[str, Node] = {}       # node name -> replacement
    for relu in graph.nodes:
        if relu.op != "Relu":
            continue
        pool = _single_consumer(graph, relu.outputs[0])
        if pool is None or pool.op != "MaxPool":
            continue
        pre = f"{pool.name}_pre_relu"
        # the pool moves to the Relu's slot (consuming its input), the Relu
        # to the pool's slot (producing its output) — topo order preserved
        swaps[relu.name] = Node("MaxPool", pool.name, [relu.inputs[0]], [pre],
                                dict(pool.attrs), dtconfig=pool.dtconfig)
        swaps[pool.name] = Node("Relu", relu.name, [pre], [pool.outputs[0]],
                                dict(relu.attrs), dtconfig=relu.dtconfig)
    if not swaps:
        return graph
    g = Graph(graph.name, [swaps.get(n.name, n) for n in graph.nodes],
              graph.inputs, graph.outputs, graph.initializers)
    g.validate()
    return g


def fuse_conv_bn_relu(graph: Graph) -> Graph:
    inits = dict(graph.initializers)
    drop = set()                      # node names removed by fusion
    fused: Dict[str, Node] = {}       # conv name -> FusedConv replacement
    pool_rewire: Dict[str, str] = {}  # pool name -> new output tensor name

    for conv in graph.nodes:
        if conv.op not in ("Conv", "DepthwiseConv"):
            continue
        nxt = _single_consumer(graph, conv.outputs[0])
        pool = None
        if nxt is not None and nxt.op == "MaxPool":
            pool = nxt
            nxt = _single_consumer(graph, pool.outputs[0])
        if nxt is None or nxt.op != "BatchNormalization":
            continue
        bn = nxt
        stats = [inits.get(i) for i in bn.inputs[1:5]]
        if any(s is None for s in stats):
            continue  # BN stats must be compile-time constants
        scale, bias, mean, var = (np.asarray(s, np.float64) for s in stats)
        eps = bn.attrs.get("epsilon", 1e-5)
        inv = scale / np.sqrt(var + eps)
        if pool is not None and not np.all(inv > 0):
            continue  # negative BN scale does not commute with MaxPool
        # the fold rescales W/b in place, so they must be private to this conv
        # (tied weights would corrupt the sharing node)
        if any(len(graph.consumers_of(t)) != 1 for t in conv.inputs[1:]):
            continue
        relu = _single_consumer(graph, bn.outputs[0])
        if relu is not None and relu.op != "Relu":
            relu = None
        tail = relu if relu is not None else bn

        # fold BN into the Weight/Bias actors (HWIO: out-channel is last dim)
        wname = conv.inputs[1]
        w = np.asarray(inits[wname])
        inits[wname] = (np.asarray(w, np.float64) * inv).astype(w.dtype)
        shift = bias - mean * inv
        if len(conv.inputs) > 2:
            bname = conv.inputs[2]
            b = np.asarray(inits[bname])
            inits[bname] = (np.asarray(b, np.float64) * inv + shift
                            ).astype(b.dtype)
            fin = list(conv.inputs)
        else:
            bname = f"{conv.name}/fused_bias"
            inits[bname] = shift.astype(w.dtype)
            fin = list(conv.inputs) + [bname]

        attrs = dict(conv.attrs)
        attrs["relu"] = relu is not None
        attrs["fused_from"] = [x.name for x in (bn, relu) if x is not None]
        if pool is None:
            outs = [tail.outputs[0]]
        else:
            outs = [conv.outputs[0]]
            pool_rewire[pool.name] = tail.outputs[0]
        fop = "FusedDepthwiseConv" if conv.op == "DepthwiseConv" else "FusedConv"
        fused[conv.name] = Node(fop, conv.name, fin, outs, attrs,
                                dtconfig=conv.dtconfig)
        drop.add(bn.name)
        if relu is not None:
            drop.add(relu.name)

    if not fused:
        return graph

    new_nodes = []
    for n in graph.nodes:
        if n.name in drop:
            continue
        if n.name in fused:
            new_nodes.append(fused[n.name])
        elif n.name in pool_rewire:
            new_nodes.append(replace(n, outputs=[pool_rewire[n.name]]))
        else:
            new_nodes.append(n)
    g = Graph(graph.name, new_nodes, graph.inputs, graph.outputs, inits)
    g.validate()
    return g
