"""Pass-based graph compiler for the ONNX-like IR.

The Reader produces a raw IR; before any Writer consumes it, a
:class:`PassManager` runs a sequence of graph-to-graph rewrites:

1. :func:`fuse_conv_bn_relu` — fold Conv/DepthwiseConv+BatchNormalization
   (+Relu) chains (also across a single interposed MaxPool) into one
   ``FusedConv`` / ``FusedDepthwiseConv`` actor;
2. :func:`reorder_relu_maxpool` — swap leftover ``Relu -> MaxPool`` chains
   (Relu commutes with the max window) so FIFOs carry pooled tensors;
3. :func:`fold_constants` — evaluate all-constant subgraphs at compile time;
4. :func:`eliminate_dead_nodes` — drop nodes/initializers unreachable from
   the graph outputs (e.g. the folded BN statistics);
5. :func:`infer_shapes` — annotate every FIFO tensor with shape/dtype
   (``Graph.value_info``);
6. :func:`make_assign_precision` — stamp a per-layer ``Dx-Wy``
   :class:`~repro.quant.qtypes.DatatypeConfig` onto every node.

``default_pipeline(dtconfig)`` builds exactly that list;
``DesignFlow.run`` applies it by default, with ``run(passes=())`` restoring
the raw node-by-node interpretation.  Each pass is a pure function
``Graph -> Graph`` (annotation passes may fill caches in place); custom
passes slot into the same pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence

from repro.core.ir import Graph
from repro.core.passes.cleanup import eliminate_dead_nodes, fold_constants
from repro.core.passes.fusion import (fuse_conv_bn_relu, fuse_gemm_relu,
                                      reorder_relu_maxpool)
from repro.core.passes.precision import (explore_mixed_precision,
                                         make_assign_precision,
                                         quantizable_layers, strip_precision)
from repro.core.passes.shape_infer import infer_shapes

GraphPass = Callable[[Graph], Graph]


@dataclass
class PassManager:
    """Runs a pass sequence, validating the graph after each rewrite."""
    passes: Sequence[GraphPass]

    def run(self, graph: Graph) -> Graph:
        for p in self.passes:
            out = p(graph)
            graph = graph if out is None else out
            graph.validate()
        return graph


def default_pipeline(dtconfig=None) -> List[GraphPass]:
    """The standard compile pipeline: fuse (conv chains, then gemm+relu),
    reorder leftover Relu->MaxPool chains, fold, sweep, annotate shapes,
    assign per-layer precision."""
    return [fuse_conv_bn_relu, fuse_gemm_relu, reorder_relu_maxpool,
            fold_constants, eliminate_dead_nodes, infer_shapes,
            make_assign_precision(dtconfig)]


def structural_pipeline() -> List[GraphPass]:
    """The graph rewrites only (no precision annotation) — what the
    mixed-precision explorer runs before searching datatypes."""
    return [fuse_conv_bn_relu, fuse_gemm_relu, reorder_relu_maxpool,
            fold_constants, eliminate_dead_nodes, infer_shapes]


__all__ = [
    "GraphPass", "PassManager", "default_pipeline", "structural_pipeline",
    "infer_shapes", "fuse_conv_bn_relu", "fuse_gemm_relu",
    "reorder_relu_maxpool", "fold_constants",
    "eliminate_dead_nodes", "make_assign_precision",
    "explore_mixed_precision", "quantizable_layers", "strip_precision",
]
