"""Constant folding and dead-node elimination passes."""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.ir import Graph, Node

# ops whose folding would materialize large new tensors for no win
_NO_FOLD = {"Conv", "FusedConv"}


def fold_constants(graph: Graph) -> Graph:
    """Evaluate nodes whose inputs are all compile-time constants
    (initializers) with the reference op implementations and promote their
    outputs to initializers.  The now-dead nodes are left for
    :func:`eliminate_dead_nodes` to sweep."""
    from repro.core.writers.registry import resolve

    inits = dict(graph.initializers)
    new_nodes: List[Node] = []
    for n in graph.topo_order():
        foldable = (n.op not in _NO_FOLD and n.inputs
                    and all(i in inits for i in n.inputs))
        if not foldable:
            new_nodes.append(n)
            continue
        env = {i: jnp.asarray(inits[i]) for i in n.inputs}
        y = resolve(n.op, "jax")(n, env)
        outs = y if isinstance(y, tuple) else (y,)
        for oname, oval in zip(n.outputs, outs):
            inits[oname] = np.asarray(oval)
    if len(new_nodes) == len(graph.nodes):
        return graph
    g = Graph(graph.name, new_nodes, graph.inputs, graph.outputs, inits)
    g.validate()
    return g


def eliminate_dead_nodes(graph: Graph) -> Graph:
    """Drop nodes (and initializers) that cannot reach a graph output —
    e.g. the BN statistics left behind by the fusion pass or debug taps in an
    imported model."""
    needed = set(graph.outputs)
    keep: List[Node] = []
    for n in reversed(graph.topo_order()):
        if any(o in needed for o in n.outputs):
            keep.append(n)
            needed.update(n.inputs)
    keep.reverse()
    inits: Dict[str, np.ndarray] = {k: v for k, v in graph.initializers.items()
                                    if k in needed}
    if len(keep) == len(graph.nodes) and len(inits) == len(graph.initializers):
        return graph
    g = Graph(graph.name, keep, graph.inputs, graph.outputs, inits)
    g.validate()
    return g
