"""The Reader half of the ONNXParser: builds the IR from model descriptions.

Sources supported:
  * ONNX-shaped JSON (+ npz weights)              — ``read_json`` / ``read_file``
  * the paper's CNN (repro.models.cnn params)     — ``cnn_to_ir``
  * a generic MLP description                     — ``mlp_to_ir``

Every reader runs the shape-inference pass on the graph it produces, so a
freshly read IR already carries ``value_info`` annotations for downstream
passes and writers (further rewrites re-infer as part of the pipeline).

By default the graph input's leading dim is the *symbolic* batch marker
(:data:`repro.core.ir.BATCH`), so one compiled artifact serves any request
size — pass ``batch=<int>`` to pin a literal batch (the pre-polymorphism
behaviour, still used when lowering ahead-of-time for a fixed shape).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.mnist_cnn import CNNConfig
from repro.configs.separable_cnn import SeparableCNNConfig
from repro.core.ir import BATCH, Dim, Graph, Node, TensorInfo
from repro.core.passes.shape_infer import infer_shapes


def normalize_groups(graph: Graph) -> Graph:
    """Rewrite ONNX grouped Convs into the IR's explicit ops.

    ``group == 1`` (or absent) stays a plain Conv (the attribute is dropped);
    ``group == C`` with HWIO weights (kh, kw, 1, C) becomes DepthwiseConv —
    the form the direct Pallas kernel consumes.  Anything between (grouped
    but not depthwise) has no lowering here and is rejected up front rather
    than miscompiled downstream.
    """
    for node in graph.nodes:
        if node.op != "Conv" or "group" not in node.attrs:
            continue
        group = int(node.attrs["group"])
        if group == 1:
            del node.attrs["group"]
            continue
        w = graph.initializers.get(node.inputs[1])
        if w is None:
            raise ValueError(
                f"grouped Conv '{node.name}' needs an initializer weight to "
                f"normalize (input '{node.inputs[1]}' is activation-fed)")
        if w.ndim != 4 or w.shape[2] != 1 or w.shape[3] != group:
            raise ValueError(
                f"Conv '{node.name}' with group={group} is not depthwise "
                f"(weights {tuple(w.shape)}, expected (kh, kw, 1, {group})); "
                f"general grouped conv has no lowering")
        node.op = "DepthwiseConv"
        del node.attrs["group"]
    return graph


def read_json(text: str, weights: Optional[Dict[str, np.ndarray]] = None) -> Graph:
    return infer_shapes(normalize_groups(Graph.from_json(text, weights)))


def read_file(path: str) -> Graph:
    return infer_shapes(normalize_groups(Graph.load(path)))


def cnn_to_ir(cfg: CNNConfig, params: Dict[str, np.ndarray],
              batch: Optional[int] = None) -> Graph:
    """The paper's 2-conv-block + FC MNIST classifier as an IR graph.

    Layout is NHWC; Conv weights HWIO (converted by the writers as needed).
    ``batch=None`` (default) records the symbolic batch dim — the compiled
    executable then serves any leading-dim size from one artifact.
    """
    h, w = cfg.image_hw
    nodes = []
    inits: Dict[str, np.ndarray] = {}
    x = "input"
    for i, cout in enumerate(cfg.conv_channels):
        wname, bname = f"conv{i}/w", f"conv{i}/b"
        inits[wname] = np.asarray(params[wname])
        inits[bname] = np.asarray(params[bname])
        nodes.append(Node("Conv", f"conv{i}", [x, wname, bname], [f"conv{i}_out"],
                          {"kernel_shape": [cfg.kernel_size] * 2, "pads": "SAME",
                           "strides": [1, 1]}))
        nodes.append(Node("MaxPool", f"pool{i}", [f"conv{i}_out"], [f"pool{i}_out"],
                          {"kernel_shape": [cfg.pool] * 2, "strides": [cfg.pool] * 2}))
        for stat in ("scale", "bias", "mean", "var"):
            inits[f"bn{i}/{stat}"] = np.asarray(params[f"bn{i}/{stat}"])
        nodes.append(Node("BatchNormalization", f"bn{i}",
                          [f"pool{i}_out", f"bn{i}/scale", f"bn{i}/bias",
                           f"bn{i}/mean", f"bn{i}/var"], [f"bn{i}_out"],
                          {"epsilon": 1e-5}))
        nodes.append(Node("Relu", f"relu{i}", [f"bn{i}_out"], [f"relu{i}_out"]))
        x = f"relu{i}_out"
        h, w = h // cfg.pool, w // cfg.pool
    nodes.append(Node("Flatten", "flatten", [x], ["flat"]))
    inits["fc/w"] = np.asarray(params["fc/w"])
    inits["fc/b"] = np.asarray(params["fc/b"])
    nodes.append(Node("Gemm", "fc", ["flat", "fc/w", "fc/b"], ["logits"]))
    bdim: Dim = BATCH if batch is None else int(batch)
    g = Graph(
        name="mnist-cnn",
        nodes=nodes,
        inputs=[TensorInfo("input", (bdim, cfg.image_hw[0], cfg.image_hw[1],
                                     cfg.in_channels))],
        outputs=["logits"],
        initializers=inits,
    )
    g.validate()
    return infer_shapes(g)


def separable_cnn_to_ir(cfg: SeparableCNNConfig, params: Dict[str, np.ndarray],
                        batch: Optional[int] = None) -> Graph:
    """The MobileNet-style depthwise-separable classifier as an IR graph.

    Conv stem + Relu + MaxPool, then per block DepthwiseConv(3x3, stride) +
    BN + Relu and pointwise Conv(1x1) + BN + Relu, Flatten, Gemm.  The stem's
    Relu -> MaxPool order is the textbook (commutable) one — the reordering
    pass swaps it so the FIFO between them carries the pooled tensor.
    Layout NHWC; depthwise weights HWIO (kh, kw, 1, C).
    """
    k = cfg.kernel_size
    nodes = []
    inits: Dict[str, np.ndarray] = {}
    inits["stem/w"] = np.asarray(params["stem/w"])
    inits["stem/b"] = np.asarray(params["stem/b"])
    nodes.append(Node("Conv", "stem", ["input", "stem/w", "stem/b"],
                      ["stem_out"],
                      {"kernel_shape": [k, k], "pads": "SAME",
                       "strides": [1, 1]}))
    nodes.append(Node("Relu", "stem_relu", ["stem_out"], ["stem_relu_out"]))
    nodes.append(Node("MaxPool", "stem_pool", ["stem_relu_out"], ["pool_out"],
                      {"kernel_shape": [cfg.pool] * 2,
                       "strides": [cfg.pool] * 2}))
    x = "pool_out"
    for i, (cout, stride) in enumerate(cfg.blocks):
        for layer, conv_op, attrs in (
                (f"dw{i}", "DepthwiseConv",
                 {"kernel_shape": [k, k], "pads": "SAME",
                  "strides": [stride, stride]}),
                (f"pw{i}", "Conv",
                 {"kernel_shape": [1, 1], "pads": "VALID",
                  "strides": [1, 1]})):
            inits[f"{layer}/w"] = np.asarray(params[f"{layer}/w"])
            inits[f"{layer}/b"] = np.asarray(params[f"{layer}/b"])
            nodes.append(Node(conv_op, layer, [x, f"{layer}/w", f"{layer}/b"],
                              [f"{layer}_out"], attrs))
            for stat in ("scale", "bias", "mean", "var"):
                inits[f"{layer}_bn/{stat}"] = np.asarray(
                    params[f"{layer}_bn/{stat}"])
            nodes.append(Node("BatchNormalization", f"{layer}_bn",
                              [f"{layer}_out", f"{layer}_bn/scale",
                               f"{layer}_bn/bias", f"{layer}_bn/mean",
                               f"{layer}_bn/var"], [f"{layer}_bn_out"],
                              {"epsilon": 1e-5}))
            nodes.append(Node("Relu", f"{layer}_relu", [f"{layer}_bn_out"],
                              [f"{layer}_relu_out"]))
            x = f"{layer}_relu_out"
    nodes.append(Node("Flatten", "flatten", [x], ["flat"]))
    inits["fc/w"] = np.asarray(params["fc/w"])
    inits["fc/b"] = np.asarray(params["fc/b"])
    nodes.append(Node("Gemm", "fc", ["flat", "fc/w", "fc/b"], ["logits"]))
    bdim: Dim = BATCH if batch is None else int(batch)
    g = Graph(
        name=cfg.name,
        nodes=nodes,
        inputs=[TensorInfo("input", (bdim, cfg.image_hw[0], cfg.image_hw[1],
                                     cfg.in_channels))],
        outputs=["logits"],
        initializers=inits,
    )
    g.validate()
    return infer_shapes(g)


def mlp_to_ir(layer_sizes, params: Dict[str, np.ndarray],
              batch: Optional[int] = None, name: str = "mlp") -> Graph:
    """Fully-connected stack (the HLS4ML comparison topology, Table I).
    ``batch=None`` records the symbolic batch dim (see :func:`cnn_to_ir`)."""
    nodes = []
    inits: Dict[str, np.ndarray] = {}
    x = "input"
    for i in range(len(layer_sizes) - 1):
        wn, bn = f"fc{i}/w", f"fc{i}/b"
        inits[wn], inits[bn] = np.asarray(params[wn]), np.asarray(params[bn])
        out = f"fc{i}_out" if i < len(layer_sizes) - 2 else "logits"
        nodes.append(Node("Gemm", f"fc{i}", [x, wn, bn], [out]))
        if i < len(layer_sizes) - 2:
            nodes.append(Node("Relu", f"relu{i}", [out], [f"relu{i}_out"]))
            x = f"relu{i}_out"
    bdim: Dim = BATCH if batch is None else int(batch)
    g = Graph(name, nodes, [TensorInfo("input", (bdim, layer_sizes[0]))],
              ["logits"], inits)
    g.validate()
    return infer_shapes(g)
