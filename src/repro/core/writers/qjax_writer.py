"""Writer 4: IR -> packed-weight quantized executable (the "qjax" target).

The execution engine the paper's one-copy-many-points architecture implies:
every >=2-D initializer is quantized ONCE to int8 master codes +
per-output-channel scales (:class:`~repro.quant.pack.PackedWeights`), and the
hot-path ops run the dequant-fused :mod:`repro.kernels.qmatmul` kernels over
those codes instead of an f32 ``@``/``conv`` over fake-quantized float copies:

* ``Gemm`` / ``MatMul`` call ``qgemm`` on the packed codes — the ``bits``-bit
  view is truncated in-VMEM, the per-channel rescale, bias and the
  consumer-side fixed-point activation quant happen in the kernel epilogue
  (no separate round/clip op per FIFO);
* ``Conv`` / ``FusedConv`` lower to im2col + ``qgemm`` with the folded ReLU
  fused into the same epilogue (kernel path), or to an XLA conv over the
  dequantized view (ref path — XLA folds the dequant of constant codes into
  a constant weight, so the CPU fallback costs exactly one conv);
* the active working point ``bits`` is a parameter of ``build`` /
  ``build_batched``, NOT baked into the weights: every point executable
  reads the SAME :class:`PackedWeights` buffer, so ``AccelServer`` switching
  W8 -> W4 -> W2 per batch moves no weights and holds ~N× less memory than
  per-point copies.

Backend selection: compiled Pallas on TPU; off-TPU the jnp reference path
(``use_kernel``/``interpret`` writer kwargs override, e.g. forced
interpret-mode kernels in tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ir import Graph, Node
from repro.core.writers.jax_writer import BatchedExecutable, JaxWriter
from repro.core.writers.registry import register_op, resolve
from repro.kernels.qmatmul.ops import qgemm, resolve_interpret
from repro.kernels.qmatmul.ref import epilogue_ref
from repro.quant.pack import PackedTensor, PackedWeights
from repro.quant.qtypes import DatatypeConfig, fixed_for_range

# reserved env key carrying the writer context into the qjax op impls; graph
# tensor names are ONNX-style identifiers and cannot collide with it
QCTX = "__qctx__"


@dataclass
class QJaxContext:
    """Per-build context the qjax op impls read from the env: the active
    working point and the writer's precision/calibration state."""

    writer: "QJaxWriter"
    bits: int

    def weight_bits(self, node: Optional[Node]) -> int:
        """Effective view bits: the runtime working point, capped by the
        node's per-layer weight precision when the precision pass assigned
        one below it (a W4 layer stays W4 even at the W8 point)."""
        dt = self.writer.node_dt(node)
        if dt.weight_bits < 32:
            return min(self.bits, dt.weight_bits)
        return self.bits

    def act_qt(self, name: str, node: Optional[Node]
               ) -> Optional[Tuple[int, int, int]]:
        """Static epilogue spec for the output's fixed-point activation
        quant — same qtype ``_act_q`` would use, fused into the kernel."""
        dt = self.writer.node_dt(node)
        if dt.act_bits >= 32:
            return None
        qt = fixed_for_range(dt.act_bits,
                             self.writer.act_ranges.get(name, 8.0))
        return (qt.frac, qt.qmin, qt.qmax)

    def mark_fused(self, name: str) -> None:
        self.writer._fused_act.add(name)


# ---------------------------------------------------------------------------
# im2col (the streaming conv as a packed matmul)
# ---------------------------------------------------------------------------

def _pad_amounts(h: int, k: int, s: int, pads) -> Tuple[int, Tuple[int, int]]:
    """(out_dim, (lo, hi)) for one spatial dim — matches XLA's SAME/VALID."""
    if pads == "SAME":
        oh = -(-h // s)
        pad = max((oh - 1) * s + k - h, 0)
        return oh, (pad // 2, pad - pad // 2)
    if pads == "VALID":
        return (h - k) // s + 1, (0, 0)
    lo, hi = pads
    return (h + lo + hi - k) // s + 1, (int(lo), int(hi))


def im2col(x, kh: int, kw: int, strides, pads):
    """x: (B, H, W, C) -> patches (B, OH, OW, kh*kw*C), dy-major then dx then
    channel — the order HWIO weights flatten to for the (K, N) matmul."""
    sh, sw = strides
    B, H, W, C = x.shape
    oh, (ph0, ph1) = _pad_amounts(H, kh, sh, pads if isinstance(pads, str)
                                  else pads[0])
    ow, (pw0, pw1) = _pad_amounts(W, kw, sw, pads if isinstance(pads, str)
                                  else pads[1])
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy:dy + sh * (oh - 1) + 1:sh,
                           dx:dx + sw * (ow - 1) + 1:sw, :])
    return jnp.concatenate(cols, axis=-1), oh, ow


# ---------------------------------------------------------------------------
# qjax op implementations
# ---------------------------------------------------------------------------

def _qgemm_node(node: Node, env, relu: bool = False):
    """Shared Gemm/MatMul lowering; None when the weight is not packed
    (activation×activation matmul, no context) so the caller falls back."""
    ctx = env.get(QCTX)
    w = env.get(node.inputs[1])
    if ctx is None or not isinstance(w, PackedTensor):
        return None
    x = env[node.inputs[0]]
    bias = env[node.inputs[2]] if len(node.inputs) > 2 else None
    out = node.outputs[0]
    aqt = ctx.act_qt(out, node)
    y = qgemm(x, w.codes_2d(), w.scale_1d(), bias,
              bits=ctx.weight_bits(node), relu=relu, act_qt=aqt,
              interpret=ctx.writer.interpret,
              use_kernel=ctx.writer.kernel_enabled())
    ctx.mark_fused(out)
    return y


@register_op("Gemm", target="qjax")
def _op_gemm_qjax(node: Node, env):
    y = _qgemm_node(node, env)
    return y if y is not None else resolve("Gemm", "jax")(node, env)


@register_op("MatMul", target="qjax")
def _op_matmul_qjax(node: Node, env):
    y = _qgemm_node(node, env)
    return y if y is not None else resolve("MatMul", "jax")(node, env)


def _qconv_node(node: Node, env, relu: bool):
    ctx = env.get(QCTX)
    w = env.get(node.inputs[1])
    if ctx is None or not isinstance(w, PackedTensor):
        return None
    x = env[node.inputs[0]]
    bias = env[node.inputs[2]] if len(node.inputs) > 2 else None
    kh, kw, _, cout = w.codes.shape
    strides = tuple(node.attrs.get("strides", (1, 1)))
    pads = node.attrs.get("pads", "SAME")
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    aqt = ctx.act_qt(out, node)
    if ctx.writer.kernel_enabled():
        # im2col + dequant-fused matmul; ReLU and the consumer-side
        # activation quant ride in the kernel epilogue
        patches, oh, ow = im2col(x, kh, kw, strides, pads)
        y = qgemm(patches.reshape(-1, patches.shape[-1]),
                  w.codes_2d(), w.scale_1d(), bias,
                  bits=bits, relu=relu, act_qt=aqt,
                  interpret=ctx.writer.interpret, use_kernel=True)
        y = y.reshape(x.shape[0], oh, ow, cout)
    else:
        # ref path: XLA conv over the dequantized view — codes are trace
        # constants, so the dequant folds into a constant f32 weight
        wf = w.dequant(bits, jnp.float32)
        y = jax.lax.conv_general_dilated(
            x, wf, window_strides=strides, padding=pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if bias is not None:
            y = y + bias
        y = epilogue_ref(y, relu, aqt)
    ctx.mark_fused(out)
    return y


@register_op("Conv", target="qjax")
def _op_conv_qjax(node: Node, env):
    y = _qconv_node(node, env, relu=False)
    return y if y is not None else resolve("Conv", "jax")(node, env)


@register_op("FusedConv", target="qjax")
def _op_fused_conv_qjax(node: Node, env):
    y = _qconv_node(node, env, relu=bool(node.attrs.get("relu")))
    return y if y is not None else resolve("FusedConv", "jax")(node, env)


# ---------------------------------------------------------------------------
# the writer
# ---------------------------------------------------------------------------

class QJaxWriter(JaxWriter):
    """Packed-weight quantized execution engine (see module docstring).

    Writer kwargs (``DesignFlow.run(writer_kwargs={"qjax": {...}})``):

    * ``use_kernel`` — None (auto: Pallas on TPU, jnp ref elsewhere), True
      (force the kernel, interpret-mode off-TPU), False (force the ref path);
    * ``interpret``  — override for the Pallas interpret flag (None = auto);
    * ``default_bits`` — working point used when ``build(bits=None)``.
    """

    target = "qjax"

    def __init__(self, graph: Graph,
                 dtconfig: Optional[DatatypeConfig] = None,
                 act_ranges: Optional[Dict[str, float]] = None, *,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 default_bits: Optional[int] = None):
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._default_bits = default_bits
        super().__init__(graph, dtconfig, act_ranges)

    # -- packed weights ------------------------------------------------------
    def _prepare_weights(self) -> Dict[str, Any]:
        """Quantize once to shared int8 master codes; the active ``bits``
        view is selected per build, not here."""
        self.packed = PackedWeights.from_initializers(self.graph.initializers)
        out: Dict[str, Any] = dict(self.packed.passthrough)
        out.update(self.packed.tensors)
        return out

    @property
    def default_bits(self) -> int:
        if self._default_bits is not None:
            return int(self._default_bits)
        if self.dt.weight_bits < 32:
            return min(8, self.dt.weight_bits)
        return 8

    def weight_bytes(self) -> int:
        """Bytes of the shared master buffer (all working points included)."""
        return self.packed.code_bytes()

    # -- backend routing -----------------------------------------------------
    def kernel_enabled(self) -> bool:
        if self.use_kernel is not None:
            return bool(self.use_kernel)
        return not resolve_interpret(self.interpret)

    @property
    def qpath(self) -> str:
        """Which execution path this writer resolves to on this backend."""
        return "pallas" if self.kernel_enabled() else "ref"

    # -- build ---------------------------------------------------------------
    def _env_seed(self, bits: Optional[int] = None) -> Dict[str, Any]:
        env: Dict[str, Any] = dict(self.weights)
        env[QCTX] = QJaxContext(self, self.default_bits if bits is None
                                else int(bits))
        return env

    def build_batched(self, max_entries: int = 8,
                      on_compile: Optional[Callable] = None,
                      bits: Optional[int] = None) -> BatchedExecutable:
        exe = super().build_batched(max_entries=max_entries,
                                    on_compile=on_compile,
                                    bits=self.default_bits if bits is None
                                    else int(bits))
        exe.packed = self.packed   # buffer-identity accounting in tests/serve
        return exe
