"""Writer 4: IR -> packed-weight quantized executable (the "qjax" target).

The execution engine the paper's one-copy-many-points architecture implies:
every >=2-D initializer is quantized ONCE to int8 master codes +
per-output-channel scales (:class:`~repro.quant.pack.PackedWeights`), and the
hot-path ops run the dequant-fused :mod:`repro.kernels.qmatmul` kernels over
those codes instead of an f32 ``@``/``conv`` over fake-quantized float copies:

* ``Gemm`` / ``MatMul`` / ``FusedGemm`` call ``qgemm`` on the packed codes —
  the ``bits``-bit view is truncated in-VMEM, the per-channel rescale, bias,
  folded ReLU and the consumer-side fixed-point activation quant happen in
  the kernel epilogue (no separate round/clip op per FIFO);
* ``Conv`` / ``FusedConv`` lower to im2col + ``qgemm`` with the folded ReLU
  fused into the same epilogue (kernel path), or to an XLA conv over the
  dequantized view (ref path — XLA folds the dequant of constant codes into
  a constant weight, so the CPU fallback costs exactly one conv);
* ``DepthwiseConv`` / ``FusedDepthwiseConv`` call the *direct* channel-
  parallel :mod:`repro.kernels.qconv_dw` kernels — no im2col patch tensor is
  ever materialized (``dw_mode="im2col"`` restores the legacy dense-expansion
  lowering as a differential baseline);
* the active working point ``bits`` is a parameter of ``build`` /
  ``build_batched``, NOT baked into the weights: every point executable
  reads the SAME :class:`PackedWeights` buffer, so ``AccelServer`` switching
  W8 -> W4 -> W2 per batch moves no weights and holds ~N× less memory than
  per-point copies.  At W4/W2 the streamed buffer is the *sub-byte packed*
  view (``PackedTensor.packed_view``) unpacked in-VMEM — resident weight
  bytes drop to ~1/2 and ~1/4 of the W8 codes.

Fully-integer mode (``int8_act``, auto-enabled when the working point's
activation precision fits int8, i.e. ``Dx <= 8``): inter-layer tensors are
:class:`ActCode` — the producer FIFO's int8 fixed-point codes plus a static
power-of-two scale from calibration.  Hot ops consume the codes directly
(``qmatmul_int8_act``: int32 MACs on the MXU int8 path, the producer scale
folded into the per-channel weight scale) and their epilogue re-quantizes to
the consumer's code, so codes — never f32 tensors — flow between layers.
Code-domain ops with exact integer semantics (MaxPool, Relu, Flatten) operate
on the codes in place; anything without an integer implementation gets its
inputs decoded on entry (the documented float-materialization points: graph
outputs and non-integer actors).

Backend selection: compiled Pallas on TPU; off-TPU the jnp reference path
(``use_kernel``/``interpret`` writer kwargs override, e.g. forced
interpret-mode kernels in tests).
"""
from __future__ import annotations

from collections import ChainMap
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ir import Graph, Node
from repro.core.writers.jax_writer import BatchedExecutable, JaxWriter
from repro.core.writers.registry import OP_REGISTRY, register_op, resolve
from repro.kernels.qconv_dw.ops import (DW_PACK_ALIGN, qconv_dw,
                                        qconv_dw_int8_act)
from repro.kernels.qconv_dw.ref import expand_dw_codes, normalize_pads
from repro.kernels.qmatmul.ops import (qgemm, qmatmul_int8_act,
                                       resolve_interpret)
from repro.kernels.qmatmul.ref import epilogue_ref, exact_in_f32
from repro.quant.fixedpoint import quantize
from repro.quant.pack import SUB_BYTE_BITS, PackedTensor, PackedWeights
from repro.quant.ptq import act_code_qtype
from repro.quant.qtypes import DatatypeConfig, QType, fixed_for_range

# reserved env key carrying the writer context into the qjax op impls; graph
# tensor names are ONNX-style identifiers and cannot collide with it
QCTX = "__qctx__"


@dataclass
class ActCode:
    """One inter-layer tensor of the fully-integer hot path: the producer
    FIFO's int8 fixed-point codes plus their static power-of-two qtype.

    ``value = codes * 2^-frac`` — but the hot path never materializes that
    float: consumers MAC the codes in int32 and fold ``2^-frac`` into their
    per-channel weight scales.  :meth:`to_float` exists for graph outputs and
    ops without an integer implementation."""

    codes: jax.Array   # int8, the tensor's shape
    qt: QType          # static: bits <= 8, power-of-two scale 2^-frac

    @property
    def shape(self):
        return self.codes.shape

    @property
    def dtype(self):
        return self.codes.dtype

    @classmethod
    def encode(cls, x, qt: QType) -> "ActCode":
        """Float -> codes on the ``qt`` grid: exactly
        ``fixedpoint.quantize`` (the fake-quant contract has ONE home),
        narrowed to int8."""
        assert qt.bits <= 8, f"activation codes need bits <= 8, got {qt}"
        return cls(quantize(x.astype(jnp.float32), qt).astype(jnp.int8), qt)

    def to_float(self, dtype=jnp.float32):
        return self.codes.astype(dtype) * jnp.asarray(self.qt.scale, dtype)


def _decoded(node: Node, env):
    """Env view with this node's ActCode inputs decoded to float — the shim
    that lets any reference op impl run mid-integer-graph (a documented
    float-materialization point)."""
    over = {}
    for name in node.inputs:
        v = env.get(name)
        if isinstance(v, ActCode):
            over[name] = v.to_float()
    return ChainMap(over, env) if over else env


def _jax_fallback(op: str, node: Node, env):
    return resolve(op, "jax")(node, _decoded(node, env))


@dataclass
class QJaxContext:
    """Per-build context the qjax op impls read from the env: the active
    working point and the writer's precision/calibration state."""

    writer: "QJaxWriter"
    bits: int

    def weight_bits(self, node: Optional[Node]) -> int:
        """Effective view bits: the runtime working point, capped by the
        node's per-layer weight precision when the precision pass assigned
        one below it (a W4 layer stays W4 even at the W8 point)."""
        dt = self.writer.node_dt(node)
        if dt.weight_bits < 32:
            return min(self.bits, dt.weight_bits)
        return self.bits

    def act_qt(self, name: str, node: Optional[Node]
               ) -> Optional[Tuple[int, int, int]]:
        """Static epilogue spec for the output's fixed-point activation
        quant — same qtype ``_act_q`` would use, fused into the kernel."""
        dt = self.writer.node_dt(node)
        if dt.act_bits >= 32:
            return None
        qt = fixed_for_range(dt.act_bits,
                             self.writer.act_ranges.get(name, 8.0))
        return (qt.frac, qt.qmin, qt.qmax)

    def code_qt(self, name: str, node: Optional[Node]) -> Optional[QType]:
        """The output FIFO's int8 activation-code qtype when this node should
        emit codes (fully-integer mode, activation precision fits int8)."""
        if not self.writer.int8_act_on:
            return None
        dt = self.writer.node_dt(node)
        if dt.act_bits > 8:
            return None
        return act_code_qtype(dt.act_bits,
                              self.writer.act_ranges.get(name, 8.0))

    def weight_codes(self, w: PackedTensor, bits: int):
        """(codes argument, packed flag) for the kernels: the sub-byte packed
        view at W4/W2 when packed storage is on, else the int8 master."""
        if self.writer.packed_storage and bits in SUB_BYTE_BITS:
            return w.packed_view(bits), True
        return w.codes_2d(), False

    def mark_fused(self, name: str) -> None:
        self.writer._fused_act.add(name)


# ---------------------------------------------------------------------------
# im2col (the streaming conv as a packed matmul)
# ---------------------------------------------------------------------------

def _pad_amounts(h: int, k: int, s: int, pads) -> Tuple[int, Tuple[int, int]]:
    """(out_dim, (lo, hi)) for one spatial dim — matches XLA's SAME/VALID."""
    if pads == "SAME":
        oh = -(-h // s)
        pad = max((oh - 1) * s + k - h, 0)
        return oh, (pad // 2, pad - pad // 2)
    if pads == "VALID":
        return (h - k) // s + 1, (0, 0)
    lo, hi = pads
    return (h + lo + hi - k) // s + 1, (int(lo), int(hi))


def im2col(x, kh: int, kw: int, strides, pads):
    """x: (B, H, W, C) -> patches (B, OH, OW, kh*kw*C), dy-major then dx then
    channel — the order HWIO weights flatten to for the (K, N) matmul.  Works
    on float tensors and on int8 code tensors alike (zero padding is the zero
    code)."""
    sh, sw = strides
    B, H, W, C = x.shape
    oh, (ph0, ph1) = _pad_amounts(H, kh, sh, pads if isinstance(pads, str)
                                  else pads[0])
    ow, (pw0, pw1) = _pad_amounts(W, kw, sw, pads if isinstance(pads, str)
                                  else pads[1])
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy:dy + sh * (oh - 1) + 1:sh,
                           dx:dx + sw * (ow - 1) + 1:sw, :])
    return jnp.concatenate(cols, axis=-1), oh, ow


# ---------------------------------------------------------------------------
# qjax op implementations
# ---------------------------------------------------------------------------

def _int8_act_gemm(ctx: QJaxContext, node: Node, x: ActCode, w: PackedTensor,
                   bias, relu: bool):
    """The fully-integer Gemm lowering: producer codes in, consumer codes out
    (float only when the output has no int8 code qtype)."""
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    oqt = ctx.code_qt(out, node)
    aqt = (oqt.frac, oqt.qmin, oqt.qmax) if oqt is not None \
        else ctx.act_qt(out, node)
    codes_arg, packed = ctx.weight_codes(w, bits)
    y = qmatmul_int8_act(x.codes, x.qt.scale, codes_arg, w.scale_1d(), bias,
                         bits=bits, relu=relu, act_qt=aqt,
                         out_code=oqt is not None, packed=packed,
                         interpret=ctx.writer.interpret,
                         use_kernel=ctx.writer.kernel_enabled(),
                         out_dtype=jnp.float32)
    ctx.mark_fused(out)
    return ActCode(y, oqt) if oqt is not None else y


def _qgemm_node(node: Node, env, relu: bool = False):
    """Shared Gemm/MatMul/FusedGemm lowering; None when the weight is not
    packed (activation×activation matmul, no context) so the caller falls
    back."""
    ctx = env.get(QCTX)
    w = env.get(node.inputs[1])
    if ctx is None or not isinstance(w, PackedTensor):
        return None
    x = env[node.inputs[0]]
    bias = env[node.inputs[2]] if len(node.inputs) > 2 else None
    if isinstance(x, ActCode):
        return _int8_act_gemm(ctx, node, x, w, bias, relu)
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    aqt = ctx.act_qt(out, node)
    codes_arg, packed = ctx.weight_codes(w, bits)
    y = qgemm(x, codes_arg, w.scale_1d(), bias,
              bits=bits, relu=relu, act_qt=aqt, packed=packed,
              interpret=ctx.writer.interpret,
              use_kernel=ctx.writer.kernel_enabled())
    ctx.mark_fused(out)
    return y


@register_op("Gemm", target="qjax")
def _op_gemm_qjax(node: Node, env):
    y = _qgemm_node(node, env)
    return y if y is not None else _jax_fallback("Gemm", node, env)


@register_op("MatMul", target="qjax")
def _op_matmul_qjax(node: Node, env):
    y = _qgemm_node(node, env)
    return y if y is not None else _jax_fallback("MatMul", node, env)


@register_op("FusedGemm", target="qjax")
def _op_fused_gemm_qjax(node: Node, env):
    y = _qgemm_node(node, env, relu=bool(node.attrs.get("relu")))
    return y if y is not None else _jax_fallback("FusedGemm", node, env)


def _int8_act_conv(ctx: QJaxContext, node: Node, x: ActCode, w: PackedTensor,
                   bias, relu: bool, strides, pads):
    """Fully-integer conv: integer MACs over the producer's codes.

    Kernel path: im2col on the code tensor + ``qmatmul_int8_act`` (the fused
    epilogue re-quantizes to the consumer's code).  Ref path: when the
    reduction is small enough that integer accumulation is exact in f32
    (:func:`exact_in_f32` — every MNIST/MLP layer qualifies), an XLA conv
    over the f32-cast codes produces the SAME integer accumulator at XLA-conv
    speed; otherwise it falls back to im2col + the int32 oracle."""
    kh, kw, _, cout = w.codes.shape
    k_dim = kh * kw * w.codes.shape[2]
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    oqt = ctx.code_qt(out, node)
    aqt = (oqt.frac, oqt.qmin, oqt.qmax) if oqt is not None \
        else ctx.act_qt(out, node)
    if ctx.writer.kernel_enabled() or not exact_in_f32(k_dim):
        patches, oh, ow = im2col(x.codes, kh, kw, strides, pads)
        codes_arg, packed = ctx.weight_codes(w, bits)
        y = qmatmul_int8_act(patches.reshape(-1, patches.shape[-1]),
                             x.qt.scale, codes_arg, w.scale_1d(), bias,
                             bits=bits, relu=relu, act_qt=aqt,
                             out_code=oqt is not None, packed=packed,
                             interpret=ctx.writer.interpret,
                             use_kernel=ctx.writer.kernel_enabled(),
                             out_dtype=jnp.float32)
        y = y.reshape(x.codes.shape[0], oh, ow, cout)
    else:
        acc = jax.lax.conv_general_dilated(
            x.codes.astype(jnp.float32), w.view(bits).astype(jnp.float32),
            window_strides=strides, padding=pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        # same scale fold as the ops wrapper: producer scale (a power of two)
        # into the per-channel weight scale — bit-identical rounding
        y = acc * (w.scale_1d() * x.qt.scale).reshape(1, 1, 1, -1)
        if bias is not None:
            y = y + bias
        from repro.kernels.qmatmul.ref import epilogue_code_ref
        if oqt is not None:
            y = epilogue_code_ref(y, relu, aqt).astype(jnp.int8)
        else:
            y = epilogue_ref(y, relu, aqt)
    ctx.mark_fused(out)
    return ActCode(y, oqt) if oqt is not None else y


def _qconv_node(node: Node, env, relu: bool):
    ctx = env.get(QCTX)
    w = env.get(node.inputs[1])
    if ctx is None or not isinstance(w, PackedTensor):
        return None
    x = env[node.inputs[0]]
    bias = env[node.inputs[2]] if len(node.inputs) > 2 else None
    kh, kw, _, cout = w.codes.shape
    strides = tuple(node.attrs.get("strides", (1, 1)))
    pads = node.attrs.get("pads", "SAME")
    if isinstance(x, ActCode):
        return _int8_act_conv(ctx, node, x, w, bias, relu, strides, pads)
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    aqt = ctx.act_qt(out, node)
    if ctx.writer.kernel_enabled():
        # im2col + dequant-fused matmul; ReLU and the consumer-side
        # activation quant ride in the kernel epilogue
        patches, oh, ow = im2col(x, kh, kw, strides, pads)
        codes_arg, packed = ctx.weight_codes(w, bits)
        y = qgemm(patches.reshape(-1, patches.shape[-1]),
                  codes_arg, w.scale_1d(), bias,
                  bits=bits, relu=relu, act_qt=aqt, packed=packed,
                  interpret=ctx.writer.interpret, use_kernel=True)
        y = y.reshape(x.shape[0], oh, ow, cout)
    else:
        # ref path: XLA conv over the dequantized view — codes are trace
        # constants, so the dequant folds into a constant f32 weight
        wf = w.dequant(bits, jnp.float32)
        y = jax.lax.conv_general_dilated(
            x, wf, window_strides=strides, padding=pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if bias is not None:
            y = y + bias
        y = epilogue_ref(y, relu, aqt)
    ctx.mark_fused(out)
    return y


def _qdwconv_node(node: Node, env, relu: bool):
    """DepthwiseConv/FusedDepthwiseConv lowering.

    ``dw_mode="direct"`` (default) calls the :mod:`repro.kernels.qconv_dw`
    family — no patch tensor, channel-parallel window MACs, the producer's
    int8 codes consumed directly and the consumer's codes emitted from the
    fused epilogue, sub-byte W4/W2 streamed at the small depthwise packing
    alignment.  ``dw_mode="im2col"`` runs the legacy lowering the direct
    kernels replace — the depthwise taps block-diagonally expanded to a dense
    (kh*kw*C, C) matrix through im2col + qgemm — kept as the differential
    baseline (bit-exact vs direct in fully-integer mode: same integer
    accumulators, same power-of-two folds) and the benchmark's foil."""
    ctx = env.get(QCTX)
    w = env.get(node.inputs[1])
    if ctx is None or not isinstance(w, PackedTensor):
        return None
    x = env[node.inputs[0]]
    bias = env[node.inputs[2]] if len(node.inputs) > 2 else None
    kh, kw, _, c = w.codes.shape
    strides = tuple(int(s) for s in node.attrs.get("strides", (1, 1)))
    pads = normalize_pads(node.attrs.get("pads", "SAME"))
    out = node.outputs[0]
    bits = ctx.weight_bits(node)
    oqt = ctx.code_qt(out, node) if isinstance(x, ActCode) else None
    aqt = (oqt.frac, oqt.qmin, oqt.qmax) if oqt is not None \
        else ctx.act_qt(out, node)

    if ctx.writer.dw_mode == "im2col":
        # differential baseline: dense block-diagonal expansion, patch blowup
        dense = expand_dw_codes(jnp.asarray(w.codes))
        if isinstance(x, ActCode):
            patches, oh, ow = im2col(x.codes, kh, kw, strides, pads)
            y = qmatmul_int8_act(patches.reshape(-1, patches.shape[-1]),
                                 x.qt.scale, dense, w.scale_1d(), bias,
                                 bits=bits, relu=relu, act_qt=aqt,
                                 out_code=oqt is not None,
                                 interpret=ctx.writer.interpret,
                                 use_kernel=ctx.writer.kernel_enabled(),
                                 out_dtype=jnp.float32)
            y = y.reshape(x.codes.shape[0], oh, ow, c)
        else:
            patches, oh, ow = im2col(x, kh, kw, strides, pads)
            y = qgemm(patches.reshape(-1, patches.shape[-1]), dense,
                      w.scale_1d(), bias, bits=bits, relu=relu, act_qt=aqt,
                      interpret=ctx.writer.interpret,
                      use_kernel=ctx.writer.kernel_enabled())
            y = y.reshape(x.shape[0], oh, ow, c)
    else:
        if ctx.writer.packed_storage and bits in SUB_BYTE_BITS:
            codes_arg, packed = w.packed_view(bits, align=DW_PACK_ALIGN), True
        else:
            codes_arg, packed = w.codes_2d(), False
        common = dict(kh=kh, kw=kw, strides=strides, pads=pads, bits=bits,
                      relu=relu, act_qt=aqt, packed=packed,
                      interpret=ctx.writer.interpret,
                      use_kernel=ctx.writer.kernel_enabled())
        if isinstance(x, ActCode):
            y = qconv_dw_int8_act(x.codes, x.qt.scale, codes_arg,
                                  w.scale_1d(), bias,
                                  out_code=oqt is not None,
                                  out_dtype=jnp.float32, **common)
        else:
            y = qconv_dw(x, codes_arg, w.scale_1d(), bias, **common)
    ctx.mark_fused(out)
    return ActCode(y, oqt) if oqt is not None else y


@register_op("DepthwiseConv", target="qjax")
def _op_dwconv_qjax(node: Node, env):
    y = _qdwconv_node(node, env, relu=False)
    return y if y is not None else _jax_fallback("DepthwiseConv", node, env)


@register_op("FusedDepthwiseConv", target="qjax")
def _op_fused_dwconv_qjax(node: Node, env):
    y = _qdwconv_node(node, env, relu=bool(node.attrs.get("relu")))
    return y if y is not None else _jax_fallback("FusedDepthwiseConv", node,
                                                 env)


@register_op("Conv", target="qjax")
def _op_conv_qjax(node: Node, env):
    y = _qconv_node(node, env, relu=False)
    return y if y is not None else _jax_fallback("Conv", node, env)


@register_op("FusedConv", target="qjax")
def _op_fused_conv_qjax(node: Node, env):
    y = _qconv_node(node, env, relu=bool(node.attrs.get("relu")))
    return y if y is not None else _jax_fallback("FusedConv", node, env)


# -- code-domain actors: exact integer semantics, no dequant ----------------

@register_op("MaxPool", target="qjax")
def _op_maxpool_qjax(node: Node, env):
    x = env[node.inputs[0]]
    if not isinstance(x, ActCode):
        return _jax_fallback("MaxPool", node, env)
    k = tuple(node.attrs["kernel_shape"])
    s = tuple(node.attrs.get("strides", k))
    # max commutes with the monotone positive-scale dequant: pooling the int8
    # codes IS pooling the values
    codes = jax.lax.reduce_window(
        x.codes, jnp.int8(jnp.iinfo(jnp.int8).min), jax.lax.max,
        (1, *k, 1), (1, *s, 1), "VALID")
    return ActCode(codes, x.qt)


@register_op("Relu", target="qjax")
def _op_relu_qjax(node: Node, env):
    x = env[node.inputs[0]]
    if not isinstance(x, ActCode):
        return _jax_fallback("Relu", node, env)
    # relu(c * s) == max(c, 0) * s for s > 0, and 0 is exactly the zero code
    return ActCode(jnp.maximum(x.codes, 0), x.qt)


@register_op("Flatten", target="qjax")
def _op_flatten_qjax(node: Node, env):
    x = env[node.inputs[0]]
    if not isinstance(x, ActCode):
        return _jax_fallback("Flatten", node, env)
    return ActCode(x.codes.reshape(x.codes.shape[0], -1), x.qt)


# ---------------------------------------------------------------------------
# the writer
# ---------------------------------------------------------------------------

class QJaxWriter(JaxWriter):
    """Packed-weight quantized execution engine (see module docstring).

    Writer kwargs (``DesignFlow.run(writer_kwargs={"qjax": {...}})``):

    * ``use_kernel`` — None (auto: Pallas on TPU, jnp ref elsewhere), True
      (force the kernel, interpret-mode off-TPU), False (force the ref path);
    * ``interpret``  — override for the Pallas interpret flag (None = auto);
    * ``default_bits`` — working point used when ``build(bits=None)``;
    * ``int8_act`` — None (auto: fully-integer inter-layer dataflow whenever
      the default activation precision fits int8), True/False to force;
    * ``packed_weights`` — None (auto: sub-byte packed W4/W2 buffers on the
      kernel path), True/False to force (the ref path unpacks at trace time,
      so forcing it on stays bit-exact);
    * ``dw_mode`` — ``"direct"`` (default: the :mod:`repro.kernels.qconv_dw`
      family, no im2col materialization) or ``"im2col"`` (the legacy dense
      block-diagonal lowering, kept as the differential baseline).
    """

    target = "qjax"

    def __init__(self, graph: Graph,
                 dtconfig: Optional[DatatypeConfig] = None,
                 act_ranges: Optional[Dict[str, float]] = None, *,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 default_bits: Optional[int] = None,
                 int8_act: Optional[bool] = None,
                 packed_weights: Optional[bool] = None,
                 dw_mode: str = "direct"):
        if dw_mode not in ("direct", "im2col"):
            raise ValueError(f"dw_mode must be 'direct' or 'im2col', "
                             f"got {dw_mode!r}")
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._default_bits = default_bits
        self._int8_act = int8_act
        self._packed_weights = packed_weights
        self.dw_mode = dw_mode
        super().__init__(graph, dtconfig, act_ranges)

    # -- packed weights ------------------------------------------------------
    def _prepare_weights(self) -> Dict[str, Any]:
        """Quantize once to shared int8 master codes; the active ``bits``
        view is selected per build, not here."""
        self.packed = PackedWeights.from_initializers(self.graph.initializers)
        out: Dict[str, Any] = dict(self.packed.passthrough)
        out.update(self.packed.tensors)
        return out

    @property
    def default_bits(self) -> int:
        if self._default_bits is not None:
            return int(self._default_bits)
        if self.dt.weight_bits < 32:
            return min(8, self.dt.weight_bits)
        return 8

    def weight_bytes(self) -> int:
        """Bytes of the shared master buffer (all working points included)."""
        return self.packed.code_bytes()

    # -- backend routing -----------------------------------------------------
    def kernel_enabled(self) -> bool:
        if self.use_kernel is not None:
            return bool(self.use_kernel)
        return not resolve_interpret(self.interpret)

    @property
    def qpath(self) -> str:
        """Which execution path this writer resolves to on this backend."""
        return "pallas" if self.kernel_enabled() else "ref"

    @property
    def int8_act_on(self) -> bool:
        """Fully-integer inter-layer dataflow: auto-on when the default
        working point's activation precision fits int8 codes."""
        if self._int8_act is not None:
            return bool(self._int8_act)
        return self.dt.act_bits <= 8

    @property
    def packed_storage(self) -> bool:
        """Sub-byte packed W4/W2 weight residency (auto: kernel path only —
        the ref path's dequant const-folds to f32 regardless)."""
        if self._packed_weights is not None:
            return bool(self._packed_weights)
        return self.kernel_enabled()

    # -- fully-integer dataflow ---------------------------------------------
    def _act_q(self, name: str, x, node: Optional[Node] = None):
        """In fully-integer mode the FIFO boundary *encodes* to int8 codes
        (graph inputs; outputs of ops without an integer impl) instead of
        fake-quantizing in f32 — downstream hot ops then consume codes.
        Values already on a code grid (ActCode, fused epilogues) pass
        through untouched."""
        if isinstance(x, ActCode):
            return x
        if (self.int8_act_on and name not in self._fused_act
                and hasattr(x, "dtype")
                and jnp.issubdtype(x.dtype, jnp.floating)):
            dt = self.node_dt(node)
            if dt.act_bits <= 8:
                qt = act_code_qtype(dt.act_bits, self.act_ranges.get(name, 8.0))
                return ActCode.encode(x, qt)
        return super()._act_q(name, x, node)

    def _materialize(self, value):
        """Graph outputs are the one place the integer hot path materializes
        floats (the value is identical to the f32 fake-quant the float mode
        would have produced — same grid, same code)."""
        if isinstance(value, ActCode):
            return value.to_float()
        return value

    def op_impl(self, op: str) -> Callable:
        """Ops registered for the qjax target are code-aware; anything else
        gets the decode shim so reference impls run mid-integer-graph."""
        impl = super().op_impl(op)
        if op in OP_REGISTRY.get(self.target, {}):
            return impl

        def shim(node, env, _impl=impl):
            return _impl(node, _decoded(node, env))

        return shim

    # -- build ---------------------------------------------------------------
    def _env_seed(self, bits: Optional[int] = None) -> Dict[str, Any]:
        env: Dict[str, Any] = dict(self.weights)
        env[QCTX] = QJaxContext(self, self.default_bits if bits is None
                                else int(bits))
        return env

    def build_batched(self, max_entries: int = 8,
                      on_compile: Optional[Callable] = None,
                      bits: Optional[int] = None) -> BatchedExecutable:
        exe = super().build_batched(max_entries=max_entries,
                                    on_compile=on_compile,
                                    bits=self.default_bits if bits is None
                                    else int(bits))
        exe.packed = self.packed   # buffer-identity accounting in tests/serve
        return exe
