"""Writer 1: IR -> pure-JAX callable (the reference "software" target).

Faithful to the paper's HLS flow semantics: weights are fake-quantized to Wy
at build time; the activation stream is quantized to Dx at every actor
boundary (the fixed-point dataflow between streaming blocks).  ``capture=True``
returns every intermediate tensor (used for PTQ calibration).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.ir import Graph, Node
from repro.quant.fixedpoint import fake_quant
from repro.quant.qtypes import DatatypeConfig, QType, fixed_for_range
from repro.quant.ptq import weight_qtype


def _op_conv(node: Node, env):
    x, w, b = (env[i] for i in node.inputs)
    pads = node.attrs.get("pads", "SAME")
    strides = tuple(node.attrs.get("strides", (1, 1)))
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _op_maxpool(node: Node, env):
    x = env[node.inputs[0]]
    k = tuple(node.attrs["kernel_shape"])
    s = tuple(node.attrs.get("strides", k))
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, *k, 1), (1, *s, 1), "VALID")


def _op_batchnorm(node: Node, env):
    x, scale, bias, mean, var = (env[i] for i in node.inputs)
    eps = node.attrs.get("epsilon", 1e-5)
    inv = scale * jax.lax.rsqrt(var + eps)
    return x * inv + (bias - mean * inv)


def _op_relu(node: Node, env):
    return jax.nn.relu(env[node.inputs[0]])


def _op_gemm(node: Node, env):
    x, w = env[node.inputs[0]], env[node.inputs[1]]
    y = x @ w
    if len(node.inputs) > 2:
        y = y + env[node.inputs[2]]
    return y


def _op_matmul(node: Node, env):
    return env[node.inputs[0]] @ env[node.inputs[1]]


def _op_add(node: Node, env):
    return env[node.inputs[0]] + env[node.inputs[1]]


def _op_flatten(node: Node, env):
    x = env[node.inputs[0]]
    return x.reshape(x.shape[0], -1)


def _op_reshape(node: Node, env):
    return env[node.inputs[0]].reshape(node.attrs["shape"])


def _op_softmax(node: Node, env):
    return jax.nn.softmax(env[node.inputs[0]], axis=-1)


def _op_identity(node: Node, env):
    return env[node.inputs[0]]


OP_IMPLS: Dict[str, Callable] = {
    "Conv": _op_conv, "MaxPool": _op_maxpool, "BatchNormalization": _op_batchnorm,
    "Relu": _op_relu, "Gemm": _op_gemm, "MatMul": _op_matmul, "Add": _op_add,
    "Flatten": _op_flatten, "Reshape": _op_reshape, "Softmax": _op_softmax,
    "Identity": _op_identity,
}


class JaxWriter:
    """Builds an executable from the IR.  Subclasses override ``op_impl`` to
    retarget individual actors (StreamWriter swaps Conv for the Pallas
    line-buffer kernel)."""

    target = "jax"

    def __init__(self, graph: Graph,
                 dtconfig: Optional[DatatypeConfig] = None,
                 act_ranges: Optional[Dict[str, float]] = None):
        graph.validate()
        self.graph = graph
        self.dt = dtconfig or DatatypeConfig(32, 32)
        self.act_ranges = act_ranges or {}
        self.weights = self._prepare_weights()

    # -- weights (the Weight/Bias actors) ----------------------------------
    def _prepare_weights(self) -> Dict[str, jax.Array]:
        out = {}
        for name, w in self.graph.initializers.items():
            w = jnp.asarray(w)
            if self.dt.weight_bits < 32 and w.ndim >= 2:
                out[name] = fake_quant(w, weight_qtype(w, self.dt.weight_bits))
            else:
                out[name] = w
        return out

    def op_impl(self, op: str) -> Callable:
        return OP_IMPLS[op]

    def _act_q(self, name: str, x):
        if self.dt.act_bits >= 32 or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        qt = fixed_for_range(self.dt.act_bits, self.act_ranges.get(name, 8.0))
        return fake_quant(x, qt)

    # -- build --------------------------------------------------------------
    def build(self, capture: bool = False) -> Callable:
        order = self.graph.topo_order()
        in_names = [t.name for t in self.graph.inputs]

        def run(*inputs):
            env: Dict[str, Any] = dict(self.weights)
            for n, x in zip(in_names, inputs):
                env[n] = self._act_q(n, x)
            for node in order:
                y = self.op_impl(node.op)(node, env)
                env[node.outputs[0]] = self._act_q(node.outputs[0], y)
            outs = tuple(env[o] for o in self.graph.outputs)
            if capture:
                return outs[0] if len(outs) == 1 else outs, env
            return outs[0] if len(outs) == 1 else outs

        return run

    def build_jit(self) -> Callable:
        return jax.jit(self.build())
