"""Writer 1: IR -> pure-JAX callable (the reference "software" target).

Faithful to the paper's HLS flow semantics: weights are fake-quantized to Wy
at build time; the activation stream is quantized to Dx at every actor
boundary (the fixed-point dataflow between streaming blocks).  ``capture=True``
returns every intermediate tensor (used for PTQ calibration).

Post pass-pipeline refactor the writer is a thin interpreter over the
annotated IR:

* actor implementations come from the target-keyed op registry
  (:mod:`repro.core.writers.registry`) instead of a hardcoded dict — a
  subclass only sets ``target`` and registers the ops it retargets;
* precision is per layer: a node annotated with ``Node.dtconfig`` (written by
  the precision-assignment pass) quantizes its weights and output FIFOs with
  its own ``Dx-Wy`` point, falling back to the writer's default config;
* every node output is bound into the environment (multi-output ops such as
  ``Split`` work; previously only ``outputs[0]`` was bound);
* ``build_batched`` wraps the interpreter in a :class:`BatchedExecutable` —
  a batch-polymorphic artifact that re-jits per concrete input signature
  with an LRU of traced shapes, so one compiled graph (symbolic leading dim,
  see :data:`repro.core.ir.BATCH`) serves batch 1..N without recompiling.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ir import Graph, Node
from repro.core.writers.registry import OP_REGISTRY, registered_ops, resolve
from repro.quant.fixedpoint import fake_quant
from repro.quant.qtypes import DatatypeConfig, fixed_for_range
from repro.quant.ptq import effective_weight_dt, weight_qtype

# Backward-compatible alias: the reference op table (live view of the "jax"
# registry entries).
OP_IMPLS: Dict[str, Callable] = OP_REGISTRY["jax"]

Signature = Tuple[Tuple[Tuple[int, ...], str], ...]


class BatchedExecutable:
    """Batch-polymorphic compiled artifact.

    Wraps a writer's interpreter; each call dispatches on the concrete input
    signature (shapes + dtypes) and re-jits on a miss, keeping at most
    ``max_entries`` traced executables in an LRU.  Each signature gets its
    *own* ``jax.jit`` object so eviction actually releases the trace — one
    shared jit would grow an unbounded internal shape cache, which is what
    this class exists to bound for long-running serving.
    """

    def __init__(self, fn: Callable, max_entries: int = 8,
                 compile_fn: Optional[Callable[[Signature], Callable]] = None,
                 on_compile: Optional[Callable[[Signature], None]] = None,
                 bits: Optional[int] = None):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self._fn = fn
        self._compile = compile_fn or (lambda sig: jax.jit(fn))
        self._cache: "OrderedDict[Signature, Callable]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        # serving telemetry hook: called with the signature on every trace
        # miss (a scheduler can count retraces per bucket / alert on churn)
        self.on_compile = on_compile
        # weight working point this artifact executes at (packed-weight
        # writers stamp it; AccelServer telemetry attributes batches to it)
        self.bits = bits

    @staticmethod
    def signature(*inputs) -> Signature:
        return tuple((tuple(jnp.shape(x)), str(jnp.result_type(x)))
                     for x in inputs)

    def executable_for(self, *inputs) -> Callable:
        """The compiled executable serving these inputs' signature."""
        sig = self.signature(*inputs)
        exe = self._cache.get(sig)
        if exe is None:
            self.misses += 1
            if self.on_compile is not None:
                self.on_compile(sig)
            exe = self._compile(sig)
            self._cache[sig] = exe
            while len(self._cache) > self.max_entries:
                self._cache.popitem(last=False)
        else:
            self.hits += 1
            self._cache.move_to_end(sig)
        return exe

    def __call__(self, *inputs):
        return self.executable_for(*inputs)(*inputs)

    @property
    def cached_signatures(self) -> Tuple[Signature, ...]:
        return tuple(self._cache)

    @property
    def cached_batches(self) -> Tuple[int, ...]:
        """Leading-dim sizes currently resident (serving telemetry)."""
        return tuple(sig[0][0][0] for sig in self._cache if sig and sig[0][0])

    def has_batch(self, batch: int) -> bool:
        """True when a trace for this leading-dim size is resident — the
        scheduler's bucket policy prefers such sizes (hit beats retrace)."""
        return batch in self.cached_batches

    def telemetry(self) -> Dict[str, Any]:
        """Hit/miss counters + resident traces, for serving dashboards."""
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "cached_batches": self.cached_batches,
            "capacity": self.max_entries,
            "bits": self.bits,
        }


class JaxWriter:
    """Builds an executable from the (pass-annotated) IR.  Subclasses set
    ``target`` and register retargeted actors in the op registry (StreamWriter
    swaps Conv/FusedConv for the Pallas line-buffer kernel)."""

    target = "jax"

    def __init__(self, graph: Graph,
                 dtconfig: Optional[DatatypeConfig] = None,
                 act_ranges: Optional[Dict[str, float]] = None):
        graph.validate()
        self.graph = graph
        self.dt = dtconfig or DatatypeConfig(32, 32)
        self.act_ranges = act_ranges or {}
        # output names whose activation quant an op impl already applied in
        # its (fused) epilogue — _act_q skips them instead of re-rounding
        self._fused_act: set = set()
        self.weights = self._prepare_weights()

    # -- per-layer precision -------------------------------------------------
    def node_dt(self, node: Optional[Node]) -> DatatypeConfig:
        if node is not None and node.dtconfig is not None:
            return node.dtconfig
        return self.dt

    # -- weights (the Weight/Bias actors) ----------------------------------
    def _prepare_weights(self) -> Dict[str, jax.Array]:
        """Fake-quantize each initializer at its *consumer's* weight
        precision (per-layer Wy); 1-D tensors (biases, norm stats) pass
        through in float."""
        out = {}
        for name, w in self.graph.initializers.items():
            w = jnp.asarray(w)
            dt = effective_weight_dt(self.graph, name, self.dt)
            if dt.weight_bits < 32 and w.ndim >= 2:
                out[name] = fake_quant(w, weight_qtype(w, dt.weight_bits))
            else:
                out[name] = w
        return out

    def op_impl(self, op: str) -> Callable:
        return resolve(op, self.target)

    def op_table(self) -> Dict[str, Callable]:
        return registered_ops(self.target)

    def _act_q(self, name: str, x, node: Optional[Node] = None):
        if name in self._fused_act:
            return x   # an op epilogue already applied this tensor's quant
        bits = self.node_dt(node).act_bits
        if bits >= 32 or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        qt = fixed_for_range(bits, self.act_ranges.get(name, 8.0))
        return fake_quant(x, qt)

    def _materialize(self, value):
        """Hook: convert one graph *output* to its caller-facing form.  The
        reference writers return values as-is; the packed-weight writer's
        fully-integer mode decodes inter-layer int8 activation codes to float
        here — the ONE place the hot path materializes floats."""
        return value

    # -- build --------------------------------------------------------------
    def _env_seed(self, bits: Optional[int] = None) -> Dict[str, Any]:
        """The environment a built executable starts from.  ``bits`` selects
        the weight working point for writers whose weights are packed master
        codes (target "qjax"); the reference writers bake precision into
        ``self.weights`` at construction and reject it."""
        if bits is not None:
            raise ValueError(
                f"writer target {self.target!r} bakes weight precision at "
                "build; bits= is a parameter of packed-weight writers "
                "(target 'qjax')")
        return self.weights

    def build(self, capture: bool = False,
              bits: Optional[int] = None) -> Callable:
        order = self.graph.topo_order()
        in_names = [t.name for t in self.graph.inputs]
        impls = [(node, self.op_impl(node.op)) for node in order]
        seed = self._env_seed(bits)

        def run(*inputs):
            env: Dict[str, Any] = dict(seed)
            for n, x in zip(in_names, inputs):
                env[n] = self._act_q(n, x)
            for node, impl in impls:
                y = impl(node, env)
                outs = y if isinstance(y, tuple) else (y,)
                for oname, oval in zip(node.outputs, outs):
                    env[oname] = self._act_q(oname, oval, node)
            outs = tuple(self._materialize(env[o]) for o in self.graph.outputs)
            if capture:
                return outs[0] if len(outs) == 1 else outs, env
            return outs[0] if len(outs) == 1 else outs

        return run

    def build_jit(self) -> Callable:
        return jax.jit(self.build())

    def build_batched(self, max_entries: int = 8,
                      on_compile: Optional[Callable] = None,
                      bits: Optional[int] = None) -> BatchedExecutable:
        """Batch-polymorphic executable: one artifact, any leading-dim size,
        LRU of per-signature traces (see :class:`BatchedExecutable`);
        ``on_compile`` observes every trace miss (serving telemetry).
        ``bits`` selects the weight working point on packed-weight writers
        and is stamped on the artifact for batch attribution."""
        return BatchedExecutable(self.build(bits=bits), max_entries=max_entries,
                                 on_compile=on_compile, bits=bits)
