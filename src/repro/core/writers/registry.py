"""Target-keyed op registry shared by every writer.

The writers used to hold hardcoded ``{op: impl}`` dicts; after the pass-based
compiler refactor they all resolve actor implementations here instead.  An
implementation is registered for an ``(op, target)`` pair; lookup falls back
to the ``"jax"`` reference target, so a writer only registers the ops it
actually retargets (StreamWriter: Conv/FusedConv onto the Pallas line-buffer
kernel; DistWriter: nothing — it inherits the reference impls and changes the
partitioning instead).

An impl has signature ``impl(node, env) -> tensor | tuple[tensor, ...]`` where
``env`` maps tensor names to values.  Multi-output ops return a tuple aligned
with ``node.outputs``.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.ir import Node

OP_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register_op(op: str, target: str = "jax"):
    def deco(fn: Callable) -> Callable:
        OP_REGISTRY.setdefault(target, {})[op] = fn
        return fn
    return deco


def resolve(op: str, target: str = "jax") -> Callable:
    impl = OP_REGISTRY.get(target, {}).get(op)
    if impl is None:
        impl = OP_REGISTRY.get("jax", {}).get(op)
    if impl is None:
        raise KeyError(f"no implementation for op {op!r} (target {target!r})")
    return impl


def registered_ops(target: str = "jax") -> Dict[str, Callable]:
    """Effective op table for a target (jax fallbacks merged in)."""
    table = dict(OP_REGISTRY.get("jax", {}))
    if target != "jax":
        table.update(OP_REGISTRY.get(target, {}))
    return table


# ---------------------------------------------------------------------------
# Reference ("jax") implementations
# ---------------------------------------------------------------------------

@register_op("Conv")
def _op_conv(node: Node, env):
    x, w = env[node.inputs[0]], env[node.inputs[1]]
    pads = node.attrs.get("pads", "SAME")
    strides = tuple(node.attrs.get("strides", (1, 1)))
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if len(node.inputs) > 2:
        y = y + env[node.inputs[2]]
    return y


@register_op("FusedConv")
def _op_fused_conv(node: Node, env):
    """Conv with BatchNormalization folded into W/b by the fusion pass;
    attrs["relu"] applies the folded trailing activation."""
    y = _op_conv(node, env)
    if node.attrs.get("relu"):
        y = jax.nn.relu(y)
    return y


@register_op("DepthwiseConv")
def _op_depthwise_conv(node: Node, env):
    x, w = env[node.inputs[0]], env[node.inputs[1]]
    pads = node.attrs.get("pads", "SAME")
    strides = tuple(node.attrs.get("strides", (1, 1)))
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    if len(node.inputs) > 2:
        y = y + env[node.inputs[2]]
    return y


@register_op("FusedDepthwiseConv")
def _op_fused_depthwise_conv(node: Node, env):
    """DepthwiseConv with BN folded into W/b by the fusion pass;
    attrs["relu"] applies the folded trailing activation."""
    y = _op_depthwise_conv(node, env)
    if node.attrs.get("relu"):
        y = jax.nn.relu(y)
    return y


@register_op("MaxPool")
def _op_maxpool(node: Node, env):
    x = env[node.inputs[0]]
    k = tuple(node.attrs["kernel_shape"])
    s = tuple(node.attrs.get("strides", k))
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, *k, 1), (1, *s, 1), "VALID")


@register_op("BatchNormalization")
def _op_batchnorm(node: Node, env):
    x, scale, bias, mean, var = (env[i] for i in node.inputs)
    eps = node.attrs.get("epsilon", 1e-5)
    inv = scale * jax.lax.rsqrt(var + eps)
    return x * inv + (bias - mean * inv)


@register_op("Relu")
def _op_relu(node: Node, env):
    return jax.nn.relu(env[node.inputs[0]])


@register_op("Gemm")
def _op_gemm(node: Node, env):
    x, w = env[node.inputs[0]], env[node.inputs[1]]
    y = x @ w
    if len(node.inputs) > 2:
        y = y + env[node.inputs[2]]
    return y


@register_op("FusedGemm")
def _op_fused_gemm(node: Node, env):
    """Gemm with a trailing Relu folded in by the fusion pass — the MLP
    (Table I) analogue of FusedConv: one actor, one FIFO hop, and the qjax
    target runs the ReLU inside the kernel epilogue."""
    y = _op_gemm(node, env)
    if node.attrs.get("relu"):
        y = jax.nn.relu(y)
    return y


@register_op("MatMul")
def _op_matmul(node: Node, env):
    return env[node.inputs[0]] @ env[node.inputs[1]]


@register_op("Add")
def _op_add(node: Node, env):
    return env[node.inputs[0]] + env[node.inputs[1]]


@register_op("Flatten")
def _op_flatten(node: Node, env):
    x = env[node.inputs[0]]
    return x.reshape(x.shape[0], -1)


@register_op("Reshape")
def _op_reshape(node: Node, env):
    return env[node.inputs[0]].reshape(node.attrs["shape"])


@register_op("Softmax")
def _op_softmax(node: Node, env):
    return jax.nn.softmax(env[node.inputs[0]], axis=-1)


@register_op("Identity")
def _op_identity(node: Node, env):
    return env[node.inputs[0]]


@register_op("Split")
def _op_split(node: Node, env):
    x = env[node.inputs[0]]
    axis = node.attrs.get("axis", -1)
    return tuple(jnp.split(x, len(node.outputs), axis=axis))
