"""Writer 3: IR -> pjit'd SPMD executable on a device mesh.

The co-processor-generator analogue: wraps the accelerator for the production
mesh (batch data-parallel; weights replicated — edge-CNN weights are tiny) and
returns the compiled artifact plus its cost/memory analysis for the roofline.

Registers nothing in the op registry: every actor runs the reference ("jax")
implementation and only the partitioning changes.  When the shape-inference
pass has annotated the graph, output shardings replicate the trailing dims
explicitly instead of relying on rank inference.

Batch polymorphism: a graph whose input leading dim is the symbolic
:data:`repro.core.ir.BATCH` marker cannot be AOT-lowered without a concrete
batch — ``lower_compile`` requires ``batch=`` for such graphs, and
``build_batched`` keeps an LRU of per-batch AOT-compiled SPMD executables so
one ``DesignFlow.run`` artifact serves varying request sizes on the mesh.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.ir import has_symbolic
from repro.core.writers.jax_writer import BatchedExecutable, JaxWriter
from repro.sharding import batch_axes


class DistWriter(JaxWriter):
    target = "dist"

    def _out_spec(self, dp) -> P:
        info = self.graph.value_info.get(self.graph.outputs[0])
        if info is not None:
            return P(dp, *([None] * (len(info.shape) - 1)))
        return P(dp)

    def build_distributed(self, mesh: Mesh) -> Callable:
        run = self.build()
        dp = batch_axes(mesh)
        in_sh = tuple(NamedSharding(mesh, P(dp, *([None] * (len(t.shape) - 1))))
                      for t in self.graph.inputs)
        return jax.jit(run, in_shardings=in_sh,
                       out_shardings=NamedSharding(mesh, self._out_spec(dp)))

    def lower_compile(self, mesh: Mesh, batch: Optional[int] = None):
        fn = self.build_distributed(mesh)
        args = []
        for t in self.graph.inputs:
            if batch is not None:
                shape = t.concrete(batch) if t.is_batched \
                    else (batch, *t.shape[1:])
            elif has_symbolic(t.shape):
                raise ValueError(
                    f"input {t.name!r} has a symbolic batch dim; pass "
                    "batch= to lower_compile (or use build_batched)")
            else:
                shape = tuple(t.shape)
            args.append(jax.ShapeDtypeStruct(shape, jnp.dtype(t.dtype)))
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        return lowered, compiled

    def build_batched(self, mesh: Optional[Mesh] = None,
                      max_entries: int = 8,
                      on_compile: Optional[Callable] = None
                      ) -> BatchedExecutable:
        """Batch-polymorphic SPMD artifact: LRU of per-batch AOT-compiled
        executables on ``mesh`` (without a mesh, falls back to the plain
        single-device batched executable).

        The data axis shards the leading dim, so a request batch that does
        not divide the mesh's DP size is zero-padded up to the next multiple
        and the output sliced back — any batch size serves, at the cost of
        running the padded remainder.
        """
        if mesh is None:
            return super().build_batched(max_entries=max_entries,
                                         on_compile=on_compile)
        from repro.sharding import dp_size
        dp = dp_size(mesh)

        def compile_for(sig):
            batch = sig[0][0][0]
            padded = -(-batch // dp) * dp
            _, compiled = self.lower_compile(mesh, batch=padded)
            if padded == batch:
                return compiled

            def run_padded(*inputs):
                grown = [jnp.concatenate(
                    [x, jnp.zeros((padded - x.shape[0], *x.shape[1:]),
                                  x.dtype)]) for x in inputs]
                out = compiled(*grown)
                if isinstance(out, tuple):
                    return tuple(o[:batch] for o in out)
                return out[:batch]

            return run_padded

        return BatchedExecutable(self.build(), max_entries=max_entries,
                                 compile_fn=compile_for, on_compile=on_compile)
