"""Writer 3: IR -> pjit'd SPMD executable on a device mesh.

The co-processor-generator analogue: wraps the accelerator for the production
mesh (batch data-parallel; weights replicated — edge-CNN weights are tiny) and
returns the compiled artifact plus its cost/memory analysis for the roofline.

Registers nothing in the op registry: every actor runs the reference ("jax")
implementation and only the partitioning changes.  When the shape-inference
pass has annotated the graph, output shardings replicate the trailing dims
explicitly instead of relying on rank inference.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.writers.jax_writer import JaxWriter
from repro.sharding import batch_axes


class DistWriter(JaxWriter):
    target = "dist"

    def _out_spec(self, dp) -> P:
        info = self.graph.value_info.get(self.graph.outputs[0])
        if info is not None:
            return P(dp, *([None] * (len(info.shape) - 1)))
        return P(dp)

    def build_distributed(self, mesh: Mesh) -> Callable:
        run = self.build()
        dp = batch_axes(mesh)
        in_sh = tuple(NamedSharding(mesh, P(dp, *([None] * (len(t.shape) - 1))))
                      for t in self.graph.inputs)
        return jax.jit(run, in_shardings=in_sh,
                       out_shardings=NamedSharding(mesh, self._out_spec(dp)))

    def lower_compile(self, mesh: Mesh, batch: Optional[int] = None):
        fn = self.build_distributed(mesh)
        args = []
        for t in self.graph.inputs:
            shape = (batch, *t.shape[1:]) if batch else tuple(t.shape)
            args.append(jax.ShapeDtypeStruct(shape, jnp.dtype(t.dtype)))
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        return lowered, compiled
