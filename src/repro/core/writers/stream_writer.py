"""Writer 2: IR -> streaming actor pipeline (the HLS-Writer analogue).

Retargets Conv / FusedConv nodes onto the Pallas line-buffer kernel (Fig. 2
template: Line Buffer + Conv actor + VMEM-resident Weight/Bias actors; the
fusion pass additionally folds BatchNormalization into the Weight/Bias actors
and appends a ReluActor) and emits an XDF-style topology description — the
artifact the Multi-Dataflow Composer consumes (``topology()``; compare the
paper's XDF/CAL files).  Each FIFO in the topology is labelled with the
*consumer actor's* per-layer ``Dx-Wy`` datatype, so a heterogeneous precision
assignment is visible in the emitted network description.

FIFO sizing
-----------
Every connection carries a concrete ``depth`` (elements) derived from the
producer tensor's ``Graph.value_info`` annotation — the buffer a streaming
implementation must provision before the consumer can fire:

* **windowed consumers** (Conv / FusedConv / DepthwiseConv /
  FusedDepthwiseConv / MaxPool) use the line-buffer model: ``(kh - 1)`` full
  image rows plus ``kw`` pixels of the NHWC stream, i.e.
  ``(kh - 1) * W * C + kw * C`` elements — grouping does not change the
  firing rule, the NHWC stream buffers every channel of a pixel anyway;
* **matrix consumers** (Gemm / MatMul) need the whole per-item activation
  vector resident before the first MAC, so the depth is the tensor's static
  per-item volume;
* **pointwise consumers** (Relu, BatchNormalization, Softmax, Flatten, ...)
  stream element-by-element and only need one pixel's channel vector in
  flight.

Depths are multiplied by ``fifo_slack`` (rate-mismatch headroom; the
``--fifo-slack`` CLI knob) and reported per-FIFO in bytes at the consumer's
activation precision; ``topology()`` aggregates them as
``total_fifo_bytes`` so benchmarks can put buffer memory next to accuracy.
The symbolic batch dim never enters the model — FIFOs buffer *per-item*
streams, which is what makes one sized topology valid for any batch.
"""
from __future__ import annotations

import json
import math
from typing import Dict

import jax

from repro.core.ir import Node, TensorInfo, static_elems
from repro.core.passes.shape_infer import infer_shapes
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.registry import register_op


@register_op("Conv", target="stream")
def _op_conv_stream(node: Node, env):
    from repro.kernels.conv2d_stream.ops import conv2d_stream
    x, w, b = (env[i] for i in node.inputs)
    return conv2d_stream(x, w, b)


@register_op("FusedConv", target="stream")
def _op_fused_conv_stream(node: Node, env):
    y = _op_conv_stream(node, env)
    if node.attrs.get("relu"):
        y = jax.nn.relu(y)
    return y


_CONV_OPS = ("Conv", "FusedConv")
# grouped (depthwise) consumers: same line-buffer firing rule as Conv — the
# NHWC stream buffers all C channels per pixel regardless of grouping, so the
# depth formula is shared; the actor template differs (no im2col/patch stage)
_DW_OPS = ("DepthwiseConv", "FusedDepthwiseConv")
# consumers whose firing rule needs a sliding window of the input stream
_WINDOWED_OPS = _CONV_OPS + _DW_OPS + ("MaxPool",)
# consumers that reduce over the whole per-item activation vector
_MATRIX_OPS = ("Gemm", "FusedGemm", "MatMul")


class StreamWriter(JaxWriter):
    target = "stream"

    def __init__(self, graph, dtconfig=None, act_ranges=None, *,
                 fifo_slack: float = 1.0):
        super().__init__(graph, dtconfig, act_ranges)
        if fifo_slack <= 0:
            raise ValueError(f"fifo_slack must be positive, got {fifo_slack}")
        self.fifo_slack = float(fifo_slack)

    # ---- FIFO sizing (value_info-driven) ----------------------------------
    def _tensor_info(self, tensor: str) -> TensorInfo:
        if tensor not in self.graph.value_info:
            infer_shapes(self.graph)
        return self.graph.value_info[tensor]

    def fifo_depth(self, tensor: str, consumer: Node) -> int:
        """Elements the FIFO feeding ``consumer`` must hold (before slack)."""
        shape = self._tensor_info(tensor).shape
        if consumer.op in _WINDOWED_OPS and len(shape) >= 4:
            ks = consumer.attrs.get("kernel_shape")
            if ks is None:
                # Conv may omit kernel_shape; the window is the weight's HW
                ks = self.graph.initializers[consumer.inputs[1]].shape[:2]
            kh, kw = ks
            w, c = int(shape[-2]), int(shape[-1])
            depth = (kh - 1) * w * c + kw * c
        elif consumer.op in _MATRIX_OPS:
            # per-item volume: the leading dim is the batch whether symbolic
            # or pinned — FIFOs buffer one item's stream
            depth = static_elems(shape[1:])
        else:
            depth = int(shape[-1])
        return max(1, math.ceil(depth * self.fifo_slack))

    # ---- dataflow topology (XDF analogue) ---------------------------------
    def topology(self) -> Dict:
        """Actors + sized FIFO connections of the streaming accelerator."""
        order = self.graph.topo_order()
        producers = self.graph.producer_index()
        input_names = {t.name for t in self.graph.inputs}
        actors = []
        for n in order:
            is_conv = n.op in _CONV_OPS
            is_dw = n.op in _DW_OPS
            if is_conv:
                target = "pallas/conv2d_stream"
            elif is_dw:
                target = "pallas/qconv_dw"
            else:
                target = "jax"
            actor = {"name": n.name, "class": n.op, "target": target}
            if is_conv or is_dw:
                w = self.graph.initializers[n.inputs[1]]
                # the depthwise actor MACs each channel against its own taps
                # straight out of the line buffer — no patch/im2col stage
                sub = ["LineBuffer",
                       "DepthwiseActor" if is_dw else "ConvActor",
                       "WeightActor", "BiasActor"]
                if n.attrs.get("relu"):
                    sub.append("ReluActor")
                actor["sub_actors"] = sub
                actor["weight_shape"] = list(w.shape)
                if n.op in ("FusedConv", "FusedDepthwiseConv"):
                    actor["fused"] = n.attrs.get("fused_from", [])
            actors.append(actor)
        conns = []
        fifo_id = 0          # global counter: ids must be unique network-wide
        total_bytes = 0
        for n in order:
            dt = self.node_dt(n)
            for i in n.inputs:
                if i in producers:
                    src = producers[i].name
                elif i in input_names:
                    src = "input"
                else:
                    continue  # weight/bias initializers are not FIFOs
                depth = self.fifo_depth(i, n)
                depth_bytes = math.ceil(depth * dt.act_bits / 8)
                total_bytes += depth_bytes
                conns.append({"fifo": f"f{fifo_id}", "tensor": i,
                              "src": src, "dst": n.name,
                              "depth": depth, "depth_bytes": depth_bytes,
                              "datatype": f"D{dt.act_bits}-W{dt.weight_bits}"})
                fifo_id += 1
        return {"network": self.graph.name, "actors": actors,
                "connections": conns, "fifo_slack": self.fifo_slack,
                "total_fifo_bytes": total_bytes}

    def save_topology(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.topology(), f, indent=1)
