"""Writer 2: IR -> streaming actor pipeline (the HLS-Writer analogue).

Retargets Conv nodes onto the Pallas line-buffer kernel (Fig. 2 template:
Line Buffer + Conv actor + VMEM-resident Weight/Bias actors) and emits an
XDF-style topology description — the artifact the Multi-Dataflow Composer
consumes (``topology()``; compare the paper's XDF/CAL files).
"""
from __future__ import annotations

import json
from typing import Callable, Dict

from repro.core.ir import Graph, Node
from repro.core.writers.jax_writer import JaxWriter, OP_IMPLS


def _op_conv_stream(node: Node, env):
    from repro.kernels.conv2d_stream.ops import conv2d_stream
    x, w, b = (env[i] for i in node.inputs)
    return conv2d_stream(x, w, b)


class StreamWriter(JaxWriter):
    target = "stream"

    def op_impl(self, op: str) -> Callable:
        if op == "Conv":
            return _op_conv_stream
        return OP_IMPLS[op]

    # ---- dataflow topology (XDF analogue) ---------------------------------
    def topology(self) -> Dict:
        """Actors + FIFO connections of the streaming accelerator."""
        actors = []
        for n in self.graph.topo_order():
            actor = {"name": n.name, "class": n.op, "target": (
                "pallas/conv2d_stream" if n.op == "Conv" else "jax")}
            if n.op == "Conv":
                w = self.graph.initializers[n.inputs[1]]
                actor["sub_actors"] = ["LineBuffer", "ConvActor", "WeightActor",
                                       "BiasActor"]
                actor["weight_shape"] = list(w.shape)
            actors.append(actor)
        conns = []
        producers = {}
        for t in self.graph.inputs:
            producers[t.name] = "input"
        for n in self.graph.topo_order():
            for i in n.inputs:
                if i in producers:
                    conns.append({"src": producers[i], "dst": n.name,
                                  "fifo": i,
                                  "datatype": f"D{self.dt.act_bits}-W{self.dt.weight_bits}"})
            for o in n.outputs:
                producers[o] = n.name
        return {"network": self.graph.name, "actors": actors,
                "connections": conns}

    def save_topology(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.topology(), f, indent=1)
