"""Writer 2: IR -> streaming actor pipeline (the HLS-Writer analogue).

Retargets Conv / FusedConv nodes onto the Pallas line-buffer kernel (Fig. 2
template: Line Buffer + Conv actor + VMEM-resident Weight/Bias actors; the
fusion pass additionally folds BatchNormalization into the Weight/Bias actors
and appends a ReluActor) and emits an XDF-style topology description — the
artifact the Multi-Dataflow Composer consumes (``topology()``; compare the
paper's XDF/CAL files).  Each FIFO in the topology is labelled with the
*consumer actor's* per-layer ``Dx-Wy`` datatype, so a heterogeneous precision
assignment is visible in the emitted network description.
"""
from __future__ import annotations

import json
from typing import Dict

import jax

from repro.core.ir import Node
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.registry import register_op


@register_op("Conv", target="stream")
def _op_conv_stream(node: Node, env):
    from repro.kernels.conv2d_stream.ops import conv2d_stream
    x, w, b = (env[i] for i in node.inputs)
    return conv2d_stream(x, w, b)


@register_op("FusedConv", target="stream")
def _op_fused_conv_stream(node: Node, env):
    y = _op_conv_stream(node, env)
    if node.attrs.get("relu"):
        y = jax.nn.relu(y)
    return y


_CONV_OPS = ("Conv", "FusedConv")


class StreamWriter(JaxWriter):
    target = "stream"

    # ---- dataflow topology (XDF analogue) ---------------------------------
    def topology(self) -> Dict:
        """Actors + FIFO connections of the streaming accelerator."""
        order = self.graph.topo_order()
        producers = self.graph.producer_index()
        input_names = {t.name for t in self.graph.inputs}
        actors = []
        for n in order:
            is_conv = n.op in _CONV_OPS
            actor = {"name": n.name, "class": n.op,
                     "target": "pallas/conv2d_stream" if is_conv else "jax"}
            if is_conv:
                w = self.graph.initializers[n.inputs[1]]
                sub = ["LineBuffer", "ConvActor", "WeightActor", "BiasActor"]
                if n.attrs.get("relu"):
                    sub.append("ReluActor")
                actor["sub_actors"] = sub
                actor["weight_shape"] = list(w.shape)
                if n.op == "FusedConv":
                    actor["fused"] = n.attrs.get("fused_from", [])
            actors.append(actor)
        conns = []
        for n in order:
            dt = self.node_dt(n)
            for i in n.inputs:
                if i in producers:
                    src = producers[i].name
                elif i in input_names:
                    src = "input"
                else:
                    continue  # weight/bias initializers are not FIFOs
                conns.append({"src": src, "dst": n.name, "fifo": i,
                              "datatype": f"D{dt.act_bits}-W{dt.weight_bits}"})
        return {"network": self.graph.name, "actors": actors,
                "connections": conns}

    def save_topology(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.topology(), f, indent=1)
