"""ONNX-like graph IR — the ONNXParser intermediate format.

The paper's Reader produces "an intermediate format with a list of objects
that describes layers and connections of the ONNX model"; this module is that
format.  Op semantics follow ONNX operator definitions.  The ``onnx`` package
is unavailable offline, so serialization is ONNX-shaped JSON (graph topology +
tensor metadata) with weights in an ``.npz`` sidecar.

The IR carries two kinds of per-graph annotations written by the compiler
passes in :mod:`repro.core.passes`:

* ``Graph.value_info`` — a ``tensor name -> TensorInfo`` map filled in by the
  shape-inference pass; every FIFO between actors gets a static shape/dtype.
* ``Node.dtconfig`` — an optional per-layer :class:`~repro.quant.qtypes.
  DatatypeConfig` attached by the precision-assignment pass.  Writers fall
  back to their construction-time default when a node carries no annotation,
  so un-annotated graphs behave exactly like the old single-global-config
  flow.

Graphs also maintain O(V+E) structural indices (``producer_index`` /
``consumer_index``) used by ``topo_order``, the passes, and the writers.

Shapes may carry ONE symbolic dimension — the leading (batch) dim, written
``BATCH`` (the string ``"N"``).  A graph whose input batch is symbolic
compiles to a *batch-polymorphic* executable: the writers trace/jit per
concrete batch size on demand (LRU of traced shapes) instead of baking a
literal batch into the artifact.  All non-leading dims stay concrete ints,
which is what the streaming FIFO-sizing model requires (per-row volumes
never involve the batch dim).
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.quant.qtypes import DatatypeConfig

# Symbolic leading-dimension sentinel.  ``TensorInfo.shape`` entries are ints
# except (at most) the leading dim, which may be this marker.
BATCH = "N"

Dim = Union[int, str]


def is_symbolic(dim: Dim) -> bool:
    """True for the symbolic batch marker (any string dim)."""
    return isinstance(dim, str)


def has_symbolic(shape) -> bool:
    return any(is_symbolic(d) for d in shape)


def concretize(shape, batch: int) -> Tuple[int, ...]:
    """Substitute a concrete batch size for every symbolic dim."""
    return tuple(int(batch) if is_symbolic(d) else int(d) for d in shape)


def static_elems(shape) -> int:
    """Element count of the non-symbolic dims (per-item volume for a
    batch-leading tensor) — what FIFO sizing and weight-storage math use."""
    n = 1
    for d in shape:
        if not is_symbolic(d):
            n *= int(d)
    return n


SUPPORTED_OPS = {
    "Conv", "MaxPool", "BatchNormalization", "Relu", "Gemm", "MatMul",
    "Add", "Flatten", "Softmax", "Reshape", "Identity", "Split",
    # grouped Conv with group == channels and HWIO weights (kh, kw, 1, C);
    # produced directly by readers or by normalize_groups from an ONNX Conv
    # carrying a depthwise ``group`` attribute
    "DepthwiseConv",
    # produced by the fusion pass: Conv with folded BatchNormalization
    # (+ optional trailing Relu, attrs["relu"]=True)
    "FusedConv",
    # produced by the fusion pass: DepthwiseConv with folded BN (+ Relu)
    "FusedDepthwiseConv",
    # produced by the fusion pass: Gemm with a folded trailing Relu
    "FusedGemm",
}


@dataclass
class TensorInfo:
    name: str
    shape: Tuple[Dim, ...]     # leading dim may be the symbolic BATCH marker
    dtype: str = "float32"

    @property
    def is_batched(self) -> bool:
        return has_symbolic(self.shape)

    def concrete(self, batch: int) -> Tuple[int, ...]:
        return concretize(self.shape, batch)


@dataclass
class Node:
    op: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = field(default_factory=dict)
    # per-layer precision annotation (written by the precision pass);
    # None => use the writer's default DatatypeConfig
    dtconfig: Optional[DatatypeConfig] = None

    def __post_init__(self):
        if self.op not in SUPPORTED_OPS:
            raise ValueError(f"unsupported op {self.op!r} (node {self.name})")


@dataclass
class Graph:
    name: str
    nodes: List[Node]
    inputs: List[TensorInfo]
    outputs: List[str]
    initializers: Dict[str, np.ndarray] = field(default_factory=dict)
    # tensor name -> inferred TensorInfo (filled by the shape-inference pass)
    value_info: Dict[str, TensorInfo] = field(default_factory=dict)

    # ---- validation / ordering -------------------------------------------
    def validate(self) -> None:
        produced = {t.name for t in self.inputs} | set(self.initializers)
        names = set()
        for n in self.nodes:
            if n.name in names:
                raise ValueError(f"duplicate node name {n.name}")
            names.add(n.name)
        for n in self.topo_order():
            for i in n.inputs:
                if i not in produced:
                    raise ValueError(f"node {n.name}: undefined input {i!r}")
            produced.update(n.outputs)
        for o in self.outputs:
            if o not in produced:
                raise ValueError(f"undefined graph output {o!r}")

    # ---- structural indices (O(V+E), cached per node-list identity) -------
    def _index_key(self) -> Tuple[int, ...]:
        return tuple(id(n) for n in self.nodes)

    def producer_index(self) -> Dict[str, Node]:
        """tensor name -> producing Node, built once in O(V+E)."""
        cached = self.__dict__.get("_pidx")
        key = self._index_key()
        if cached is None or cached[0] != key:
            idx: Dict[str, Node] = {}
            for n in self.nodes:
                for o in n.outputs:
                    idx[o] = n
            self.__dict__["_pidx"] = cached = (key, idx)
        return cached[1]

    def consumer_index(self) -> Dict[str, List[Node]]:
        """tensor name -> consuming Nodes, built once in O(V+E)."""
        cached = self.__dict__.get("_cidx")
        key = self._index_key()
        if cached is None or cached[0] != key:
            idx: Dict[str, List[Node]] = {}
            for n in self.nodes:
                for i in n.inputs:
                    idx.setdefault(i, []).append(n)
            self.__dict__["_cidx"] = cached = (key, idx)
        return cached[1]

    def topo_order(self) -> List[Node]:
        """Kahn's algorithm over the producer index — O(V+E) (the old
        implementation re-scanned the remaining-node list per step, O(V^2·E)
        worst case)."""
        avail = {t.name for t in self.inputs} | set(self.initializers)
        producers: Dict[str, int] = {}
        for idx, n in enumerate(self.nodes):
            for o in n.outputs:
                producers[o] = idx
        indeg = [0] * len(self.nodes)
        adj: Dict[int, List[int]] = {}
        for idx, n in enumerate(self.nodes):
            for i in set(n.inputs):
                if i in avail:
                    continue
                p = producers.get(i)
                indeg[idx] += 1
                if p is not None and p != idx:
                    adj.setdefault(p, []).append(idx)
                # p is None (missing producer) or a self-loop: the edge can
                # never be satisfied, so the node stays unscheduled and we
                # report it below.
        ready = deque(i for i, d in enumerate(indeg) if d == 0)
        order: List[Node] = []
        while ready:
            idx = ready.popleft()
            order.append(self.nodes[idx])
            for c in adj.get(idx, ()):
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self.nodes):
            stuck = [n.name for i, n in enumerate(self.nodes) if indeg[i] > 0]
            raise ValueError(
                f"graph has a cycle or missing producer; stuck at {stuck}")
        return order

    def producer_of(self, tensor: str) -> Optional[Node]:
        return self.producer_index().get(tensor)

    def consumers_of(self, tensor: str) -> List[Node]:
        return self.consumer_index().get(tensor, [])

    # ---- serialization ----------------------------------------------------
    def to_json(self) -> str:
        d = {
            "name": self.name,
            "nodes": [asdict(n) for n in self.nodes],
            "inputs": [asdict(t) for t in self.inputs],
            "outputs": self.outputs,
            "initializers": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                             for k, v in self.initializers.items()},
            "value_info": {k: {"shape": list(t.shape), "dtype": t.dtype}
                           for k, t in self.value_info.items()},
        }
        return json.dumps(d, indent=1)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
        if self.initializers:
            np.savez(path + ".npz", **self.initializers)

    @classmethod
    def from_json(cls, text: str, weights: Optional[Dict[str, np.ndarray]] = None
                  ) -> "Graph":
        d = json.loads(text)
        nodes = []
        for n in d["nodes"]:
            n = dict(n)
            dt = n.pop("dtconfig", None)
            node = Node(**n)
            if dt is not None:
                node.dtconfig = DatatypeConfig(**dt)
            nodes.append(node)
        inputs = [TensorInfo(t["name"], tuple(t["shape"]), t.get("dtype", "float32"))
                  for t in d["inputs"]]
        inits = dict(weights or {})
        for k, meta in d.get("initializers", {}).items():
            if k not in inits:
                inits[k] = np.zeros(meta["shape"], dtype=meta["dtype"])
        vi = {k: TensorInfo(k, tuple(m["shape"]), m.get("dtype", "float32"))
              for k, m in d.get("value_info", {}).items()}
        g = cls(d["name"], nodes, inputs, d["outputs"], inits, vi)
        g.validate()
        return g

    @classmethod
    def load(cls, path: str) -> "Graph":
        import os
        weights = None
        if os.path.exists(path + ".npz"):
            weights = dict(np.load(path + ".npz"))
        with open(path) as f:
            return cls.from_json(f.read(), weights)
