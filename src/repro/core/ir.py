"""ONNX-like graph IR — the ONNXParser intermediate format.

The paper's Reader produces "an intermediate format with a list of objects
that describes layers and connections of the ONNX model"; this module is that
format.  Op semantics follow ONNX operator definitions.  The ``onnx`` package
is unavailable offline, so serialization is ONNX-shaped JSON (graph topology +
tensor metadata) with weights in an ``.npz`` sidecar.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

SUPPORTED_OPS = {
    "Conv", "MaxPool", "BatchNormalization", "Relu", "Gemm", "MatMul",
    "Add", "Flatten", "Softmax", "Reshape", "Identity",
}


@dataclass
class TensorInfo:
    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"


@dataclass
class Node:
    op: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.op not in SUPPORTED_OPS:
            raise ValueError(f"unsupported op {self.op!r} (node {self.name})")


@dataclass
class Graph:
    name: str
    nodes: List[Node]
    inputs: List[TensorInfo]
    outputs: List[str]
    initializers: Dict[str, np.ndarray] = field(default_factory=dict)

    # ---- validation / ordering -------------------------------------------
    def validate(self) -> None:
        produced = {t.name for t in self.inputs} | set(self.initializers)
        names = set()
        for n in self.nodes:
            if n.name in names:
                raise ValueError(f"duplicate node name {n.name}")
            names.add(n.name)
        for n in self.topo_order():
            for i in n.inputs:
                if i not in produced:
                    raise ValueError(f"node {n.name}: undefined input {i!r}")
            produced.update(n.outputs)
        for o in self.outputs:
            if o not in produced:
                raise ValueError(f"undefined graph output {o!r}")

    def topo_order(self) -> List[Node]:
        avail = {t.name for t in self.inputs} | set(self.initializers)
        remaining = list(self.nodes)
        order: List[Node] = []
        while remaining:
            progressed = False
            for n in list(remaining):
                if all(i in avail for i in n.inputs):
                    order.append(n)
                    avail.update(n.outputs)
                    remaining.remove(n)
                    progressed = True
            if not progressed:
                raise ValueError(
                    f"graph has a cycle or missing producer; stuck at "
                    f"{[n.name for n in remaining]}")
        return order

    def producer_of(self, tensor: str) -> Optional[Node]:
        for n in self.nodes:
            if tensor in n.outputs:
                return n
        return None

    # ---- serialization ----------------------------------------------------
    def to_json(self) -> str:
        d = {
            "name": self.name,
            "nodes": [asdict(n) for n in self.nodes],
            "inputs": [asdict(t) for t in self.inputs],
            "outputs": self.outputs,
            "initializers": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                             for k, v in self.initializers.items()},
        }
        return json.dumps(d, indent=1)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
        if self.initializers:
            np.savez(path + ".npz", **self.initializers)

    @classmethod
    def from_json(cls, text: str, weights: Optional[Dict[str, np.ndarray]] = None
                  ) -> "Graph":
        d = json.loads(text)
        nodes = [Node(**n) for n in d["nodes"]]
        inputs = [TensorInfo(t["name"], tuple(t["shape"]), t.get("dtype", "float32"))
                  for t in d["inputs"]]
        inits = dict(weights or {})
        for k, meta in d.get("initializers", {}).items():
            if k not in inits:
                inits[k] = np.zeros(meta["shape"], dtype=meta["dtype"])
        g = cls(d["name"], nodes, inputs, d["outputs"], inits)
        g.validate()
        return g

    @classmethod
    def load(cls, path: str) -> "Graph":
        import os
        weights = None
        if os.path.exists(path + ".npz"):
            weights = dict(np.load(path + ".npz"))
        with open(path) as f:
            return cls.from_json(f.read(), weights)
