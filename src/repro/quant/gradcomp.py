"""int8 gradient compression with error feedback — the paper's precision-scaling
idea applied to the training-time collective bottleneck.

Gradients are quantized to int8 (per-tensor symmetric scale) *before* the
data-parallel all-reduce and dequantized after; the quantization residual is
carried in an error-feedback buffer so the compression is unbiased over time.
4x less all-reduce traffic on the wire (the collective roofline term).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_state(grads: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(v.shape, jnp.bfloat16) for k, v in grads.items()}


def _q_int8(x):
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    c = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return c, s


def compress_decompress(g, err):
    """Quantize (g + err) to int8, return (dequantized, new_err).

    In the distributed step the int8 codes are what crosses the wire; XLA sees
    the all-reduce operand at int8 width when this wraps the psum (see
    runtime/train.py grad_transform hooks)."""
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    c, s = _q_int8(x)
    deq = c.astype(jnp.float32) * s
    return deq.astype(g.dtype), (x - deq).astype(jnp.bfloat16)


def compress_tree(grads: Dict[str, jax.Array], err: Dict[str, jax.Array]
                  ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    new_g, new_e = {}, {}
    for k, g in grads.items():
        new_g[k], new_e[k] = compress_decompress(g, err[k])
    return new_g, new_e
