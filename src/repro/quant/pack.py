"""Bit-packing for sub-byte weight storage (int4: 2/byte, int2: 4/byte).

Packing is what turns low weight precision into a real HBM-bandwidth win on
TPU (the paper's BRAM-column effect); ``repro.kernels.qmatmul`` unpacks in-VMEM.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_int4(codes):
    """codes: int8 array in [-8, 7], last dim even -> uint8 packed (…, n/2)."""
    assert codes.shape[-1] % 2 == 0
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(packed):
    """uint8 (…, n/2) -> int8 (…, n) in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def pack_int2(codes):
    """codes: int8 in [-2, 1], last dim % 4 == 0 -> uint8 packed (…, n/4)."""
    assert codes.shape[-1] % 4 == 0
    u = (codes.astype(jnp.int32) & 0x3).astype(jnp.uint8)
    b0, b1, b2, b3 = u[..., 0::4], u[..., 1::4], u[..., 2::4], u[..., 3::4]
    return b0 | (b1 << 2) | (b2 << 4) | (b3 << 6)


def unpack_int2(packed):
    outs = []
    for sh in (0, 2, 4, 6):
        v = ((packed >> sh) & 0x3).astype(jnp.int8)
        outs.append(jnp.where(v >= 2, v - 4, v))
    out = jnp.stack(outs, axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 4)
