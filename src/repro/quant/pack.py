"""Packed weight storage: the one-copy-many-points artifact.

Three layers live here:

* :class:`PackedWeights` / :class:`PackedTensor` — every >=2-D initializer of
  a graph quantized ONCE to int8 master codes + per-output-channel f32 scales.
  W4/W2 working points are *nested truncations* of the same codes
  (``quant.ptq.derive_view``), so N working points share ONE buffer — the
  paper's MDC weight sharing, and what lets ``AccelServer`` switch precision
  per batch with zero weight movement.  The dequant-fused
  ``repro.kernels.qmatmul`` kernels stream these codes directly.
* sub-byte **HBM residency**: ``PackedTensor.packed_view(bits)`` stores the
  W4/W2 views nibble/crumb-packed into ``uint8`` with the *split-row* layout
  (:func:`pack_rows`), cutting the resident weight buffer to ~1/2 and ~1/4 of
  the W8 codes — the paper's BRAM-column effect realized as real HBM
  bandwidth: the qmatmul kernels unpack each k-block in-VMEM.
* generic bit-packing helpers (int4: 2/byte, int2: 4/byte) along the last
  dim (``pack_int4`` / ``pack_int2``) — layout-agnostic round-trip utilities.

Split-row layout
----------------
``pack_rows(codes, bits)`` pads K (the reduction dim) up to ``PACK_ALIGN``,
splits the rows into ``r = 8 // bits`` contiguous chunks of ``Kp / r`` rows,
and packs row ``i`` of every chunk into one byte (chunk ``j`` occupies bit
field ``j*bits``).  A contiguous *byte-row* block of the packed buffer then
maps to ``r`` contiguous *code-row* blocks of the logical matrix — exactly
what a Pallas kernel wants: it streams one packed (bk/r, bn) tile plus the
``r`` matching activation tiles and never reshuffles lanes in VMEM.  The
stored field is ``q = view / 2^(8-bits)`` (the true ``bits``-bit integer), so
kernels fold the power-of-two step into the channel scale instead of
multiplying it back per element.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# K-dim alignment of the packed buffers: matches the qmatmul kernels'
# _MIN_TILE so a stored packed view is directly streamable (no repack)
PACK_ALIGN = 128

# working points with a sub-byte packed representation
SUB_BYTE_BITS = (4, 2)


def _crc32(arr) -> int:
    """CRC32 of a buffer's raw bytes (the per-region integrity checksum)."""
    return zlib.crc32(np.ascontiguousarray(np.asarray(arr)).tobytes())


@dataclass(frozen=True)
class Region:
    """One independently-checksummed buffer of a :class:`PackedWeights`:
    a tensor's int8 master codes, its f32 per-channel scales, or one cached
    sub-byte packed view (identified by ``(bits, align)``).  The scrubber
    walks these; ``nbytes`` is what one verification of the region costs
    against its rate budget."""
    tensor: str
    kind: str                  # "codes" | "scale" | "view"
    bits: Optional[int] = None     # view regions only
    align: Optional[int] = None    # view regions only
    nbytes: int = 0

    def label(self) -> str:
        if self.kind == "view":
            return f"{self.tensor}:view(w{self.bits},align={self.align})"
        return f"{self.tensor}:{self.kind}"


@dataclass(frozen=True)
class RegionMismatch:
    """A failed region verification: the buffer's bytes no longer hash to
    the checksum sealed at pack time (a silent-data-corruption detection).
    ``repairable`` regions (the W4/W2 packed views — nested truncations of
    the master codes) can be re-derived bit-exactly; master-code or scale
    corruption has no redundant source and must escalate."""
    region: Region
    expected_crc: int
    actual_crc: int

    @property
    def repairable(self) -> bool:
        return self.region.kind == "view"

    def __str__(self) -> str:
        fix = "repairable from master" if self.repairable else "UNREPAIRABLE"
        return (f"checksum mismatch in {self.region.label()} "
                f"({self.region.nbytes} bytes, expected "
                f"{self.expected_crc:#010x}, got {self.actual_crc:#010x}; "
                f"{fix})")


def _pad_rows(codes, align: int):
    r = (-codes.shape[0]) % align
    if r == 0:
        return codes
    return jnp.pad(codes, ((0, r),) + ((0, 0),) * (codes.ndim - 1))


def pack_rows(codes, bits: int, align: int = PACK_ALIGN):
    """int8 master codes (K, N) -> split-row packed uint8 (Kp/r, N).

    ``r = 8 // bits``; K is zero-padded to ``align`` (code 0 packs to a zero
    field and contributes nothing to a MAC).  Byte ``i`` holds the ``bits``-bit
    integer ``q`` of rows ``i + j*(Kp/r)`` for ``j = 0..r-1``, field ``j`` at
    bit ``j*bits``.  ``q`` is the rounded nested truncation — identical to
    ``derive_view(codes, bits) / 2^(8-bits)``."""
    assert bits in SUB_BYTE_BITS, f"no sub-byte packing for bits={bits}"
    r = 8 // bits
    shift = 8 - bits
    step = 1 << shift
    half = 1 << (bits - 1)
    cp = _pad_rows(jnp.asarray(codes), align)
    kp = cp.shape[0]
    q = jnp.clip(jnp.round(cp.astype(jnp.float32) / step),
                 -half, half - 1).astype(jnp.int32)
    chunks = q.reshape(r, kp // r, *cp.shape[1:])
    mask = (1 << bits) - 1
    out = jnp.zeros(chunks.shape[1:], jnp.int32)
    for j in range(r):
        out = out | ((chunks[j] & mask) << (j * bits))
    return out.astype(jnp.uint8)


def unpack_rows(packed, bits: int):
    """Split-row packed uint8 (Kp/r, N) -> int8 codes (Kp, N) in the *view*
    domain (``q * 2^(8-bits)``, i.e. exactly ``derive_view`` of the master)."""
    assert bits in SUB_BYTE_BITS, f"no sub-byte packing for bits={bits}"
    r = 8 // bits
    step = 1 << (8 - bits)
    half = 1 << (bits - 1)
    mask = (1 << bits) - 1
    p = packed.astype(jnp.int32)
    chunks = []
    for j in range(r):
        f = (p >> (j * bits)) & mask
        q = jnp.where(f >= half, f - (1 << bits), f)
        chunks.append(q * step)
    return jnp.concatenate(chunks, axis=0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Packed master-code artifact (graph-level analogue of ptq.QuantizedParams)
# ---------------------------------------------------------------------------

@dataclass
class PackedTensor:
    """One weight, quantized once: int8 master codes + per-out-channel scale.

    ``codes`` keeps the original weight shape (HWIO for conv, (K, N) for
    Gemm); ``scale`` is f32 and broadcastable against it (keepdims over the
    last axis).  Low-bit working points are derived views of the same codes —
    no storage per point; the W4/W2 views additionally cache a *sub-byte
    packed* buffer (:meth:`packed_view`) so their HBM residency really is
    bits/8 of the master's.

    Every region (master codes, scales, each cached packed view) is sealed
    with a CRC32 at creation; :meth:`verify` re-hashes the live buffers and
    reports typed :class:`RegionMismatch` entries for any silent bit flip.
    Corrupted views are re-derivable from the intact master
    (:meth:`repair_view` — nested truncation makes repair free); the cache
    and checksum dicts are lock-guarded because the fleet heal path rebuilds
    replicas while siblings serve from the same tensors."""

    codes: jax.Array     # int8, original weight shape
    scale: jax.Array     # f32, per-output-channel (last dim), keepdims
    # cache key: (bits, K-alignment) — one resident buffer per view
    _packed: Dict[tuple, jax.Array] = field(default_factory=dict, repr=False,
                                            compare=False)
    # sealed checksums: "codes" / "scale" / ("view", bits, align) -> CRC32
    _crc: Dict[object, int] = field(default_factory=dict, repr=False,
                                    compare=False)
    # guards first-touch view derivation AND checksum (re)sealing
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False,
                                  compare=False)

    def __post_init__(self):
        self.seal()

    def seal(self) -> None:
        """(Re)seal the master-code and scale checksums from the CURRENT
        buffers (called at pack time)."""
        with self._lock:
            self._crc["codes"] = _crc32(self.codes)
            self._crc["scale"] = _crc32(self.scale)

    def view(self, bits: int) -> jax.Array:
        """The ``bits``-bit nested-truncation view of the master codes."""
        from repro.quant.ptq import derive_view
        return derive_view(self.codes, bits)

    def dequant(self, bits: int = 8, dtype=jnp.float32) -> jax.Array:
        """Fake-quant float copy at a working point (the legacy writer path —
        under jit over constant codes XLA folds this away)."""
        from repro.quant.ptq import dequant
        return dequant(self.codes, self.scale, bits, dtype)

    def codes_2d(self) -> jax.Array:
        """Codes flattened to (K, N) for the qmatmul kernels (N = out chans)."""
        return self.codes.reshape(-1, self.codes.shape[-1])

    def scale_1d(self) -> jax.Array:
        return self.scale.reshape(-1)

    def packed_view(self, bits: int, align: int = PACK_ALIGN) -> jax.Array:
        """Split-row sub-byte packed W4/W2 buffer (cached; K padded to
        ``align`` so kernels stream it without a repack).  The default
        alignment matches the qmatmul tile; the depthwise-direct kernels pass
        a small alignment so a 3x3 window (K = 9) is not padded 14x."""
        if bits not in SUB_BYTE_BITS:
            raise ValueError(f"packed_view is for bits in {SUB_BYTE_BITS}, "
                             f"got {bits} (the W8 view IS the master codes)")
        key = (bits, int(align))
        # first-touch derivation is lock-guarded: the fleet heal path builds
        # a fresh replica's executables while sibling pumps serve from the
        # same PackedWeights, so two threads may race the cache miss
        with self._lock:
            buf = self._packed.get(key)
            if buf is None:
                buf = pack_rows(self.codes_2d(), bits, align=align)
                self._packed[key] = buf
                self._crc[("view", *key)] = _crc32(buf)
        return buf

    # -- integrity -----------------------------------------------------------
    def regions(self, name: str, bits: Optional[int] = None) -> List[Region]:
        """The checksummed regions of this tensor, filtered by working
        point: ``None`` = every region; ``8`` = master codes + scales;
        ``4``/``2`` = that point's cached packed views + the scales (what
        the sub-byte serving path actually reads)."""
        regs: List[Region] = []
        with self._lock:
            view_keys = list(self._packed)
        if bits is None or bits == 8:
            regs.append(Region(name, "codes", nbytes=int(self.codes.size)))
        regs.append(Region(name, "scale", nbytes=4 * int(self.scale.size)))
        for (b, align) in view_keys:
            if bits is None or b == bits:
                with self._lock:
                    nb = int(self._packed[(b, align)].size)
                regs.append(Region(name, "view", bits=b, align=align,
                                   nbytes=nb))
        return regs

    def _buffer(self, region: Region):
        if region.kind == "codes":
            return self.codes
        if region.kind == "scale":
            return self.scale
        with self._lock:
            return self._packed.get((region.bits, region.align))

    def _sealed_crc(self, region: Region) -> Optional[int]:
        key = (region.kind if region.kind != "view"
               else ("view", region.bits, region.align))
        with self._lock:
            return self._crc.get(key)

    def verify_region(self, region: Region) -> Optional[RegionMismatch]:
        """Re-hash one region against its sealed checksum; ``None`` = clean.
        An evicted/never-derived view region verifies clean (nothing to
        corrupt)."""
        buf = self._buffer(region)
        expected = self._sealed_crc(region)
        if buf is None or expected is None:
            return None
        actual = _crc32(buf)
        if actual == expected:
            return None
        return RegionMismatch(region, expected, actual)

    def verify(self, name: str, bits: Optional[int] = None
               ) -> List[RegionMismatch]:
        return [m for m in (self.verify_region(r)
                            for r in self.regions(name, bits))
                if m is not None]

    def repair_view(self, bits: int, align: int = PACK_ALIGN) -> jax.Array:
        """Re-derive one packed view bit-exactly from the master codes and
        reseal its checksum — the self-healing half of SDC handling (views
        are nested truncations, so repair costs one re-pack, no reload).
        The caller must have verified the master codes first: repairing from
        a corrupted master would launder the corruption into a 'clean'
        checksum."""
        if bits not in SUB_BYTE_BITS:
            raise ValueError(f"only sub-byte views are repairable, got "
                             f"bits={bits}")
        key = (bits, int(align))
        with self._lock:
            fresh = pack_rows(self.codes_2d(), bits, align=align)
            self._packed[key] = fresh
            self._crc[("view", *key)] = _crc32(fresh)
        return fresh

    @property
    def nbytes(self) -> int:
        """Master storage: 1 byte/code + 4 bytes/scale (shared by all points)."""
        return int(self.codes.size) + 4 * int(self.scale.size)

    def view_nbytes(self, bits: int, align: int = PACK_ALIGN) -> int:
        """Resident HBM bytes of the ``bits``-bit view on the kernel path:
        the streamed weight buffer (K padded to ``align``, sub-byte packed
        below W8) plus the f32 channel scales."""
        k, n = self.codes_2d().shape
        kp = k + ((-k) % align)
        if bits in SUB_BYTE_BITS:
            buf = (kp // (8 // bits)) * n
        else:
            buf = kp * n
        return buf + 4 * int(self.scale.size)


@dataclass
class PackedWeights:
    """All of a graph's quantizable initializers packed to shared master codes.

    ``tensors`` holds the packed >=2-D weights; ``passthrough`` everything that
    stays float (biases, norm stats, 1-D tensors).  One instance backs every
    working-point executable of a :class:`~repro.core.writers.qjax_writer.
    QJaxWriter` — switching W8 -> W4 -> W2 re-reads the same buffers (W8: the
    int8 master; W4/W2: its cached sub-byte packed views)."""

    tensors: Dict[str, PackedTensor]
    passthrough: Dict[str, jax.Array]

    @classmethod
    def from_initializers(cls, initializers: Dict) -> "PackedWeights":
        from repro.quant.ptq import is_quantizable, quantize_channelwise
        tensors, passthrough = {}, {}
        for name, arr in initializers.items():
            w = jnp.asarray(arr)
            if is_quantizable(name, w):
                tensors[name] = PackedTensor(*quantize_channelwise(w))
            else:
                passthrough[name] = w
        return cls(tensors, passthrough)

    def dequantized(self, bits: int = 8, dtype=jnp.float32) -> Dict[str, jax.Array]:
        """Fake-quant float copies at a working point (the pre-packed-engine
        baseline: what each per-point executable used to hold)."""
        out = dict(self.passthrough)
        for name, t in self.tensors.items():
            out[name] = t.dequant(bits, dtype)
        return out

    def code_bytes(self) -> int:
        """Bytes of the shared master buffer (codes + scales)."""
        return sum(t.nbytes for t in self.tensors.values())

    # -- integrity -----------------------------------------------------------
    def regions(self, bits: Optional[int] = None) -> List[Region]:
        """Every checksummed region across all tensors (see
        :meth:`PackedTensor.regions` for the ``bits`` filter) — the
        scrubber's round-robin walk list."""
        return [r for name, t in self.tensors.items()
                for r in t.regions(name, bits)]

    def verify_region(self, region: Region) -> Optional[RegionMismatch]:
        t = self.tensors.get(region.tensor)
        if t is None:
            return None
        return t.verify_region(region)

    def verify(self, bits: Optional[int] = None) -> List[RegionMismatch]:
        """Re-hash every region (or only the ``bits`` working point's
        regions) against the checksums sealed at pack time; returns the
        typed mismatches — ``[]`` means the buffer is clean.  One shared
        buffer backs every working point on every replica, so this is THE
        silent-data-corruption detector for the whole fleet."""
        return [m for name, t in self.tensors.items()
                for m in t.verify(name, bits)]

    def repair(self, mismatch: RegionMismatch) -> jax.Array:
        """Repair one *view* mismatch by re-deriving the packed buffer from
        the (intact) master codes; raises ``ValueError`` for master-code or
        scale corruption, which has no redundant source here — callers
        escalate those (replica ejection / rebuild from the original
        initializers)."""
        r = mismatch.region
        if not mismatch.repairable:
            raise ValueError(f"cannot repair {r.label()}: only derived "
                             "views re-derive from the master codes")
        return self.tensors[r.tensor].repair_view(r.bits, align=r.align)

    def view_bytes(self, bits: int,
                   caps: Optional[Dict[str, int]] = None) -> int:
        """Resident streamed weight bytes at a working point (sub-byte packed
        buffers below W8; see :meth:`PackedTensor.view_nbytes`).

        ``caps`` optionally bounds individual initializers below the runtime
        view (``{name: max_bits}`` — the per-layer precision caps a
        :class:`~repro.quant.qtypes.PrecisionMap` realizes through
        ``QJaxContext.weight_bits``): the effective bits of a capped tensor
        are ``min(bits, caps[name])``, exactly what the mixed-precision
        executable streams.  The DSE's weight-bytes budget term is this
        number."""
        caps = caps or {}
        return sum(t.view_nbytes(min(bits, caps.get(name, bits)))
                   for name, t in self.tensors.items())

    def sharing_report(self, n_points: int = 3) -> Dict[str, float]:
        """Merged-vs-separate weight storage for ``n_points`` working points
        (the MDC LUT-sharing story, in bytes): the shared master vs each point
        holding its own int8 copy (a 1/n_points drop by construction), and —
        the empirical ``sharing_ratio`` — vs the legacy per-point fake-quant
        f32 copies the writers used to bake into each executable.  The
        ``view_bytes`` entry accounts the *streamed* buffer per point with
        sub-byte packing (what actually moves HBM -> VMEM at W4/W2)."""
        shared = self.code_bytes()
        n_elems = sum(int(t.codes.size) for t in self.tensors.values())
        f32_copies = n_points * 4 * n_elems
        return {
            "n_points": n_points,
            "shared_bytes": shared,
            "per_point_copy_bytes": n_points * shared,
            "per_point_f32_bytes": f32_copies,
            "sharing_ratio": f32_copies / max(shared, 1),
            "view_bytes": {b: self.view_bytes(b) for b in (8, *SUB_BYTE_BITS)},
        }


def pack_int4(codes):
    """codes: int8 array in [-8, 7], last dim even -> uint8 packed (…, n/2)."""
    assert codes.shape[-1] % 2 == 0
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(packed):
    """uint8 (…, n/2) -> int8 (…, n) in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def pack_int2(codes):
    """codes: int8 in [-2, 1], last dim % 4 == 0 -> uint8 packed (…, n/4)."""
    assert codes.shape[-1] % 4 == 0
    u = (codes.astype(jnp.int32) & 0x3).astype(jnp.uint8)
    b0, b1, b2, b3 = u[..., 0::4], u[..., 1::4], u[..., 2::4], u[..., 3::4]
    return b0 | (b1 << 2) | (b2 << 4) | (b3 << 6)


def unpack_int2(packed):
    outs = []
    for sh in (0, 2, 4, 6):
        v = ((packed >> sh) & 0x3).astype(jnp.int8)
        outs.append(jnp.where(v >= 2, v - 4, v))
    out = jnp.stack(outs, axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 4)
