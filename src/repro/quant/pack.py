"""Packed weight storage: the one-copy-many-points artifact.

Two layers live here:

* :class:`PackedWeights` / :class:`PackedTensor` — every >=2-D initializer of
  a graph quantized ONCE to int8 master codes + per-output-channel f32 scales.
  W4/W2 working points are *nested truncations* of the same codes
  (``quant.ptq.derive_view``), so N working points share ONE buffer — the
  paper's MDC weight sharing, and what lets ``AccelServer`` switch precision
  per batch with zero weight movement.  The dequant-fused
  ``repro.kernels.qmatmul`` kernels stream these codes directly.
* bit-packing helpers for sub-byte storage (int4: 2/byte, int2: 4/byte) —
  what turns low weight precision into a real HBM-bandwidth win on TPU (the
  paper's BRAM-column effect); the kernels unpack in-VMEM.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Packed master-code artifact (graph-level analogue of ptq.QuantizedParams)
# ---------------------------------------------------------------------------

@dataclass
class PackedTensor:
    """One weight, quantized once: int8 master codes + per-out-channel scale.

    ``codes`` keeps the original weight shape (HWIO for conv, (K, N) for
    Gemm); ``scale`` is f32 and broadcastable against it (keepdims over the
    last axis).  Low-bit working points are derived views of the same codes —
    no storage per point."""

    codes: jax.Array     # int8, original weight shape
    scale: jax.Array     # f32, per-output-channel (last dim), keepdims

    def view(self, bits: int) -> jax.Array:
        """The ``bits``-bit nested-truncation view of the master codes."""
        from repro.quant.ptq import derive_view
        return derive_view(self.codes, bits)

    def dequant(self, bits: int = 8, dtype=jnp.float32) -> jax.Array:
        """Fake-quant float copy at a working point (the legacy writer path —
        under jit over constant codes XLA folds this away)."""
        from repro.quant.ptq import dequant
        return dequant(self.codes, self.scale, bits, dtype)

    def codes_2d(self) -> jax.Array:
        """Codes flattened to (K, N) for the qmatmul kernels (N = out chans)."""
        return self.codes.reshape(-1, self.codes.shape[-1])

    def scale_1d(self) -> jax.Array:
        return self.scale.reshape(-1)

    @property
    def nbytes(self) -> int:
        """Master storage: 1 byte/code + 4 bytes/scale (shared by all points)."""
        return int(self.codes.size) + 4 * int(self.scale.size)


@dataclass
class PackedWeights:
    """All of a graph's quantizable initializers packed to shared master codes.

    ``tensors`` holds the packed >=2-D weights; ``passthrough`` everything that
    stays float (biases, norm stats, 1-D tensors).  One instance backs every
    working-point executable of a :class:`~repro.core.writers.qjax_writer.
    QJaxWriter` — switching W8 -> W4 -> W2 re-reads the same buffers."""

    tensors: Dict[str, PackedTensor]
    passthrough: Dict[str, jax.Array]

    @classmethod
    def from_initializers(cls, initializers: Dict) -> "PackedWeights":
        from repro.quant.ptq import is_quantizable, quantize_channelwise
        tensors, passthrough = {}, {}
        for name, arr in initializers.items():
            w = jnp.asarray(arr)
            if is_quantizable(name, w):
                tensors[name] = PackedTensor(*quantize_channelwise(w))
            else:
                passthrough[name] = w
        return cls(tensors, passthrough)

    def dequantized(self, bits: int = 8, dtype=jnp.float32) -> Dict[str, jax.Array]:
        """Fake-quant float copies at a working point (the pre-packed-engine
        baseline: what each per-point executable used to hold)."""
        out = dict(self.passthrough)
        for name, t in self.tensors.items():
            out[name] = t.dequant(bits, dtype)
        return out

    def code_bytes(self) -> int:
        """Bytes of the shared master buffer (codes + scales)."""
        return sum(t.nbytes for t in self.tensors.values())

    def sharing_report(self, n_points: int) -> Dict[str, float]:
        """Merged-vs-separate weight storage for ``n_points`` working points
        (the MDC LUT-sharing story, in bytes): the shared master vs each point
        holding its own int8 copy (a 1/n_points drop by construction), and —
        the empirical ``sharing_ratio`` — vs the legacy per-point fake-quant
        f32 copies the writers used to bake into each executable."""
        shared = self.code_bytes()
        n_elems = sum(int(t.codes.size) for t in self.tensors.values())
        f32_copies = n_points * 4 * n_elems
        return {
            "n_points": n_points,
            "shared_bytes": shared,
            "per_point_copy_bytes": n_points * shared,
            "per_point_f32_bytes": f32_copies,
            "sharing_ratio": f32_copies / max(shared, 1),
        }


def pack_int4(codes):
    """codes: int8 array in [-8, 7], last dim even -> uint8 packed (…, n/2)."""
    assert codes.shape[-1] % 2 == 0
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(packed):
    """uint8 (…, n/2) -> int8 (…, n) in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def pack_int2(codes):
    """codes: int8 in [-2, 1], last dim % 4 == 0 -> uint8 packed (…, n/4)."""
    assert codes.shape[-1] % 4 == 0
    u = (codes.astype(jnp.int32) & 0x3).astype(jnp.uint8)
    b0, b1, b2, b3 = u[..., 0::4], u[..., 1::4], u[..., 2::4], u[..., 3::4]
    return b0 | (b1 << 2) | (b2 << 4) | (b3 << 6)


def unpack_int2(packed):
    outs = []
    for sh in (0, 2, 4, 6):
        v = ((packed >> sh) & 0x3).astype(jnp.int8)
        outs.append(jnp.where(v >= 2, v - 4, v))
    out = jnp.stack(outs, axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 4)
