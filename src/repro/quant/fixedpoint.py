"""Faithful fixed-point (Qm.n) arithmetic simulation — the ``ap_fixed`` analogue.

Fake-quantization keeps values on the exact 2^-frac grid in f32; products and
sums of grid values with <=23 mantissa bits are exact in f32, so the simulated
network is bit-equivalent to an integer datapath with wide accumulators (the
paper's HLS MACs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QType


def quantize(x, qt: QType):
    """Round to the Qm.n grid and saturate.  Returns the *integer code* (f32)."""
    if qt.is_float:
        return x
    inv = 2.0 ** qt.frac
    code = jnp.round(x.astype(jnp.float32) * inv)
    return jnp.clip(code, qt.qmin, qt.qmax)


def dequantize(code, qt: QType):
    if qt.is_float:
        return code
    return code * qt.scale


def fake_quant(x, qt: QType):
    """x -> nearest representable Qm.n value (straight-through estimator grad)."""
    if qt.is_float:
        return x
    y = dequantize(quantize(x, qt), qt)
    return x + jax.lax.stop_gradient(y - x)


def quant_error(x, qt: QType):
    return jnp.max(jnp.abs(fake_quant(x, qt) - x))


def zero_fraction(x, qt: QType):
    """Fraction of values that quantize to exactly 0 (Table II 'Zero-weights')."""
    if qt.is_float:
        return jnp.mean((x == 0).astype(jnp.float32))
    return jnp.mean((quantize(x, qt) == 0).astype(jnp.float32))
