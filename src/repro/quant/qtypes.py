"""Quantization types.

Two regimes (DESIGN.md §2):

* **Fixed point** (paper-faithful, Vivado ``ap_fixed`` analogue): ``QType(bits,
  frac)`` — signed Qm.n with m = bits-frac integer bits.  Used by the Table II
  reproduction.  Values are *fake-quantized* (held on the exact grid in f32,
  bit-exact for bits <= 23).
* **MXU-native storage**: int8 / int4 / int2-in-int8 symmetric per-channel —
  the at-scale serving path (weight-only quantization).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dataclasses_field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class QType:
    bits: int
    frac: Optional[int] = None   # None => float passthrough
    signed: bool = True

    @property
    def is_float(self) -> bool:
        return self.frac is None

    @property
    def scale(self) -> float:
        assert self.frac is not None
        return 2.0 ** (-self.frac)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.signed else 2 ** self.bits - 1

    def __str__(self) -> str:
        return "float" if self.is_float else f"Q{self.bits - (self.frac or 0)}.{self.frac}"


FLOAT = QType(32, None)


def fixed_for_range(bits: int, max_abs: float) -> QType:
    """Pick the Qm.n split so [−max_abs, max_abs] fits (the HLS-writer policy:
    integer bits to cover the calibrated range, remaining bits fractional).

    Integer bits may be *negative* (ap_fixed allows it): small-magnitude weight
    tensors (max |w| << 1) then keep every bit as fraction — at W4 this is the
    difference between the paper's 97 % and a collapsed accuracy."""
    import math
    max_abs = max(float(max_abs), 1e-8)
    int_bits = math.ceil(math.log2(max_abs + 1e-12))   # qmax*scale >= max_abs
    frac = bits - 1 - int_bits                         # 1 sign bit
    return QType(bits, frac)


@dataclass(frozen=True)
class DatatypeConfig:
    """The paper's ``Dx-Wy`` mixed-precision working point."""
    act_bits: int      # x — activation bits (32 = float)
    weight_bits: int   # y — weight bits (32 = float)

    @property
    def name(self) -> str:
        return f"D{self.act_bits}-W{self.weight_bits}"


@dataclass(frozen=True)
class PrecisionMap:
    """Per-layer precision: a default ``Dx-Wy`` point plus node-name
    overrides.  This is the heterogeneous generalization of the paper's single
    global ``DatatypeConfig`` — the precision-assignment pass stamps
    ``for_node(name)`` onto every IR node, and the writers quantize each
    actor's weights/FIFO independently."""
    default: DatatypeConfig
    per_node: "Mapping[str, DatatypeConfig]" = dataclasses_field(default_factory=dict)

    def for_node(self, name: str) -> DatatypeConfig:
        return self.per_node.get(name, self.default)

    @property
    def min_act_bits(self) -> int:
        return min([self.default.act_bits] +
                   [c.act_bits for c in self.per_node.values()])

    @property
    def min_weight_bits(self) -> int:
        return min([self.default.weight_bits] +
                   [c.weight_bits for c in self.per_node.values()])

    @property
    def name(self) -> str:
        if not self.per_node:
            return self.default.name
        ov = ",".join(f"{n}:{c.name}" for n, c in sorted(self.per_node.items()))
        return f"{self.default.name}[{ov}]"


# Table II exploration points
TABLE2_POINTS = (
    DatatypeConfig(32, 32),
    DatatypeConfig(16, 16),
    DatatypeConfig(8, 16),
    DatatypeConfig(16, 8),
    DatatypeConfig(16, 4),
    DatatypeConfig(16, 2),
)


def storage_dtype(bits: int):
    """MXU-native storage dtype for a weight bit-width."""
    if bits >= 16:
        return jnp.bfloat16
    if bits > 4:
        return jnp.int8
    if bits > 2:
        return jnp.int4
    return jnp.int8  # int2 packed 4-per-byte elsewhere; unpacked sim in int8
