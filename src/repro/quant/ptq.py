"""Post-training quantization (the paper's regime, §IV: "quantized using
post-training quantization").

Two entry points:

* ``quantize_tree_fixed``   — paper-faithful Qm.n fake-quant of a param tree
  for a ``Dx-Wy`` point (weights here; activations are quantized at runtime by
  the writers / LM forward via ``ActQuant``).
* ``quantize_tree_native``  — MXU-native weight-only quantization: symmetric
  per-output-channel int8 master + f32 scales; W4/W2 are *derived views* of the
  same master (nested truncation), which is what lets the adaptive accelerator
  share one weight copy across working points (DESIGN.md §2, MDC row).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.fixedpoint import fake_quant, zero_fraction
from repro.quant.qtypes import QType, DatatypeConfig, fixed_for_range

# parameters that stay in high precision (norms, scalar gains, recurrence)
_SKIP_SUFFIXES = ("norm/w", "norm_w", "A_log", "dt_bias", "/D", "/b", "bias",
                  "/mean", "/var", "/scale", "bq", "bk", "bv", "b_up", "b_down",
                  "enc_pos", "dec_pos")


def is_quantizable(path: str, arr) -> bool:
    return arr.ndim >= 2 and not any(path.endswith(s) for s in _SKIP_SUFFIXES)


# ---------------------------------------------------------------------------
# Fixed-point (Table II) path
# ---------------------------------------------------------------------------

def weight_qtype(w, bits: int) -> QType:
    if bits >= 32:
        return QType(32, None)
    return fixed_for_range(bits, float(jnp.max(jnp.abs(w))))


def effective_weight_dt(graph, init_name: str,
                        default_dt: Optional[DatatypeConfig] = None
                        ) -> Optional[DatatypeConfig]:
    """The per-layer datatype governing an initializer: its (first) consumer
    node's ``Node.dtconfig``, falling back to ``default_dt``.  Single source
    of truth for the writers, the stats, and the storage model."""
    users = graph.consumer_index().get(init_name, [])
    if users and users[0].dtconfig is not None:
        return users[0].dtconfig
    return default_dt


def graph_weight_stats(graph, default_dt: Optional[DatatypeConfig] = None
                       ) -> Dict[str, float]:
    """Zero-weight fraction of an IR graph under *per-layer* precision: each
    initializer is quantized at its consumer node's ``Node.dtconfig`` weight
    bits (falling back to ``default_dt``).  This is the Table II
    "Zero weights" column generalized to heterogeneous assignments."""
    zeros, total = 0.0, 0
    for name, arr in graph.initializers.items():
        if arr.ndim < 2:
            continue
        dt = effective_weight_dt(graph, name, default_dt)
        w = jnp.asarray(arr)
        qt = weight_qtype(w, dt.weight_bits if dt else 32)
        zeros += float(zero_fraction(w, qt)) * arr.size
        total += arr.size
    return {"zero_weight_frac": zeros / max(total, 1)}


def quantize_tree_fixed(params: Dict[str, jax.Array], dt: DatatypeConfig
                        ) -> Tuple[Dict[str, jax.Array], Dict[str, float]]:
    """Fake-quantize weights to Wy.  Returns (new params, stats)."""
    out, zeros, total = {}, 0.0, 0
    for path, w in params.items():
        if is_quantizable(path, w) and dt.weight_bits < 32:
            qt = weight_qtype(w, dt.weight_bits)
            out[path] = fake_quant(w, qt)
            n = w.size
            zeros += float(zero_fraction(w, qt)) * n
            total += n
        else:
            out[path] = w
    stats = {"zero_weight_frac": zeros / max(total, 1)}
    return out, stats


@dataclass
class ActQuant:
    """Runtime activation quantizer for Dx (calibrated per-site)."""
    bits: int
    ranges: Dict[str, float]    # site name -> calibrated max |act|

    def __call__(self, name: str, x):
        if self.bits >= 32:
            return x
        qt = fixed_for_range(self.bits, self.ranges.get(name, 8.0))
        return fake_quant(x, qt)


def calibrate_acts(capture_fn: Callable[[], Dict[str, jax.Array]]) -> Dict[str, float]:
    """capture_fn runs the model on a calibration batch and returns named
    intermediate activations; we record per-site max |x|."""
    acts = capture_fn()
    return {k: float(jnp.max(jnp.abs(v))) for k, v in acts.items()}


def top1_agreement(logits, ref) -> float:
    """Fraction of calibration rows whose argmax matches the float
    reference's — the accuracy proxy every explorer in the flow optimizes
    (greedy mixed-precision descent and the DSE's accuracy objective)."""
    return float(jnp.mean((jnp.argmax(logits, -1) == jnp.argmax(ref, -1))
                          .astype(jnp.float32)))


def act_code_qtype(bits: int, act_range: float) -> QType:
    """The integer-code qtype of one activation FIFO: a power-of-two scale
    (``2^-frac``) sized so the calibrated range fits ``min(bits, 8)`` signed
    integers.  This is what the fully-integer hot path threads between
    layers — the producer's kernel epilogue emits these int8 codes and the
    consumer folds ``2^-frac`` into its weight scales (one f32 multiply per
    output channel, zero per-element dequant work)."""
    return fixed_for_range(min(bits, 8), act_range)


def act_code_scales(act_ranges: Dict[str, float], bits: int = 8
                    ) -> Dict[str, QType]:
    """Per-FIFO activation-code qtypes from calibrated ranges (the artifact
    ``DesignFlow.calibrate`` feeds to the ``qjax`` writer)."""
    return {name: act_code_qtype(bits, r) for name, r in act_ranges.items()}


# ---------------------------------------------------------------------------
# MXU-native weight-only path (LM serving)
# ---------------------------------------------------------------------------

@dataclass
class QuantizedParams:
    """int8 master codes + per-channel scales; low-bit views derived on read."""
    codes: Dict[str, jax.Array]      # int8, same shape as the weight
    scales: Dict[str, jax.Array]     # f32, broadcastable (per out-channel)
    passthrough: Dict[str, jax.Array]  # unquantized params (norms, embeds opt-out)
    bits: int = 8                    # active working point (8 / 4 / 2)

    def tree(self):
        return {"codes": self.codes, "scales": self.scales,
                "passthrough": self.passthrough}


def _channel_scale(w):
    """Symmetric per-output-channel scale; channel = last dim."""
    m = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(range(w.ndim - 1)),
                keepdims=True)
    return jnp.maximum(m, 1e-8) / 127.0


def quantize_channelwise(w) -> Tuple[jax.Array, jax.Array]:
    """(int8 master codes, per-out-channel f32 scale) — THE master-code rule.
    Single source of truth shared by the LM-serving tree path below and the
    graph-level :class:`repro.quant.pack.PackedWeights`."""
    s = _channel_scale(w)
    codes = jnp.clip(jnp.round(w.astype(jnp.float32) / s),
                     -127, 127).astype(jnp.int8)
    return codes, s.astype(jnp.float32)


def quantize_tree_native(params: Dict[str, jax.Array],
                         quant_embeddings: bool = False) -> QuantizedParams:
    codes, scales, passthrough = {}, {}, {}
    for path, w in params.items():
        quantize = is_quantizable(path, w)
        if not quant_embeddings and path.startswith(("embed/", "lm_head/")):
            quantize = False
        if quantize:
            codes[path], scales[path] = quantize_channelwise(w)
        else:
            passthrough[path] = w
    return QuantizedParams(codes, scales, passthrough)


def derive_view(code_i8, bits: int):
    """Nested truncation: int8 master -> effective int-``bits`` codes, still in
    int8 domain (granularity 2^(8-bits)); shares the master's scale."""
    if bits >= 8:
        return code_i8
    sh = 8 - bits
    step = 1 << sh
    q = jnp.clip(jnp.round(code_i8.astype(jnp.float32) / step),
                 -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    return (q * step).astype(jnp.int8)


def dequant(code_i8, scale, bits: int = 8, dtype=jnp.bfloat16):
    return (derive_view(code_i8, bits).astype(jnp.float32) * scale).astype(dtype)


def dequantize_tree(qp: QuantizedParams, bits: Optional[int] = None,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    b = qp.bits if bits is None else bits
    out = dict(qp.passthrough)
    for path, c in qp.codes.items():
        out[path] = dequant(c, qp.scales[path], b, dtype)
    return out


def quant_memory_bytes(qp: QuantizedParams, bits: int, packed: bool = True) -> int:
    """Weight-storage footprint at a working point (packed sub-byte storage)."""
    per_val = bits / 8.0 if packed else 1.0
    n_q = sum(int(np.prod(c.shape)) for c in qp.codes.values())
    n_s = sum(int(np.prod(s.shape)) * 4 for s in qp.scales.values())
    n_p = sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
              for p in qp.passthrough.values())
    return int(n_q * per_val) + n_s + n_p
