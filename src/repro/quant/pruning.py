"""Computation Reduction (paper §II-B-a): magnitude pruning + zero accounting.

On TPU the MXU cannot skip individual zero multiplications; the exploitable
effects are (a) the *memory* side (packed sparse/low-bit weights shrink HBM
traffic) and (b) structured sparsity that removes whole blocks.  We implement
magnitude + structured N:M pruning and account for both in the roofline model.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.quant.ptq import is_quantizable


def magnitude_prune(w, sparsity: float):
    """Zero exactly the ``sparsity`` fraction of smallest-|w| entries
    (rank-based, deterministic under ties)."""
    if sparsity <= 0.0:
        return w
    k = int(w.size * sparsity)
    if k == 0:
        return w
    flat = jnp.abs(w).reshape(-1)
    order = jnp.argsort(flat, stable=True)
    keep = jnp.ones_like(flat, bool).at[order[:k]].set(False)
    return (w.reshape(-1) * keep).reshape(w.shape).astype(w.dtype)


def nm_prune(w, n: int = 2, m: int = 4):
    """Structured N:M pruning along the last dim (keep n largest of every m)."""
    assert w.shape[-1] % m == 0
    g = w.reshape(*w.shape[:-1], w.shape[-1] // m, m)
    mag = jnp.abs(g)
    kth = jnp.sort(mag, axis=-1)[..., m - n][..., None]
    keep = mag >= kth
    return (g * keep).reshape(w.shape).astype(w.dtype)


def prune_tree(params: Dict[str, jax.Array], sparsity: float,
               structured: bool = False) -> Tuple[Dict[str, jax.Array], Dict[str, float]]:
    out, zeros, total = {}, 0.0, 0
    for path, w in params.items():
        if is_quantizable(path, w):
            out[path] = nm_prune(w) if structured else magnitude_prune(w, sparsity)
            zeros += float(jnp.mean((out[path] == 0).astype(jnp.float32))) * w.size
            total += w.size
        else:
            out[path] = w
    return out, {"zero_weight_frac": zeros / max(total, 1)}


def zero_weight_fraction(params: Dict[str, jax.Array]) -> float:
    zeros, total = 0.0, 0
    for path, w in params.items():
        if is_quantizable(path, w):
            zeros += float(jnp.mean((w == 0).astype(jnp.float32))) * w.size
            total += w.size
    return zeros / max(total, 1)
