"""Sharding rules for the production mesh.

Axis conventions (DESIGN.md §4):
  - ``pod``   : data-parallel replication across pods (multi-pod mesh only)
  - ``data``  : data parallelism (batch / tokens)
  - ``model`` : tensor parallelism (flattened head dims, FFN hidden, vocab, experts)

All *explicit* shardings are placed on dims that divide the 16-way axes; head-level
tensors are constrained only on flattened dims and left to SPMD propagation otherwise.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    """Axes used for data parallelism (pod axis folded in when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def batch_spec(mesh: Mesh, *rest) -> P:
    """PartitionSpec with the batch dim sharded over all DP axes."""
    return P(batch_axes(mesh), *rest)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules.
#
# Parameters are stored in a flat dict {path: array}; the rule is selected by
# path suffix.  Stacked-over-layers params have a leading L dim (never sharded).
# ---------------------------------------------------------------------------

_RULES = (
    # (suffix, candidate specs WITHOUT the leading layer-stack dim; first whose
    #  sharded dims divide the model axis wins)
    ("embed/table", (P("model", None),)),          # (V, d) vocab-sharded
    ("lm_head/w", (P(None, "model"),)),            # (d, V)
    ("attn/wq", (P(None, "model"),)),              # (d, H*Dh)
    ("attn/wk", (P(None, "model"),)),              # (d, Hkv*Dh)
    ("attn/wv", (P(None, "model"),)),
    ("attn/wo", (P("model", None),)),              # (H*Dh, d)
    ("attn/bq", (P("model"),)),
    ("attn/bk", (P("model"),)),
    ("attn/bv", (P("model"),)),
    ("mlp/w_gate", (P(None, "model"),)),           # (d, f)
    ("mlp/w_up", (P(None, "model"),)),
    ("mlp/w_down", (P("model", None),)),           # (f, d)
    ("moe/w_gate", (P("model", None, None, None),)),  # (tp_total, E/ep, d, f/tp)
    ("moe/w_up", (P("model", None, None, None),)),
    ("moe/w_down", (P("model", None, None, None),)),
    ("moe/router", (P(),)),                        # (d, E) replicated (tiny)
    ("ssm/w_z", (P(None, "model"),)),              # (d, d_inner)
    ("ssm/w_x", (P(None, "model"),)),
    ("ssm/w_bc", (P(None, "model"),)),             # (d, 2GN)
    ("ssm/w_dt", (P(),)),                          # (d, H) tiny: replicate
    ("ssm/w_out", (P("model", None),)),            # (d_inner, d)
    ("ssm/conv", (P(None, "model"),)),             # (K, conv_dim)
    ("ssm/A_log", (P("model"),)),                  # (H,) if H % 16 == 0
    ("ssm/D", (P("model"),)),
    ("ssm/dt_bias", (P("model"),)),
    ("ssm/norm_w", (P("model"),)),
    ("cross/wq", (P(None, "model"),)),
    ("cross/wk", (P(None, "model"),)),
    ("cross/wv", (P(None, "model"),)),
    ("cross/wo", (P("model", None),)),
)


def param_spec(path: str, shape: Sequence[int], mesh: Mesh, stacked: bool = True) -> P:
    """PartitionSpec for parameter ``path`` with given global ``shape``.

    Falls back to replication when no candidate's sharded dim divides the
    model-axis size (jax rejects uneven explicit shardings).
    """
    tp = mesh.shape["model"]
    for suffix, specs in _RULES:
        if not path.endswith(suffix):
            continue
        for spec in specs:
            parts = list(spec)
            lead = 1 if (stacked and len(shape) == len(parts) + 1) else 0
            parts = [None] * lead + parts
            if len(parts) != len(shape):
                continue  # rank mismatch: try next candidate
            if all(ax != "model" or shape[i] % tp == 0 for i, ax in enumerate(parts)):
                return P(*parts)
        return P()  # no candidate fits: replicate (small tensors only)
    return P()  # norms, biases, scales: replicated


def param_sharding(params: dict, mesh: Mesh, stacked: bool = True) -> dict:
    return {
        k: NamedSharding(mesh, param_spec(k, v.shape, mesh, stacked=stacked))
        for k, v in params.items()
    }


def opt_state_spec(path: str, shape: Sequence[int], mesh: Mesh) -> P:
    """ZeRO-1: moments additionally sharded over ``data`` on the largest
    even-divisible dim not already sharded by the param rule."""
    base = param_spec(path, shape, mesh, stacked=True)
    parts = list(base) + [None] * (len(shape) - len(base))
    dsz = mesh.shape["data"]
    # pick the largest dim that is free and divides the data axis
    cands = [i for i, ax in enumerate(parts) if ax is None and shape[i] % dsz == 0]
    if cands:
        i = max(cands, key=lambda i: shape[i])
        parts[i] = "data"
    return P(*parts)
