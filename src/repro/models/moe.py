"""Mixture-of-Experts block with ep×tp expert parallelism (DESIGN.md §4).

Layout: the 16-way ``model`` axis factors into ``ep = gcd(E, 16)`` expert groups
× ``tp = 16/ep`` tensor slices.  Expert weights are stored pre-arranged as
``(tp_total, E/ep, d, f/tp)``; rank ``r`` (model-axis index) owns the tp-slice
``r % tp`` of experts ``[(r//tp)·E/ep, (r//tp+1)·E/ep)``.

Activations enter the block replicated over ``model``, so dispatch (capacity
gather) and combine (scatter-add) are *collective-free*; the single ``psum``
over ``model`` both merges expert outputs and completes the tp partial sums.
FLOPs stay ∝ top-k via capacity-based token selection (one argsort + static
dynamic-slices per local expert).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.common import swiglu
from repro.models.params import moe_factors


class MoELayerParams(NamedTuple):
    router: jax.Array   # (d, E)
    w_gate: jax.Array   # (tp_total, E/ep, d, f/tp)
    w_up: jax.Array
    w_down: jax.Array   # (tp_total, E/ep, f/tp, d)


def route(x, router_w, top_k: int):
    """x: (T, d) -> (probs (T,k) f32, experts (T,k) i32, logits (T,E) f32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    vals, idx = jax.lax.top_k(logits, top_k)
    probs = jax.nn.softmax(vals, axis=-1)
    return probs, idx, logits


def aux_losses(logits, experts, n_experts: int) -> Tuple[jax.Array, jax.Array]:
    """(load-balance loss, router z-loss) — standard Switch/ST-MoE auxiliaries."""
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    me = jnp.mean(probs, axis=0)                                 # mean router prob
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], n_experts), axis=0)  # top-1 load
    lb = n_experts * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return lb, z


def _expert_ffn(xe, wg, wu, wd):
    """xe: (C, d); wg/wu: (d, fl); wd: (fl, d)."""
    h = swiglu(xe @ wg, xe @ wu)
    return h @ wd


def moe_shard_body(x, p: MoELayerParams, cfg: ModelConfig, tp_total: int,
                   rank) -> jax.Array:
    """Per-model-rank body.  x: (T_loc, d) replicated over model;
    p.w_*: local block (1, E/ep, d, fl) / (1, E/ep, fl, d); rank: model index."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    ep, tp = moe_factors(E, tp_total)
    e_loc = E // ep
    T = x.shape[0]
    cap = max(int(math.ceil(T * k * m.capacity_factor / E)), 1)
    cap = min(cap, T)

    probs, experts, logits = route(x, p.router, k)               # (T,k)
    flat_e = experts.reshape(-1)                                 # (T*k,)
    flat_p = probs.reshape(-1)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k

    # group token-slots by expert with one stable argsort
    order = jnp.argsort(flat_e * (T * k) + jnp.arange(T * k, dtype=jnp.int32))
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                      # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])

    grp = rank // tp                                             # my ep group
    out = jnp.zeros_like(x)
    wg = p.w_gate[0]                                             # (E/ep, d, fl)
    wu = p.w_up[0]
    wd = p.w_down[0]
    for j in range(e_loc):                                       # unrolled, <= 5
        e_id = grp * e_loc + j
        # dynamic_slice clamps starts near the end; membership in the sorted
        # segment is the correct validity test under clamping (capacity
        # dropping = the segment's tail beyond `cap` never enters the slice)
        start = jnp.minimum(starts[e_id], T * k - cap)
        slot_idx = jax.lax.dynamic_slice(order, (start,), (cap,))
        seg = jax.lax.dynamic_slice(sorted_e, (start,), (cap,))
        pos_in_seg = jnp.arange(cap) + (start - starts[e_id])
        valid = (seg == e_id) & (pos_in_seg < jnp.minimum(counts[e_id], cap))
        tok = flat_tok[slot_idx]
        xe = jnp.take(x, tok, axis=0) * valid[:, None].astype(x.dtype)
        ye = _expert_ffn(xe, wg[j], wu[j], wd[j])
        w = (flat_p[slot_idx] * valid).astype(x.dtype)
        out = out.at[tok].add(ye * w[:, None], mode="drop")
    lb, z = aux_losses(logits, experts, E)
    return out, lb, z


def moe_block(x, p: MoELayerParams, cfg: ModelConfig, mesh: Optional[Mesh],
              tp_total: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B,S,d), load-balance loss, z loss)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if mesh is None or tp_total == 1:
        y, lb, z = moe_shard_body(xt, p, cfg, 1, 0)
        return y.reshape(B, S, d), lb, z

    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ndp = 1
    for ax in dp:
        ndp *= mesh.shape[ax]
    # tiny decode batches (e.g. long_500k, 1 token) can't shard over dp:
    # replicate tokens instead (each data shard redundantly computes them)
    tok_spec = P(dp, None) if (B * S) % ndp == 0 else P(None, None)
    dp_axes = dp if tok_spec[0] is not None else ()

    def body(xt, router, wg, wu, wd):
        rank = jax.lax.axis_index("model")
        pl = MoELayerParams(router, wg, wu, wd)
        y, lb, z = moe_shard_body(xt, pl, cfg, tp_total, rank)
        y = jax.lax.psum(y, "model")
        if dp_axes:
            lb = jax.lax.pmean(lb, dp_axes)
            z = jax.lax.pmean(z, dp_axes)
        return y, lb, z

    y, lb, z = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(), P("model", None, None, None),
                  P("model", None, None, None), P("model", None, None, None)),
        out_specs=(tok_spec, P(), P()),
        check_rep=False,
    )(xt, p.router, p.w_gate, p.w_up, p.w_down)
    return y.reshape(B, S, d), lb, z
