"""Decoder-only LM assembly (dense / MoE / SSM / hybrid / VLM-stub).

Layers are stacked on a leading L dim and executed with ``lax.scan`` so the
HLO stays compact for the 512-device dry-run; the per-layer body is optionally
rematerialized (``remat=True``) for the training memory term.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import LayerAttnParams, attention, cache_size, decode_attention
from repro.models.common import embed_lookup, norm, swiglu, gelu, unembed
from repro.models.moe import MoELayerParams, moe_block
from repro.models.ssm import SSMLayerParams, SSMState, init_ssm_state

LAYER_PREFIX = "layers/"


def layer_tree(params: Dict[str, jax.Array], prefix: str = LAYER_PREFIX) -> Dict[str, jax.Array]:
    return {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}


def _attn_params(lp: Dict[str, jax.Array], prefix: str = "attn") -> LayerAttnParams:
    return LayerAttnParams(
        wq=lp[f"{prefix}/wq"], wk=lp[f"{prefix}/wk"], wv=lp[f"{prefix}/wv"],
        wo=lp[f"{prefix}/wo"],
        bq=lp.get(f"{prefix}/bq"), bk=lp.get(f"{prefix}/bk"), bv=lp.get(f"{prefix}/bv"))


def _ssm_params(lp: Dict[str, jax.Array]) -> SSMLayerParams:
    return SSMLayerParams(
        w_z=lp["ssm/w_z"], w_x=lp["ssm/w_x"], w_bc=lp["ssm/w_bc"],
        w_dt=lp["ssm/w_dt"], conv=lp["ssm/conv"], A_log=lp["ssm/A_log"],
        D=lp["ssm/D"], dt_bias=lp["ssm/dt_bias"], norm_w=lp["ssm/norm_w"],
        w_out=lp["ssm/w_out"])


def _moe_params(lp: Dict[str, jax.Array]) -> MoELayerParams:
    return MoELayerParams(router=lp["moe/router"], w_gate=lp["moe/w_gate"],
                          w_up=lp["moe/w_up"], w_down=lp["moe/w_down"])


def _mlp(x, lp, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = swiglu(jnp.einsum("bsd,df->bsf", x, lp["mlp/w_gate"]),
                   jnp.einsum("bsd,df->bsf", x, lp["mlp/w_up"]))
        return jnp.einsum("bsf,fd->bsd", h, lp["mlp/w_down"])
    h = gelu(jnp.einsum("bsd,df->bsf", x, lp["mlp/w_up"]) + lp["mlp/b_up"])
    return jnp.einsum("bsf,fd->bsd", h, lp["mlp/w_down"]) + lp["mlp/b_down"]


def _token_mixer(x, lp, cfg: ModelConfig, positions, mesh, unroll: bool = False):
    """Full-sequence mixer for one layer; returns (dx, (k, v, ssm_state))."""
    k = v = ssm_state = None
    if cfg.family == "ssm":
        xn = norm(x, lp["ssm_norm/w"], cfg.norm)
        dx, ssm_state = ssm_mod.ssm_block(xn, _ssm_params(lp), cfg, mesh=mesh)
    elif cfg.hybrid:
        xn = norm(x, lp["attn_norm/w"], cfg.norm)
        a, k, v = attention(xn, _attn_params(lp), cfg, positions=positions,
                            unroll=unroll, mesh=mesh)
        s, ssm_state = ssm_mod.ssm_block(norm(x, lp["ssm_norm/w"], cfg.norm),
                                         _ssm_params(lp), cfg, mesh=mesh)
        dx = 0.5 * (a + s)
    else:
        xn = norm(x, lp["attn_norm/w"], cfg.norm)
        dx, k, v = attention(xn, _attn_params(lp), cfg, positions=positions,
                             unroll=unroll, mesh=mesh)
    return dx, (k, v, ssm_state)


def _channel_mixer(x, lp, cfg: ModelConfig, mesh, tp_total):
    """FFN / MoE part; returns (dx, (lb, z)) aux losses."""
    if cfg.moe is not None:
        xn = norm(x, lp["mlp_norm/w"], cfg.norm)
        dx, lb, z = moe_block(xn, _moe_params(lp), cfg, mesh, tp_total)
        return dx, (lb, z)
    if cfg.d_ff > 0:
        xn = norm(x, lp["mlp_norm/w"], cfg.norm)
        return _mlp(xn, lp, cfg), (jnp.zeros((), jnp.float32),) * 2
    return jnp.zeros_like(x), (jnp.zeros((), jnp.float32),) * 2


def embed_inputs(params, cfg: ModelConfig, tokens, patch_embeds=None, mesh=None):
    x = embed_lookup(params["embed/table"], tokens)
    if cfg.n_patches and patch_embeds is not None:
        pe = jnp.einsum("bpd,de->bpe", patch_embeds.astype(x.dtype), params["vision_proj/w"])
        x = jnp.concatenate([pe, x[:, cfg.n_patches:, :]], axis=1)
    return x


def forward(params: Dict[str, jax.Array], tokens, cfg: ModelConfig, *,
            mesh: Optional[Mesh] = None, tp_total: int = 1,
            patch_embeds=None, remat: bool = False,
            collect_cache: bool = False, unroll: bool = False):
    """tokens: (B, S) -> (logits (B, S, Vp), aux dict).

    With ``collect_cache`` also returns stacked per-layer (k, v, ssm_state)
    for prefill→decode handoff.
    """
    B, S = tokens.shape
    x = embed_inputs(params, cfg, tokens, patch_embeds, mesh)
    positions = jnp.arange(S)
    lt = layer_tree(params)

    def layer(carry, lp):
        x, lb_acc, z_acc = carry
        dx, cache = _token_mixer(x, lp, cfg, positions, mesh, unroll)
        x = x + dx  # noqa: PLW2901
        dx, (lb, z) = _channel_mixer(x, lp, cfg, mesh, tp_total)
        x = x + dx
        ys = cache if collect_cache else None
        return (x, lb_acc + lb, z_acc + z), ys

    if remat:
        layer = jax.checkpoint(layer)

    zero = jnp.zeros((), jnp.float32)
    (x, lb, z), caches = jax.lax.scan(layer, (x, zero, zero), lt,
                                      unroll=cfg.n_layers if unroll else 1)
    x = norm(x, params["final_norm/w"], cfg.norm)
    logits = unembed(x, params["embed/table"] if cfg.tie_embeddings
                     else params["lm_head/w"], cfg.tie_embeddings)
    aux = {"lb_loss": lb / cfg.n_layers, "z_loss": z / cfg.n_layers}
    if collect_cache:
        return logits, aux, caches
    return logits, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    cache_k: Optional[jax.Array]   # (L, B, Smax, Hkv*Dh) — kv dim flattened
    cache_v: Optional[jax.Array]
    ssm_ssd: Optional[jax.Array]   # (L, B, H*P, N) f32 — head dim flattened
    ssm_conv: Optional[jax.Array]  # (L, B, K-1, conv_dim)
    index: jax.Array               # scalar i32: tokens already in cache


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    L = cfg.n_layers
    ck = cv = sd = sc = None
    if cfg.family != "ssm":
        smax = cache_size(cfg, seq_len)
        ck = jnp.zeros((L, batch, smax, cfg.kv_dim), dtype)
        cv = jnp.zeros_like(ck)
    if cfg.family in ("ssm", "hybrid"):
        st = init_ssm_state(cfg, batch, dtype)
        sd = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm.d_state), jnp.float32)
        sc = jnp.broadcast_to(st.conv[None], (L,) + st.conv.shape)
    return DecodeState(ck, cv, sd, sc, jnp.zeros((), jnp.int32))


def abstract_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                          dtype=jnp.bfloat16) -> DecodeState:
    proto = jax.eval_shape(lambda: init_decode_state(cfg, batch, seq_len, dtype))
    return proto


def decode_step(params: Dict[str, jax.Array], tokens, state: DecodeState,
                cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                tp_total: int = 1, unroll: bool = False):
    """tokens: (B, 1) -> (logits (B, 1, Vp), new DecodeState)."""
    x = embed_lookup(params["embed/table"], tokens)
    lt = layer_tree(params)
    idx = state.index

    def _unflat_ssd(sd):
        B = sd.shape[0]
        return sd.reshape(B, cfg.n_ssm_heads, cfg.ssm.d_head, cfg.ssm.d_state)

    def _flat_ssd(sd):
        B = sd.shape[0]
        return sd.reshape(B, cfg.d_inner, cfg.ssm.d_state)

    def layer(x, lp_and_cache):
        lp, ck, cv, sd, sc = lp_and_cache
        new = [ck, cv, sd, sc]
        if cfg.family == "ssm":
            xn = norm(x, lp["ssm_norm/w"], cfg.norm)
            dx, st = ssm_mod.ssm_decode(xn, _ssm_params(lp), cfg,
                                        SSMState(_unflat_ssd(sd), sc))
            new[2], new[3] = _flat_ssd(st.ssd), st.conv
        elif cfg.hybrid:
            xn = norm(x, lp["attn_norm/w"], cfg.norm)
            a, nk, nv = decode_attention(xn, _attn_params(lp), cfg, ck, cv, idx,
                                         mesh=mesh)
            s, st = ssm_mod.ssm_decode(norm(x, lp["ssm_norm/w"], cfg.norm),
                                       _ssm_params(lp), cfg,
                                       SSMState(_unflat_ssd(sd), sc))
            dx = 0.5 * (a + s)
            new[0], new[1], new[2], new[3] = nk, nv, _flat_ssd(st.ssd), st.conv
        else:
            xn = norm(x, lp["attn_norm/w"], cfg.norm)
            dx, nk, nv = decode_attention(xn, _attn_params(lp), cfg, ck, cv, idx,
                                          mesh=mesh)
            new[0], new[1] = nk, nv
        x = x + dx
        dx, _ = _channel_mixer(x, lp, cfg, mesh, tp_total)
        return x + dx, tuple(new)

    dummy = jnp.zeros((cfg.n_layers, 1, 1), jnp.int8)
    xs = (lt,
          state.cache_k if state.cache_k is not None else dummy,
          state.cache_v if state.cache_v is not None else dummy,
          state.ssm_ssd if state.ssm_ssd is not None else dummy,
          state.ssm_conv if state.ssm_conv is not None else dummy)

    def body(x, xs_l):
        lp = xs_l[0]
        return layer(x, (lp, *xs_l[1:]))

    x, (nk, nv, nsd, nsc) = jax.lax.scan(body, x, xs,
                                         unroll=cfg.n_layers if unroll else 1)
    x = norm(x, params["final_norm/w"], cfg.norm)
    logits = unembed(x, params["embed/table"] if cfg.tie_embeddings
                     else params["lm_head/w"], cfg.tie_embeddings)
    new_state = DecodeState(
        cache_k=None if state.cache_k is None else nk,
        cache_v=None if state.cache_v is None else nv,
        ssm_ssd=None if state.ssm_ssd is None else nsd,
        ssm_conv=None if state.ssm_conv is None else nsc,
        index=idx + 1)
    return logits, new_state
