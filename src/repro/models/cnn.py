"""The paper's accelerator model (Table II): two convolutional blocks
(conv → maxpool → batchnorm → relu) followed by one fully connected layer.

This is the model the ONNX-to-hardware flow compiles; it exists both as this
plain-JAX definition (training + oracle) and as an IR graph
(``repro.core.reader.cnn_to_ir``) lowered by the writers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import CNNConfig


def init_params(cfg: CNNConfig, key) -> Dict[str, jax.Array]:
    params: Dict[str, jax.Array] = {}
    cin = cfg.in_channels
    ks = jax.random.split(key, len(cfg.conv_channels) + 1)
    for i, cout in enumerate(cfg.conv_channels):
        fan = cfg.kernel_size * cfg.kernel_size * cin
        params[f"conv{i}/w"] = (jax.random.normal(ks[i], (cfg.kernel_size, cfg.kernel_size, cin, cout)) / jnp.sqrt(fan)).astype(jnp.float32)
        params[f"conv{i}/b"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/scale"] = jnp.ones((cout,), jnp.float32)
        params[f"bn{i}/bias"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/mean"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/var"] = jnp.ones((cout,), jnp.float32)
        cin = cout
    params["fc/w"] = (jax.random.normal(ks[-1], (cfg.fc_in, cfg.n_classes)) / jnp.sqrt(cfg.fc_in)).astype(jnp.float32)
    params["fc/b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params


def conv2d(x, w, b):
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout) — SAME padding, stride 1."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def maxpool(x, k: int):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                                 (1, k, k, 1), "VALID")


def batchnorm(x, scale, bias, mean, var, eps: float = 1e-5):
    inv = scale * jax.lax.rsqrt(var + eps)
    return x * inv + (bias - mean * inv)


def forward(params: Dict[str, jax.Array], x, cfg: CNNConfig,
            train_stats: bool = False):
    """x: (B, H, W, C) -> logits (B, n_classes).

    train_stats: use batch statistics (training); else the stored running stats.
    """
    aux = {}
    for i in range(len(cfg.conv_channels)):
        x = conv2d(x, params[f"conv{i}/w"], params[f"conv{i}/b"])
        x = maxpool(x, cfg.pool)
        if train_stats:
            mean = jnp.mean(x, axis=(0, 1, 2))
            var = jnp.var(x, axis=(0, 1, 2))
            aux[f"bn{i}/mean"], aux[f"bn{i}/var"] = mean, var
        else:
            mean, var = params[f"bn{i}/mean"], params[f"bn{i}/var"]
        x = batchnorm(x, params[f"bn{i}/scale"], params[f"bn{i}/bias"], mean, var)
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc/w"] + params["fc/b"], aux


def loss_fn(params, x, labels, cfg: CNNConfig) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, x, cfg, train_stats=True)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold), aux


def accuracy(params, x, labels, cfg: CNNConfig) -> jax.Array:
    logits, _ = forward(params, x, cfg)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
