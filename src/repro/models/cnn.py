"""The paper's accelerator model (Table II): two convolutional blocks
(conv → maxpool → batchnorm → relu) followed by one fully connected layer.

This is the model the ONNX-to-hardware flow compiles; it exists both as this
plain-JAX definition (training + oracle) and as an IR graph
(``repro.core.reader.cnn_to_ir``) lowered by the writers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import CNNConfig
from repro.configs.separable_cnn import SeparableCNNConfig


def init_params(cfg: CNNConfig, key) -> Dict[str, jax.Array]:
    params: Dict[str, jax.Array] = {}
    cin = cfg.in_channels
    ks = jax.random.split(key, len(cfg.conv_channels) + 1)
    for i, cout in enumerate(cfg.conv_channels):
        fan = cfg.kernel_size * cfg.kernel_size * cin
        params[f"conv{i}/w"] = (jax.random.normal(ks[i], (cfg.kernel_size, cfg.kernel_size, cin, cout)) / jnp.sqrt(fan)).astype(jnp.float32)
        params[f"conv{i}/b"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/scale"] = jnp.ones((cout,), jnp.float32)
        params[f"bn{i}/bias"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/mean"] = jnp.zeros((cout,), jnp.float32)
        params[f"bn{i}/var"] = jnp.ones((cout,), jnp.float32)
        cin = cout
    params["fc/w"] = (jax.random.normal(ks[-1], (cfg.fc_in, cfg.n_classes)) / jnp.sqrt(cfg.fc_in)).astype(jnp.float32)
    params["fc/b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params


def conv2d(x, w, b):
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout) — SAME padding, stride 1."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def maxpool(x, k: int):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                                 (1, k, k, 1), "VALID")


def batchnorm(x, scale, bias, mean, var, eps: float = 1e-5):
    inv = scale * jax.lax.rsqrt(var + eps)
    return x * inv + (bias - mean * inv)


def forward(params: Dict[str, jax.Array], x, cfg: CNNConfig,
            train_stats: bool = False):
    """x: (B, H, W, C) -> logits (B, n_classes).

    train_stats: use batch statistics (training); else the stored running stats.
    """
    aux = {}
    for i in range(len(cfg.conv_channels)):
        x = conv2d(x, params[f"conv{i}/w"], params[f"conv{i}/b"])
        x = maxpool(x, cfg.pool)
        if train_stats:
            mean = jnp.mean(x, axis=(0, 1, 2))
            var = jnp.var(x, axis=(0, 1, 2))
            aux[f"bn{i}/mean"], aux[f"bn{i}/var"] = mean, var
        else:
            mean, var = params[f"bn{i}/mean"], params[f"bn{i}/var"]
        x = batchnorm(x, params[f"bn{i}/scale"], params[f"bn{i}/bias"], mean, var)
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc/w"] + params["fc/b"], aux


def depthwise_conv2d(x, w, b, stride: int = 1):
    """x: (B, H, W, C); w: (kh, kw, 1, C) HWIO — SAME padding, one filter per
    channel (``feature_group_count == C``)."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    return y + b


def init_separable_params(cfg: SeparableCNNConfig, key) -> Dict[str, jax.Array]:
    """Conv stem + (depthwise 3x3, pointwise 1x1) separable blocks + FC."""
    params: Dict[str, jax.Array] = {}
    k = cfg.kernel_size
    keys = jax.random.split(key, 2 * len(cfg.blocks) + 2)
    fan = k * k * cfg.in_channels
    params["stem/w"] = (jax.random.normal(
        keys[0], (k, k, cfg.in_channels, cfg.stem_channels))
        / jnp.sqrt(fan)).astype(jnp.float32)
    params["stem/b"] = jnp.zeros((cfg.stem_channels,), jnp.float32)
    cin = cfg.stem_channels
    for i, (cout, _) in enumerate(cfg.blocks):
        params[f"dw{i}/w"] = (jax.random.normal(keys[2 * i + 1], (k, k, 1, cin))
                              / jnp.sqrt(k * k)).astype(jnp.float32)
        params[f"dw{i}/b"] = jnp.zeros((cin,), jnp.float32)
        params[f"pw{i}/w"] = (jax.random.normal(keys[2 * i + 2], (1, 1, cin, cout))
                              / jnp.sqrt(cin)).astype(jnp.float32)
        params[f"pw{i}/b"] = jnp.zeros((cout,), jnp.float32)
        for layer in (f"dw{i}", f"pw{i}"):
            c = cin if layer.startswith("dw") else cout
            params[f"{layer}_bn/scale"] = jnp.ones((c,), jnp.float32)
            params[f"{layer}_bn/bias"] = jnp.zeros((c,), jnp.float32)
            params[f"{layer}_bn/mean"] = jnp.zeros((c,), jnp.float32)
            params[f"{layer}_bn/var"] = jnp.ones((c,), jnp.float32)
        cin = cout
    params["fc/w"] = (jax.random.normal(keys[-1], (cfg.fc_in, cfg.n_classes))
                      / jnp.sqrt(cfg.fc_in)).astype(jnp.float32)
    params["fc/b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params


def separable_forward(params: Dict[str, jax.Array], x,
                      cfg: SeparableCNNConfig):
    """x: (B, H, W, C) -> logits (B, n_classes) — inference-stats oracle for
    the separable IR graph (``repro.core.reader.separable_cnn_to_ir``)."""
    x = conv2d(x, params["stem/w"], params["stem/b"])
    x = jax.nn.relu(x)
    x = maxpool(x, cfg.pool)
    for i, (_, stride) in enumerate(cfg.blocks):
        x = depthwise_conv2d(x, params[f"dw{i}/w"], params[f"dw{i}/b"], stride)
        x = batchnorm(x, params[f"dw{i}_bn/scale"], params[f"dw{i}_bn/bias"],
                      params[f"dw{i}_bn/mean"], params[f"dw{i}_bn/var"])
        x = jax.nn.relu(x)
        x = conv2d(x, params[f"pw{i}/w"], params[f"pw{i}/b"])
        x = batchnorm(x, params[f"pw{i}_bn/scale"], params[f"pw{i}_bn/bias"],
                      params[f"pw{i}_bn/mean"], params[f"pw{i}_bn/var"])
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc/w"] + params["fc/b"]


def loss_fn(params, x, labels, cfg: CNNConfig) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, x, cfg, train_stats=True)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold), aux


def accuracy(params, x, labels, cfg: CNNConfig) -> jax.Array:
    logits, _ = forward(params, x, cfg)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
