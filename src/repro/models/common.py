"""Shared model building blocks: norms, RoPE, activations, embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * w.astype(jnp.float32)
    if b is not None:
        x = x + b.astype(jnp.float32)
    return x.astype(dt)


def norm(x, w, kind: str):
    return rmsnorm(x, w) if kind == "rmsnorm" else layernorm(x, w)


def rope_angles(positions, d_head: int, theta: float):
    """positions: (...,) int32 -> cos/sin of shape (..., d_head//2)."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, Dh); cos/sin: (S, Dh//2) or (B, S, Dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table_or_head, tied: bool):
    """x: (..., d) -> logits (..., Vp)."""
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)


def cross_entropy(logits, labels, vocab_real: int):
    """Masked CE over the *real* vocab (padded logits excluded)."""
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.arange(logits.shape[-1]) < vocab_real
    logits = jnp.where(mask, logits, neg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
