"""Attention: GQA with RoPE, sliding-window support, chunked prefill, KV-cache decode.

Prefill/train attention is computed in query chunks (``lax.scan``) so the
(B, H, S, S) score tensor is never materialized — the XLA-level analogue of a
flash schedule; per-row softmax stays exact because each chunk row sees all keys.

Perf knobs (repro.perf.FLAGS, see EXPERIMENTS.md §Perf):
  * head-sharded layout constraints (stops GSPMD from splitting the d_head
    contraction, which all-reduces full score tensors across the mesh);
  * grouped GQA (scores computed per kv-head group — the repeated kv tensor is
    never materialized, removing the G× KV read amplification);
  * banded SWA prefill (only the in-window key band is computed per q chunk).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, rope_angles
from repro.perf import FLAGS

Q_CHUNK = 1024  # query-block size for chunked attention


class LayerAttnParams(NamedTuple):
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


def _dp(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _constrain_heads(x, mesh, batch_sharded: bool = True):
    """x: (B, S, H, Dh) -> head-sharded over 'model' (uneven dims pad)."""
    dp = _dp(mesh) if batch_sharded and x.shape[0] % 2 == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, "model", None)))


def _proj_qkv(x, p: LayerAttnParams, cfg: ModelConfig, mesh=None):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p.wq)
    k = jnp.einsum("bsd,de->bse", x, p.wk)
    v = jnp.einsum("bsd,de->bse", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    # Pin head-sharded layouts only in the pathological case: q heads neither
    # divide nor fit under the model axis, where GSPMD otherwise splits the
    # d_head *contraction* and all-reduces full score tensors (measured:
    # 24 GiB/layer on granite prefill).  Divisible counts propagate fine;
    # H < tp everywhere (whisper) pads more slots than heads and regresses.
    # The per-layer decision follows the q-head count and applies to k/v too
    # (an unconstrained kv side re-introduces the bad contraction split).
    if mesh is not None and FLAGS.attn_head_constraint:
        tp = mesh.shape["model"]
        if cfg.n_heads % tp != 0 and cfg.n_heads > tp:
            q = _constrain_heads(q, mesh)
            k = _constrain_heads(k, mesh)
            v = _constrain_heads(v, mesh)
    return q, k, v


def _expand_kv(k, n_heads: int):
    """(B, S, Hkv, Dh) -> (B, S, H, Dh) by group repetition."""
    rep = n_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _mask(qpos, kpos, window: Optional[int], causal: bool):
    """qpos: (Q,), kpos: (K,) -> bool (Q, K) of *allowed* links."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _sdpa_chunk(q, k, v, qpos, kpos, window, causal, scale, grouped: bool):
    """q: (B, Qc, H, Dh); k/v: (B, S, Hkv, Dh) -> (B, Qc, H, Dh).

    grouped=True computes scores per kv group without repeating k/v."""
    B, Qc, H, Dh = q.shape
    Hkv = k.shape[2]
    m = _mask(qpos, kpos, window, causal)
    sdt = jnp.bfloat16 if (FLAGS.attn_bf16_scores
                           and q.dtype == jnp.bfloat16) else jnp.float32
    neg = jnp.finfo(sdt).min
    if grouped and Hkv != H:
        G = H // Hkv
        qg = q.reshape(B, Qc, Hkv, G, Dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                       preferred_element_type=jnp.float32
                       ).astype(sdt) * jnp.asarray(scale, sdt)
        s = jnp.where(m[None, None, None], s, neg)
        prob = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", prob, v)
        return o.reshape(B, Qc, H, Dh)
    kx = _expand_kv(k, H)
    vx = _expand_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                   preferred_element_type=jnp.float32
                   ).astype(sdt) * jnp.asarray(scale, sdt)
    s = jnp.where(m[None, None], s, neg)
    prob = jax.nn.softmax(s, axis=-1).astype(vx.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", prob, vx)


def attention(x, p: LayerAttnParams, cfg: ModelConfig, *, positions=None,
              causal: bool = True, kv_override=None, unroll: bool = False,
              mesh=None):
    """Full-sequence attention (train / prefill / encoder).

    kv_override: (k, v, kpos) for cross-attention (q from x, kv precomputed).
    Returns (out (B,S,d), k, v) — k/v returned for cache population at prefill.
    """
    B, S, _ = x.shape
    q, k, v = _proj_qkv(x, p, cfg, mesh)
    if positions is None:
        positions = jnp.arange(S)
    if kv_override is not None:
        ko, vo, kpos = kv_override
        k, v = ko, vo
    else:
        if cfg.rope_theta > 0:
            cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        kpos = positions
    k_cache, v_cache = k, v
    scale = cfg.head_dim ** -0.5
    grouped = FLAGS.gqa_grouped
    win = cfg.sliding_window

    # banded SWA: per q chunk only keys in [chunk_start - window, chunk_end)
    # can attend; slice that band instead of scoring all S keys
    banded = (FLAGS.swa_banded and win is not None and causal
              and S > Q_CHUNK and S % Q_CHUNK == 0
              and kv_override is None and win % Q_CHUNK == 0)

    if S <= Q_CHUNK or S % Q_CHUNK != 0:  # small/ragged (whisper enc): unchunked
        out = _sdpa_chunk(q, k, v, positions, kpos, win, causal, scale, grouped)
    else:
        nc = S // Q_CHUNK
        qc = q.reshape(B, nc, Q_CHUNK, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(nc, Q_CHUNK)

        if banded:
            band = win + Q_CHUNK          # keys visible to one q chunk
            # pad keys in front so every chunk slices a fixed-size band
            pad = band - Q_CHUNK
            kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
            kpos_p = jnp.pad(kpos, (pad, 0), constant_values=-10 ** 9)

            def body(_, ci):
                qi = qc[ci]
                pi = pc[ci]
                start = ci * Q_CHUNK      # band ends at chunk end
                kb = jax.lax.dynamic_slice_in_dim(kp, start, band, 1)
                vb = jax.lax.dynamic_slice_in_dim(vp, start, band, 1)
                pb = jax.lax.dynamic_slice_in_dim(kpos_p, start, band, 0)
                return None, _sdpa_chunk(qi, kb, vb, pi, pb, win, causal,
                                         scale, grouped)

            _, oc = jax.lax.scan(body, None, jnp.arange(nc),
                                 unroll=nc if unroll else 1)
        else:
            def body(_, qp):
                qi, pi = qp
                return None, _sdpa_chunk(qi, k, v, pi, kpos, win, causal,
                                         scale, grouped)

            _, oc = jax.lax.scan(body, None, (qc, pc),
                                 unroll=nc if unroll else 1)
        out = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.n_heads, cfg.head_dim)

    out = out.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bse,ed->bsd", out, p.wo), k_cache, v_cache


def cache_size(cfg: ModelConfig, seq_len: int) -> int:
    """Allocated cache length: SWA archs keep a ring buffer of window size."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def decode_attention(x, p: LayerAttnParams, cfg: ModelConfig, cache_k, cache_v,
                     index, *, kv_override=None, mesh=None):
    """Single-token decode. x: (B, 1, d); cache_k/v: (B, Smax, Hkv*Dh)
    *flattened* on the kv dim so explicit shardings divide the model axis
    (DESIGN.md §4); index: scalar i32 — tokens already in the cache.

    RoPE is applied at insertion, so SWA ring buffers need no re-rotation.
    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    q, k, v = _proj_qkv(x, p, cfg, mesh)
    scale = cfg.head_dim ** -0.5
    if kv_override is not None:
        ko, vo, _ = kv_override
        out = _sdpa_chunk(q, ko.astype(q.dtype), vo.astype(q.dtype),
                          jnp.zeros(1, jnp.int32),
                          jnp.zeros(ko.shape[1], jnp.int32), None, False,
                          scale, FLAGS.gqa_grouped)
        out = out.reshape(B, 1, cfg.q_dim)
        return jnp.einsum("bse,ed->bsd", out, p.wo), cache_k, cache_v

    if cfg.rope_theta > 0:
        cos, sin = rope_angles(index[None], cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    smax = cache_k.shape[1]
    slot = index % smax if cfg.sliding_window is not None else index
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.reshape(B, 1, cfg.kv_dim).astype(cache_k.dtype), (0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.reshape(B, 1, cfg.kv_dim).astype(cache_v.dtype), (0, slot, 0))

    kc = cache_k.reshape(B, smax, cfg.n_kv_heads, cfg.head_dim).astype(q.dtype)
    vc = cache_v.reshape(B, smax, cfg.n_kv_heads, cfg.head_dim).astype(q.dtype)
    valid = jnp.arange(smax) <= jnp.minimum(index, smax - 1)  # ring: written slots
    kpos = jnp.where(valid, 0, 10 ** 9)  # invalid slots fail the causal test
    out = _sdpa_chunk(q, kc, vc, jnp.zeros(1, jnp.int32), kpos, None, True,
                      scale, FLAGS.gqa_grouped)
    out = out.reshape(B, 1, cfg.q_dim)
    return jnp.einsum("bse,ed->bsd", out, p.wo), cache_k, cache_v
