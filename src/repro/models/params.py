"""Parameter tree definition: shapes, initialization, analytic counts.

``param_shapes(cfg, max_seq, tp_total)`` is the single source of truth; init,
counting, checkpointing and the dry-run all derive from it.

MoE expert weights are stored pre-arranged in the expert-parallel layout
``(tp_total, E/ep, d, f/tp)`` where ``ep = gcd(E, tp_total)`` and
``tp = tp_total/ep`` (DESIGN.md §4): shard dim 0 over ``model`` and each rank
holds its ep-group's experts' tp-slice.  Total element count is exactly E*d*f.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def moe_factors(n_experts: int, tp_total: int) -> Tuple[int, int]:
    ep = math.gcd(n_experts, tp_total)
    return ep, tp_total // ep


def _attn_shapes(cfg: ModelConfig, L: int, prefix: str, bias: bool) -> Dict[str, tuple]:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = {
        f"{prefix}_norm/w": (L, d),
        f"{prefix}/wq": (L, d, q),
        f"{prefix}/wk": (L, d, kv),
        f"{prefix}/wv": (L, d, kv),
        f"{prefix}/wo": (L, q, d),
    }
    if bias:
        s[f"{prefix}/bq"] = (L, q)
        s[f"{prefix}/bk"] = (L, kv)
        s[f"{prefix}/bv"] = (L, kv)
    return s


def _mlp_shapes(cfg: ModelConfig, L: int, prefix: str = "mlp") -> Dict[str, tuple]:
    d, f = cfg.d_model, cfg.d_ff
    s = {f"{prefix}_norm/w": (L, d)}
    if cfg.act == "swiglu":
        s[f"{prefix}/w_gate"] = (L, d, f)
        s[f"{prefix}/w_up"] = (L, d, f)
        s[f"{prefix}/w_down"] = (L, f, d)
    else:  # gelu MLP (whisper)
        s[f"{prefix}/w_up"] = (L, d, f)
        s[f"{prefix}/b_up"] = (L, f)
        s[f"{prefix}/w_down"] = (L, f, d)
        s[f"{prefix}/b_down"] = (L, d)
    return s


def _moe_shapes(cfg: ModelConfig, L: int, tp_total: int) -> Dict[str, tuple]:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ep, tp = moe_factors(E, tp_total)
    el, fl = E // ep, f // tp
    return {
        "mlp_norm/w": (L, d),
        "moe/router": (L, d, E),
        "moe/w_gate": (L, tp_total, el, d, fl),
        "moe/w_up": (L, tp_total, el, d, fl),
        "moe/w_down": (L, tp_total, el, fl, d),
    }


def _ssm_shapes(cfg: ModelConfig, L: int) -> Dict[str, tuple]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = cfg.d_inner
    H = cfg.n_ssm_heads
    gn = 2 * s.n_groups * s.d_state
    conv_dim = d_inner + gn                 # conv over (x, B, C)
    # separate projections (z | x | BC | dt) so each output dim shards
    # cleanly over 'model' (fused 2*d_inner+2GN+H is rarely divisible)
    return {
        "ssm_norm/w": (L, d),
        "ssm/w_z": (L, d, d_inner),
        "ssm/w_x": (L, d, d_inner),
        "ssm/w_bc": (L, d, gn),
        "ssm/w_dt": (L, d, H),
        "ssm/conv": (L, s.d_conv, conv_dim),
        "ssm/A_log": (L, H),
        "ssm/D": (L, H),
        "ssm/dt_bias": (L, H),
        "ssm/norm_w": (L, d_inner),
        "ssm/w_out": (L, d_inner, d),
    }


def param_shapes(cfg: ModelConfig, max_seq: int = 0, tp_total: int = 1) -> Dict[str, tuple]:
    """Flat {path: shape}.  Decoder stack paths are prefixed ``layers/`` and
    carry a leading L dim (scanned); encoder stack uses ``enc/``."""
    d, L = cfg.d_model, cfg.n_layers
    shapes: Dict[str, tuple] = {
        "embed/table": (cfg.vocab_padded, d),
        "final_norm/w": (d,),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head/w"] = (d, cfg.vocab_padded)

    layer: Dict[str, tuple] = {}
    if cfg.family != "ssm":
        layer.update(_attn_shapes(cfg, L, "attn", cfg.qkv_bias))
    if cfg.family in ("ssm", "hybrid"):
        layer.update(_ssm_shapes(cfg, L))
    if cfg.moe is not None:
        layer.update(_moe_shapes(cfg, L, tp_total))
    elif cfg.d_ff > 0:
        layer.update(_mlp_shapes(cfg, L))
    shapes.update({f"layers/{k}": v for k, v in layer.items()})

    if cfg.enc_layers:  # whisper encoder + cross attention + learned positions
        Le = cfg.enc_layers
        enc: Dict[str, tuple] = {}
        enc.update(_attn_shapes(cfg, Le, "attn", cfg.qkv_bias))
        enc.update(_mlp_shapes(cfg, Le))
        shapes.update({f"enc/{k}": v for k, v in enc.items()})
        shapes["enc_final_norm/w"] = (d,)
        shapes["enc_pos"] = (cfg.enc_seq, d)
        shapes["dec_pos"] = (max(max_seq, 8), d)
        shapes.update({f"layers/{k}": v for k, v in _attn_shapes(cfg, L, "cross", False).items()})
    if cfg.n_patches:
        shapes["vision_proj/w"] = (d, d)
    return shapes


_F32_SUFFIXES = ("A_log", "dt_bias")


def param_dtype(path: str, default) -> jnp.dtype:
    if any(path.endswith(s) for s in _F32_SUFFIXES):
        return jnp.float32
    return default


def init_params(cfg: ModelConfig, key, max_seq: int = 0, tp_total: int = 1) -> Dict[str, jax.Array]:
    """Scaled-normal init matching ``param_shapes`` exactly."""
    shapes = param_shapes(cfg, max_seq=max_seq, tp_total=tp_total)
    dt = jnp.dtype(cfg.dtype)
    params: Dict[str, jax.Array] = {}
    keys = jax.random.split(key, len(shapes))
    for (path, shape), k in zip(sorted(shapes.items()), keys):
        pdt = param_dtype(path, dt)
        if path.endswith("norm/w") or path.endswith("norm_w"):
            params[path] = jnp.ones(shape, pdt)
        elif path.endswith("/D"):
            params[path] = jnp.ones(shape, pdt)
        elif path.endswith("A_log"):
            params[path] = jnp.log(jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0))
        elif path.endswith("dt_bias"):
            u = jax.random.uniform(k, shape, jnp.float32, 1e-3, 0.1)
            params[path] = jnp.log(jnp.expm1(u))  # inverse softplus
        elif path.endswith(("/bq", "/bk", "/bv", "/b_up", "/b_down")):
            params[path] = jnp.zeros(shape, pdt)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            params[path] = (jax.random.normal(k, shape, jnp.float32) * std).astype(pdt)
    return params


def abstract_params(cfg: ModelConfig, max_seq: int = 0, tp_total: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = jnp.dtype(cfg.dtype)
    return {
        p: jax.ShapeDtypeStruct(s, param_dtype(p, dt))
        for p, s in param_shapes(cfg, max_seq=max_seq, tp_total=tp_total).items()
    }


def count_params_analytic(cfg: ModelConfig, active_only: bool = False, max_seq: int = 0) -> int:
    """Total (or MoE-active) parameter count; positions/embeddings included."""
    total = 0
    for path, shape in param_shapes(cfg, max_seq=max_seq, tp_total=1).items():
        n = int(np.prod(shape))
        if active_only and "/moe/w_" in path:
            m = cfg.moe
            n = n * m.top_k // m.n_experts
        total += n
    return total
