"""Mamba-2 SSD (state-space duality) block: chunked train/prefill + O(1) decode.

Chunked SSD (arXiv:2405.21060): within chunks of length Q the output is a
masked attention-like quadratic form; across chunks a (H, P, N) state is
carried by a linear recurrence (``lax.scan``).  The intra-chunk part is the
compute hot-spot and has a Pallas kernel (``repro.kernels.ssd_scan``); this
module is the pure-jnp reference used by the models and the kernel oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rmsnorm


class SSMLayerParams(NamedTuple):
    w_z: jax.Array      # (d, d_inner) — gate projection
    w_x: jax.Array      # (d, d_inner) — value projection
    w_bc: jax.Array     # (d, 2*G*N)   — B/C projection
    w_dt: jax.Array     # (d, H)       — dt projection
    conv: jax.Array     # (K, conv_dim)
    A_log: jax.Array    # (H,) f32
    D: jax.Array        # (H,)
    dt_bias: jax.Array  # (H,) f32
    norm_w: jax.Array   # (d_inner,)
    w_out: jax.Array    # (d_inner, d)


class SSMState(NamedTuple):
    ssd: jax.Array      # (B, H, P, N) f32
    conv: jax.Array     # (B, K-1, conv_dim)


def _project_in(x, p: "SSMLayerParams"):
    """Separate z/x/BC/dt projections (TP-clean layout, DESIGN.md §4)."""
    z = jnp.einsum("...d,de->...e", x, p.w_z)
    xv = jnp.einsum("...d,de->...e", x, p.w_x)
    bc = jnp.einsum("...d,de->...e", x, p.w_bc)
    dt = jnp.einsum("...d,de->...e", x, p.w_dt)
    return z, jnp.concatenate([xv, bc], axis=-1), dt  # dt: (..., H)


def _causal_conv(xbc, w, state: Optional[jax.Array] = None):
    """Depthwise causal conv via K shifted adds.  xbc: (B, S, C); w: (K, C).

    state: (B, K-1, C) previous inputs (decode);  returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)           # (B, S+K-1, C)
    y = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(xbc.dtype), new_state


def ssd_chunked(x, dt, A, Bm, C, D, chunk: int, init_state=None):
    """Chunked SSD scan (pure jnp oracle).

    x: (B, S, H, P); dt: (B, S, H) f32 (post-softplus); A: (H,) f32 (negative);
    Bm/C: (B, S, G, N); D: (H,).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    G = Bm.shape[2]
    N = Bm.shape[3]
    S0 = S
    if S % chunk != 0:
        # zero-pad to a chunk multiple: dt=0 rows neither update the state
        # (dt_j factor) nor decay it (exp(0)=1), so padding is exact
        pad = chunk - S % chunk
        def zf(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))

        x, dt, Bm, C = zf(x), zf(dt), zf(Bm), zf(C)
        S = S + pad
    nc = S // chunk
    rep = H // G

    xc = x.reshape(Bsz, nc, chunk, H, Pd)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)   # (B,nc,Q,H,N)
    Cc = jnp.repeat(C.reshape(Bsz, nc, chunk, G, N), rep, axis=3)

    dA = dtc * A[None, None, None, :]                                # (B,nc,Q,H) <= 0
    ld = jnp.cumsum(dA, axis=2)                                      # cumulative log-decay
    l_last = ld[:, :, -1:, :]                                        # (B,nc,1,H)

    # intra-chunk: att[i,j] = (C_i . B_j) * exp(l_i - l_j) * dt_j,  j <= i
    from repro.perf import FLAGS
    idt = jnp.bfloat16 if (FLAGS.ssd_bf16_intra
                           and x.dtype == jnp.bfloat16) else jnp.float32
    li = ld[:, :, :, None, :]                                        # (B,nc,Q,1,H)
    lj = ld[:, :, None, :, :]                                        # (B,nc,1,Q,H)
    decay = jnp.exp(jnp.minimum(li - lj, 0.0)).astype(idt)           # mask j>i later
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", Cc.astype(idt), Bc.astype(idt))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = cb * decay * dtc[:, :, None, :, :].astype(idt)
    att = jnp.where(causal[None, None, :, :, None], att, jnp.zeros((), idt))
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att, xc.astype(idt)
                         ).astype(jnp.float32)

    # chunk summaries: S_c = sum_j exp(l_last - l_j) dt_j B_j x_j^T   (B,nc,H,N,P)
    w_j = jnp.exp(l_last - ld) * dtc                                 # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w_j, Bc.astype(jnp.float32),
                     xc.astype(jnp.float32))

    # inter-chunk recurrence over nc (sequential scan)
    chunk_decay = jnp.exp(l_last[:, :, 0, :])                        # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, N, Pd), jnp.float32) if init_state is None
          else init_state.transpose(0, 1, 3, 2).astype(jnp.float32))  # (B,H,N,P)

    def body(s_prev, inp):
        dec, s_new = inp                                             # (B,H), (B,H,N,P)
        s = s_prev * dec[:, :, None, None] + s_new
        return s, s_prev

    s_fin, s_prefix = jax.lax.scan(
        body, s0, (chunk_decay.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    s_prefix = s_prefix.transpose(1, 0, 2, 3, 4)                     # (B,nc,H,N,P)

    # inter-chunk contribution: y_i += C_i . (exp(l_i) * state_prefix)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Cc.astype(jnp.float32) *
                         jnp.exp(ld)[..., None], s_prefix)

    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :S0].astype(x.dtype), s_fin.transpose(0, 1, 3, 2)    # state (B,H,P,N)


def ssd_decode_step(x, dt, A, Bm, C, D, state):
    """One-token SSD update.  x: (B,H,P); dt: (B,H); Bm/C: (B,G,N);
    state: (B,H,P,N) f32.  Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bx = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)             # (B,H,N)
    Cx = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                                    # (B,H)
    upd = (dt[:, :, None] * x.astype(jnp.float32))[..., None] * Bx[:, :, None, :]
    new_state = state * dA[:, :, None, None] + upd                   # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cx)
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), new_state


def _constrain_inner(t, mesh):
    """(B, S, d_inner-like) -> last dim over 'model' (divisible by design)."""
    from repro.perf import FLAGS
    if mesh is None or not FLAGS.ssd_constraint:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bspec = dp if t.shape[0] % 2 == 0 else None
    spec = P(bspec, None, "model") if t.shape[-1] % mesh.shape["model"] == 0 \
        else P(bspec, None, None)
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))


def ssm_block(x, p: SSMLayerParams, cfg: ModelConfig,
              state: Optional[SSMState] = None, use_kernel: bool = False,
              mesh=None):
    """Full-sequence SSM mixer.  x: (B, S, d) -> (y (B,S,d), final SSMState)."""
    s = cfg.ssm
    B, S, d = x.shape
    H, Pd = cfg.n_ssm_heads, s.d_head
    z, xbc, dt = _project_in(x, p)
    xbc, conv_state = _causal_conv(xbc, p.conv, None if state is None else state.conv)
    xi, BC = jnp.split(xbc, [cfg.d_inner], axis=-1)
    z = _constrain_inner(z, mesh)
    xi = _constrain_inner(xi, mesh)
    Bm, Cm = jnp.split(BC, 2, axis=-1)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.A_log)
    xh = xi.reshape(B, S, H, Pd)
    from repro.perf import FLAGS
    if mesh is not None and FLAGS.ssd_constraint:
        # pin the SSD head layout (uneven head counts pad on 'model') so GSPMD
        # never reshards or partial-sums across the chunked scan
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        bspec = dp if B % 2 == 0 else None
        xh = jax.lax.with_sharding_constraint(
            xh, NamedSharding(mesh, P(bspec, None, "model", None)))
        dt = jax.lax.with_sharding_constraint(
            dt, NamedSharding(mesh, P(bspec, None, "model")))
    if use_kernel:
        from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
        y, ssd_state = ssd_chunked_kernel(
            xh, dt, A, Bm, Cm, p.D, s.chunk,
            None if state is None else state.ssd)
    else:
        y, ssd_state = ssd_chunked(xh, dt, A, Bm, Cm, p.D, s.chunk,
                                   None if state is None else state.ssd)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p.norm_w)
    out = jnp.einsum("bse,ed->bsd", y, p.w_out)
    return out, SSMState(ssd=ssd_state, conv=conv_state)


def ssm_decode(x, p: SSMLayerParams, cfg: ModelConfig, state: SSMState):
    """One-token SSM step.  x: (B, 1, d) -> (y (B,1,d), new state)."""
    s = cfg.ssm
    B = x.shape[0]
    H, Pd = cfg.n_ssm_heads, s.d_head
    z, xbc, dt = _project_in(x[:, 0], p)
    # conv state update: append current xbc, take window
    xp = jnp.concatenate([state.conv.astype(xbc.dtype), xbc[:, None, :]], axis=1)
    y = sum(xp[:, i, :] * p.conv[i] for i in range(p.conv.shape[0]))
    xbc = jax.nn.silu(y.astype(jnp.float32)).astype(xbc.dtype)
    conv_state = xp[:, 1:, :]
    xi, BC = jnp.split(xbc, [cfg.d_inner], axis=-1)
    Bm, Cm = jnp.split(BC, 2, axis=-1)
    Bm = Bm.reshape(B, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.A_log)
    yh, ssd_state = ssd_decode_step(xi.reshape(B, H, Pd), dt, A, Bm, Cm, p.D, state.ssd)
    yh = yh.reshape(B, cfg.d_inner)
    yh = rmsnorm(yh * jax.nn.silu(z.astype(jnp.float32)).astype(yh.dtype), p.norm_w)
    out = jnp.einsum("be,ed->bd", yh, p.w_out)
    return out[:, None, :], SSMState(ssd=ssd_state, conv=conv_state)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> SSMState:
    s = cfg.ssm
    H, Pd = cfg.n_ssm_heads, s.d_head
    conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
    return SSMState(
        ssd=jnp.zeros((batch, H, Pd, s.d_state), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    )
