"""Whisper-style encoder-decoder.

The conv/mel audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, enc_seq, d_model).  Learned absolute positions
(``enc_pos`` / ``dec_pos``), pre-LayerNorm, GELU MLPs, cross-attention from
decoder to encoder output.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.attention import attention, decode_attention
from repro.models.common import embed_lookup, norm, unembed
from repro.models.transformer import _attn_params, _mlp, layer_tree


def encode(params: Dict[str, jax.Array], frames, cfg: ModelConfig,
           remat: bool = False, unroll: bool = False, mesh=None):
    """frames: (B, enc_seq, d) stub embeddings -> (B, enc_seq, d)."""
    x = frames + params["enc_pos"][None, : frames.shape[1]].astype(frames.dtype)
    lt = layer_tree(params, "enc/")
    positions = jnp.arange(frames.shape[1])

    def layer(x, lp):
        xn = norm(x, lp["attn_norm/w"], cfg.norm)
        a, _, _ = attention(xn, _attn_params(lp), cfg, positions=positions,
                            causal=False, unroll=unroll, mesh=mesh)
        x = x + a
        x = x + _mlp(norm(x, lp["mlp_norm/w"], cfg.norm), lp, cfg)
        return x, None

    if remat:
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, lt, unroll=cfg.enc_layers if unroll else 1)
    return norm(x, params["enc_final_norm/w"], cfg.norm)


def _cross_kv(enc_out, lp, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, lp["cross/wk"])
    v = jnp.einsum("bsd,de->bse", enc_out, lp["cross/wv"])
    k = k.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def forward(params: Dict[str, jax.Array], tokens, frames, cfg: ModelConfig, *,
            mesh: Optional[Mesh] = None, tp_total: int = 1, remat: bool = False,
            collect_cache: bool = False, unroll: bool = False):
    """Teacher-forced decode pass. tokens: (B, S); frames: (B, enc_seq, d)."""
    enc_out = encode(params, frames, cfg, remat=remat, unroll=unroll, mesh=mesh)
    B, S = tokens.shape
    x = embed_lookup(params["embed/table"], tokens)
    x = x + params["dec_pos"][None, :S].astype(x.dtype)
    positions = jnp.arange(S)
    enc_pos = jnp.arange(enc_out.shape[1])
    lt = layer_tree(params)

    def layer(x, lp):
        xn = norm(x, lp["attn_norm/w"], cfg.norm)
        a, k, v = attention(xn, _attn_params(lp), cfg, positions=positions,
                            unroll=unroll, mesh=mesh)
        x = x + a
        ck, cv = _cross_kv(enc_out, lp, cfg)
        xn = norm(x, lp["cross_norm/w"], cfg.norm)
        c, _, _ = attention(xn, _attn_params(lp, "cross"), cfg, positions=positions,
                            causal=False, kv_override=(ck, cv, enc_pos),
                            unroll=unroll, mesh=mesh)
        x = x + c
        x = x + _mlp(norm(x, lp["mlp_norm/w"], cfg.norm), lp, cfg)
        ys = (k, v, ck, cv) if collect_cache else None
        return x, ys

    if remat:
        layer = jax.checkpoint(layer)
    x, caches = jax.lax.scan(layer, x, lt, unroll=cfg.n_layers if unroll else 1)
    x = norm(x, params["final_norm/w"], cfg.norm)
    logits = unembed(x, params["lm_head/w"], False)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    if collect_cache:
        return logits, aux, caches
    return logits, aux


class EncDecDecodeState(NamedTuple):
    cache_k: jax.Array    # (L, B, Smax, Hkv*Dh) decoder self-attn (flat kv)
    cache_v: jax.Array
    cross_k: jax.Array    # (L, B, enc_seq, Hkv, Dh) precomputed from encoder
    cross_v: jax.Array
    index: jax.Array


def init_decode_state(params, frames, cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> EncDecDecodeState:
    """Runs the encoder and precomputes per-layer cross k/v."""
    enc_out = encode(params, frames, cfg)
    lt = layer_tree(params)

    def layer(_, lp):
        return None, _cross_kv(enc_out, lp, cfg)

    _, (ck, cv) = jax.lax.scan(layer, None, lt)
    L = cfg.n_layers
    k = jnp.zeros((L, batch, seq_len, cfg.kv_dim), dtype)
    return EncDecDecodeState(k, jnp.zeros_like(k), ck.astype(dtype), cv.astype(dtype),
                             jnp.zeros((), jnp.int32))


def abstract_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                          dtype=jnp.bfloat16) -> EncDecDecodeState:
    L = cfg.n_layers
    k = jax.ShapeDtypeStruct((L, batch, seq_len, cfg.kv_dim), dtype)
    c = jax.ShapeDtypeStruct((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
    return EncDecDecodeState(k, k, c, c, jax.ShapeDtypeStruct((), jnp.int32))


def decode_step(params: Dict[str, jax.Array], tokens, state: EncDecDecodeState,
                cfg: ModelConfig, *, mesh: Optional[Mesh] = None, tp_total: int = 1,
                unroll: bool = False):
    """tokens: (B, 1) -> (logits, new state)."""
    idx = state.index
    x = embed_lookup(params["embed/table"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], idx, 1, 0)[None].astype(x.dtype)
    lt = layer_tree(params)

    def layer(x, xs_l):
        lp, ck, cv, xk, xv = xs_l
        xn = norm(x, lp["attn_norm/w"], cfg.norm)
        a, nk, nv = decode_attention(xn, _attn_params(lp), cfg, ck, cv, idx,
                                     mesh=mesh)
        x = x + a
        xn = norm(x, lp["cross_norm/w"], cfg.norm)
        c, _, _ = decode_attention(xn, _attn_params(lp, "cross"), cfg, None, None, idx,
                                   kv_override=(xk, xv, None), mesh=mesh)
        x = x + c
        x = x + _mlp(norm(x, lp["mlp_norm/w"], cfg.norm), lp, cfg)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(layer, x, (lt, state.cache_k, state.cache_v,
                                          state.cross_k, state.cross_v),
                               unroll=cfg.n_layers if unroll else 1)
    x = norm(x, params["final_norm/w"], cfg.norm)
    logits = unembed(x, params["lm_head/w"], False)
    return logits, EncDecDecodeState(nk, nv, state.cross_k, state.cross_v, idx + 1)
