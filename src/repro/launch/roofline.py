"""Roofline-term derivation from compiled dry-run artifacts (brief §ROOFLINE).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 (394 TOP/s int8) per
chip, 819 GB/s HBM, ~50 GB/s/link ICI.

``cost_analysis()`` flops / bytes are for the *per-device* SPMD program
(verified empirically), so terms need no chip division.  Collective bytes are
parsed from the compiled HLO text: per op, wire bytes on the slowest link of a
ring schedule (2(n-1)/n for all-reduce, (n-1)/n for gather/scatter/all-to-all,
1x for collective-permute).

:func:`im2col_scratch_bytes` is the CNN-side byte term: the patch tensor an
im2col conv lowering materializes, which neither HLO ``cost_analysis`` (the
interpreter never compiles it as one program) nor the FIFO model accounts
for.  ``benchmarks/qpath_latency.py`` emits it per row so the direct
depthwise kernel's byte savings are visible in the report.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    wire_bytes: float = 0.0          # per-device, slowest-link, ring-adjusted
    raw_bytes: float = 0.0           # sum of operand/result sizes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats(counts=Counter(), bytes_by_op=Counter())
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "all-gather":
            wire = (n - 1) / n * size          # result is the gathered shape
        elif op == "reduce-scatter":
            wire = (n - 1) * size              # result is the scattered shape
        elif op == "all-to-all":
            wire = (n - 1) / n * size
        else:  # collective-permute
            wire = size
        st.counts[op] += 1
        st.bytes_by_op[op] += wire
        st.wire_bytes += wire
        st.raw_bytes += size
    return st


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops: float               # 6ND / 2ND useful-model flops (global)
    peak_flops: float = PEAK_FLOPS_BF16

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective.wire_bytes / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO flops): remat/dispatch/pad waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step time."""
        denom = self.step_s * self.chips * self.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_wire_bytes": self.collective.wire_bytes,
            "collective_counts": dict(self.collective.counts),
            "collective_bytes_by_op": dict(self.collective.bytes_by_op),
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_s": self.step_s, "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


# ---------------------------------------------------------------------------
# im2col scratch accounting (qpath conv lowering)
# ---------------------------------------------------------------------------

_IM2COL_OPS = ("Conv", "FusedConv")
_IM2COL_DW_OPS = ("DepthwiseConv", "FusedDepthwiseConv")


def im2col_scratch_bytes(graph, *, batch: int = 1,
                         act_bytes: int = 1) -> Dict[str, int]:
    """Patch-tensor bytes each conv's im2col lowering materializes.

    The im2col+qgemm path rewrites every windowed conv into a
    ``(B*OH*OW, KH*KW*Cin)`` patch matrix before the matmul — scratch
    traffic no other byte model in the repo sees (HLO ``cost_analysis``
    never compiles the interpreter as one program, and the FIFO model only
    sizes inter-actor streams).  Depthwise convs are the pathological case:
    the dense block-diagonal weight expansion keeps the patch row at
    ``KH*KW*C`` even though each output channel reads ``KH*KW`` taps, so
    bytes blow up ~``KH*KW``-fold with no reuse — the direct ``qconv_dw``
    kernel reads the padded activation in place and drops this term to
    zero.

    ``act_bytes`` is the element width of the materialized patches (1 for
    the int8-code hot path, 4 for the f32 fake-quant path).  Returns
    per-node bytes keyed by node name plus a ``"_total"`` sum; the graph's
    ``value_info`` must be populated (run ``infer_shapes`` first).
    """
    out: Dict[str, int] = {}
    total = 0
    for n in graph.topo_order():
        dw = n.op in _IM2COL_DW_OPS
        if not dw and n.op not in _IM2COL_OPS:
            continue
        w = graph.initializers[n.inputs[1]]
        ks = n.attrs.get("kernel_shape") or w.shape[:2]
        kh, kw = int(ks[0]), int(ks[1])
        oshape = graph.value_info[n.outputs[0]].shape
        oh, ow = int(oshape[1]), int(oshape[2])
        # HWIO: regular conv reduces over w[2]=Cin; depthwise has w[2]==1
        # but its dense im2col expansion still spans all C=w[3] channels
        cin = int(w.shape[3] if dw else w.shape[2])
        nbytes = batch * oh * ow * kh * kw * cin * act_bytes
        out[n.name] = nbytes
        total += nbytes
    out["_total"] = total
    return out


_MAC_DW_OPS = ("DepthwiseConv", "FusedDepthwiseConv")
_MAC_CONV_OPS = ("Conv", "FusedConv")
_MAC_GEMM_OPS = ("Gemm", "FusedGemm", "MatMul")


def graph_mac_count(graph, *, batch: int = 1) -> Dict[str, int]:
    """Multiply-accumulate count per weighted node of a flow graph.

    The DSE's compute-side roofline term: Conv is ``B*OH*OW*KH*KW*Cin*Cout``,
    depthwise ``B*OH*OW*KH*KW*C`` (each output channel reads its own
    ``KH*KW`` taps), Gemm/MatMul ``B*K*N``.  Returns per-node MACs keyed by
    node name plus a ``"_total"`` sum; ``value_info`` must be populated (run
    ``infer_shapes`` first).  FLOPs = 2 * MACs."""
    out: Dict[str, int] = {}
    total = 0
    for n in graph.topo_order():
        dw = n.op in _MAC_DW_OPS
        if dw or n.op in _MAC_CONV_OPS:
            w = graph.initializers[n.inputs[1]]
            ks = n.attrs.get("kernel_shape") or w.shape[:2]
            kh, kw = int(ks[0]), int(ks[1])
            oshape = graph.value_info[n.outputs[0]].shape
            oh, ow = int(oshape[1]), int(oshape[2])
            cout = int(w.shape[3])
            cin = 1 if dw else int(w.shape[2])
            macs = batch * oh * ow * kh * kw * cin * cout
        elif n.op in _MAC_GEMM_OPS:
            init = next((i for i in n.inputs[1:]
                         if i in graph.initializers), None)
            if init is None:
                continue
            w = graph.initializers[init]
            k, nn = int(w.shape[-2]), int(w.shape[-1])
            macs = batch * k * nn
        else:
            continue
        out[n.name] = macs
        total += macs
    out["_total"] = total
    return out


def predict_latency_s(flops: float, hbm_bytes: float, *,
                      peak_flops: float = PEAK_FLOPS_INT8,
                      hbm_bw: float = HBM_BW) -> float:
    """Roofline latency: max of the compute and memory terms (overlapped).

    The DSE's analytical latency objective — ``flops`` from
    :func:`graph_mac_count` (*2), ``hbm_bytes`` the streamed weight + scratch
    traffic of a candidate working point.  Defaults assume the int8 hot
    path's peak."""
    return max(flops / peak_flops, hbm_bytes / hbm_bw)


def model_flops_for(cfg, shape, n_params_active: int) -> float:
    """Useful model FLOPs per executed step (global)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_params_active * shape.global_batch
