"""Training launcher.

Production entry point: builds the mesh, shards the TrainState, runs the
fault-tolerant loop (checkpoint/restart, straggler watchdog, resumable data).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--smoke`` uses the reduced config + local mesh (CPU-runnable end to end);
without it the full config and the 16x16 production mesh are used (TPU pod).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data.tokens import DataConfig
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.params import init_params
from repro.optim.adamw import OptConfig
from repro.runtime import ft
from repro.runtime.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh()
    tp_total = mesh.shape["model"]

    params = init_params(cfg, jax.random.PRNGKey(args.seed), max_seq=args.seq,
                         tp_total=tp_total)
    n_params = sum(int(np.prod(p.shape)) for p in params.values())
    print(f"arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)}")

    state = init_train_state(params, grad_compress=args.grad_compress)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)

    step = make_train_step(cfg, opt_cfg, mesh=mesh, tp_total=tp_total,
                           remat=True, grad_compress=args.grad_compress,
                           microbatches=args.microbatches)
    with mesh:
        step = jax.jit(step, donate_argnums=(0,))
        result = ft.run_training(
            step, state, data_cfg, args.steps, args.ckpt_dir,
            ckpt_every=args.ckpt_every, state_shardings=None)
    first = result.metrics_log[0]["loss"] if result.metrics_log else float("nan")
    last = result.metrics_log[-1]["loss"] if result.metrics_log else float("nan")
    print(f"done: steps={result.final_step} restarts={result.restarts} "
          f"loss {first:.4f} -> {last:.4f} "
          f"stragglers_flagged={len(result.flagged_steps)}")


if __name__ == "__main__":
    main()
