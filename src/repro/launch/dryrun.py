import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief §MULTI-POD DRY-RUN).

Lowers + compiles the production step for every (arch x shape x mesh) cell
with ShapeDtypeStruct inputs (no allocation), records memory/cost analysis and
the collective schedule, and derives the roofline terms.  JSON artifacts land
in artifacts/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig, shapes_for
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineReport, model_flops_for, parse_collectives
from repro.optim.adamw import OptConfig
from repro.runtime import model_api
from repro.runtime.train import make_train_step, state_shardings

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                remat: bool = True, grad_compress: bool = False,
                extra: Optional[Dict] = None, unroll: bool = False):
    """Build + lower the production step for one cell. Returns (lowered, meta)."""
    tp_total = mesh.shape["model"]
    extra = extra or {}
    # attention layout flags are inference wins (prefill 1.5-26x); the bwd
    # pass prefers GSPMD's own layouts, so train cells keep baseline attention
    # (measured: mixtral/danube/whisper train regress with forced layouts)
    import contextlib
    import dataclasses as _dc
    from repro import perf

    @contextlib.contextmanager
    def _train_flags():
        saved = _dc.replace(perf.FLAGS)
        if shape.kind == "train":
            # banded-SWA/grouped-GQA/bf16-score layouts regress the bwd pass
            # (measured: mixtral/danube train); head constraints self-gate
            perf.FLAGS.gqa_grouped = False
            perf.FLAGS.swa_banded = False
            perf.FLAGS.attn_bf16_scores = False
        try:
            yield
        finally:
            perf.FLAGS.__dict__.update(saved.__dict__)

    with mesh, _train_flags():
        if shape.kind == "train":
            state = S.abstract_train_state(cfg, shape, tp_total, grad_compress)
            batch = S.input_specs(cfg, shape)
            step = make_train_step(cfg, OptConfig(), mesh=mesh,
                                   tp_total=tp_total, remat=remat,
                                   grad_compress=grad_compress,
                                   microbatches=extra.get("microbatches", 1),
                                   unroll=unroll)
            st_sh = state_shardings(cfg, state, mesh)
            b_sh = S.batch_sharding(batch, mesh)
            fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
            return fn.lower(state, batch)
        params = S.abstract_inference_params(cfg, shape, tp_total)
        qbits = int(extra.get("quant_bits", 0) or 0)
        if qbits:
            # the paper's technique at pod scale: weight-only quantized serving.
            # codes are int8 (W8) / int4 (W4) arrays; dequant happens in-graph
            # and fuses into the matmul reads (memory roofline term drops).
            from repro.quant.ptq import is_quantizable
            store = jnp.int4 if qbits <= 4 else jnp.int8
            qparams, q_sh = {}, {}
            base_sh = S.param_sharding_for(cfg, params, mesh)
            for k, v in params.items():
                if is_quantizable(k, jax.ShapeDtypeStruct(v.shape, v.dtype)) \
                        and not k.startswith(("embed/", "lm_head/")):
                    qparams[k] = jax.ShapeDtypeStruct(v.shape, store)
                    qparams[k + "@scale"] = jax.ShapeDtypeStruct(
                        v.shape[:-2] + (1, v.shape[-1]), jnp.float32)
                    q_sh[k] = base_sh[k]
                    q_sh[k + "@scale"] = S.param_sharding_for(
                        cfg, {k: qparams[k + "@scale"]}, mesh)[k]
                else:
                    qparams[k] = v
                    q_sh[k] = base_sh[k]

            def dequant_params(qp):
                out = {}
                for k, v in qp.items():
                    if k.endswith("@scale"):
                        continue
                    if k + "@scale" in qp:
                        out[k] = (v.astype(jnp.float32) * qp[k + "@scale"]
                                  ).astype(jnp.dtype(cfg.dtype))
                    else:
                        out[k] = v
                return out
        else:
            qparams, q_sh = params, S.param_sharding_for(cfg, params, mesh)

            def dequant_params(p):
                return p
        if shape.kind == "prefill":
            batch = S.input_specs(cfg, shape)
            b_sh = S.batch_sharding(batch, mesh)

            def prefill(p, b):
                logits, _ = model_api.forward_logits(dequant_params(p), b, cfg,
                                                     mesh=mesh,
                                                     tp_total=tp_total,
                                                     unroll=unroll)
                return logits

            fn = jax.jit(prefill, in_shardings=(q_sh, b_sh))
            return fn.lower(qparams, batch)
        # decode
        kv_dtype = extra.get("kv_dtype")
        state = S.abstract_decode_state(cfg, shape, kv_dtype=kv_dtype)
        st_sh = S.decode_state_sharding(cfg, state, mesh)
        toks = S.input_specs(cfg, shape)["tokens"]
        t_sh = S.batch_sharding({"tokens": toks}, mesh)["tokens"]

        def decode(p, t, st):
            return model_api.decode_step(dequant_params(p), t, st, cfg,
                                         mesh=mesh, tp_total=tp_total,
                                         unroll=unroll)

        fn = jax.jit(decode, in_shardings=(q_sh, t_sh, st_sh),
                     out_shardings=(None, st_sh), donate_argnums=(2,))
        return fn.lower(qparams, toks, state)


def _layer_points(cfg: ModelConfig):
    """(variant cfg, linear weight) pairs whose weighted sum of per-program
    costs equals the full model — XLA's cost_analysis counts a scan body
    ONCE, so per-layer costs are recovered by two-point extrapolation:
    f(L) = f(1) + (L-1)(f(2)-f(1)).  Whisper varies enc and dec stacks."""
    import dataclasses
    L = cfg.n_layers
    if cfg.enc_layers:
        E = cfg.enc_layers
        return [
            (dataclasses.replace(cfg, n_layers=1, enc_layers=1),
             1.0 - (E - 1) - (L - 1)),
            (dataclasses.replace(cfg, n_layers=1, enc_layers=2), float(E - 1)),
            (dataclasses.replace(cfg, n_layers=2, enc_layers=1), float(L - 1)),
        ]
    return [
        (dataclasses.replace(cfg, n_layers=1), 2.0 - L),
        (dataclasses.replace(cfg, n_layers=2), float(L - 1)),
    ]


def _analyze_extrapolated(cfg: ModelConfig, shape: ShapeConfig, mesh, **kw):
    """Extrapolated (flops, bytes, CollectiveStats) for the full depth."""
    from repro.launch.roofline import CollectiveStats
    from collections import Counter
    flops = byts = wire = raw = 0.0
    counts, by_op = Counter(), Counter()
    for sub_cfg, w in _layer_points(cfg):
        lowered = _lower_cell(sub_cfg, shape, mesh, unroll=True, **kw)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        st = parse_collectives(compiled.as_text())
        flops += w * float(ca.get("flops", 0.0))
        byts += w * float(ca.get("bytes accessed", 0.0))
        wire += w * st.wire_bytes
        raw += w * st.raw_bytes
        for k, v in st.counts.items():
            counts[k] += round(w * v)
        for k, v in st.bytes_by_op.items():
            by_op[k] += w * v
    coll = CollectiveStats(counts=dict(counts), bytes_by_op=dict(by_op),
                           wire_bytes=wire, raw_bytes=raw)
    return flops, byts, coll


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             remat: bool = True, grad_compress: bool = False,
             extra: Optional[Dict] = None, out_dir: str = ARTIFACT_DIR,
             tag: str = "", verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, remat=remat,
                          grad_compress=grad_compress, extra=extra)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": int(ma.argument_size_in_bytes),
               "output_bytes": int(ma.output_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes),
               "alias_bytes": int(ma.alias_size_in_bytes)}
    except Exception:
        mem = {}
    # depth-extrapolated roofline terms (scan bodies count once in XLA's
    # cost model; see _layer_points)
    flops, byts, coll = _analyze_extrapolated(
        cfg, shape, mesh, remat=remat, grad_compress=grad_compress, extra=extra)
    n_active = cfg.active_param_count()
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective=coll,
        model_flops=model_flops_for(cfg, shape, n_active))
    result = {**rep.to_dict(), "memory_analysis": mem,
              "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
              "kind": shape.kind, "remat": remat,
              "grad_compress": grad_compress, "extra": extra or {},
              "n_params": cfg.param_count(), "n_active": n_active,
              "status": "ok"}
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    if verbose:
        print(f"[ok] {arch} x {shape_name} x {mesh_name}{suffix}: "
              f"compile={t_compile:.0f}s bound={result['bound']} "
              f"compute={result['compute_s']:.2e}s memory={result['memory_s']:.2e}s "
              f"collective={result['collective_s']:.2e}s "
              f"useful={result['useful_flops_ratio']:.2f} mfu={result['mfu']:.3f}",
              flush=True)
        if mem:
            print(f"     mem/device: args={mem['argument_bytes']/2**30:.2f}GiB "
                  f"temps={mem['temp_bytes']/2**30:.2f}GiB", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="disable repro.perf optimizations (paper-faithful run)")
    ap.add_argument("--quant-bits", type=int, default=0,
                    help="weight-quantized serving (8/4): decode/prefill cells")
    ap.add_argument("--kv-dtype", default=None,
                    help="KV-cache dtype for decode cells (e.g. float8_e4m3fn)")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()
    if args.baseline:
        from repro import perf
        perf.set_baseline()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in shapes_for(get_config(arch)):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            suffix = f"_{args.tag}" if args.tag else ""
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}{suffix}.json")
            if not args.force and os.path.exists(path):
                print(f"[skip] {arch} x {shape} x {mesh_name}{suffix} (cached)",
                      flush=True)
                continue
            extra = {}
            if args.microbatches > 1:
                extra["microbatches"] = args.microbatches
            if args.quant_bits:
                extra["quant_bits"] = args.quant_bits
            if args.kv_dtype:
                extra["kv_dtype"] = args.kv_dtype
            try:
                run_cell(arch, shape, multi_pod=mp, remat=not args.no_remat,
                         grad_compress=args.grad_compress,
                         extra=extra or None,
                         out_dir=args.out, tag=args.tag)
            except Exception as e:
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
