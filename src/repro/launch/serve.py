"""Serving launcher: batched decode with the adaptive mixed-precision server.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --steps 32

Demonstrates the paper's runtime adaptivity at serving time: the energy
budget drains over the run and the RuntimePolicy drops the working point
(W8 -> W4 -> W2) without reloading weights.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.models.params import init_params
from repro.runtime import model_api
from repro.runtime.serve import AdaptiveLMServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key, max_seq=args.seq)

    points = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    server = AdaptiveLMServer(params, cfg, points,
                              RuntimePolicy(points, thresholds=[0.66, 0.33]))

    batch = {"tokens": jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    state = model_api.init_decode_state(params, batch, cfg, args.batch, args.seq)
    tok = batch["tokens"]
    budget = 1.0
    switches = []
    last_pt = None
    for i in range(args.steps):
        logits, state, m = server.decode(tok, state, energy_budget_frac=budget)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
        budget -= 1.0 / args.steps
        if m.point != last_pt:
            switches.append((i, m.point))
            last_pt = m.point
        if i % 8 == 0:
            print(f"step {i:3d} point={m.point} budget={budget:.2f} "
                  f"weight_bytes_read={m.weight_bytes_read:,}")
    print("working-point switches:", switches)
    print("served", args.steps, "decode steps,", args.batch, "streams")


if __name__ == "__main__":
    main()
