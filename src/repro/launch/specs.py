"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns the abstract model inputs (no device
allocation); ``cell_specs`` packages everything jit.lower needs per cell kind:

  train   -> (TrainState, batch{tokens, labels[, frames|patches]})
  prefill -> (params, batch{tokens[, frames|patches]})
  decode  -> (params, tokens(B, 1), DecodeState)
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.params import abstract_params
from repro.optim.adamw import OptState
from repro.runtime.train import TrainState
from repro.sharding import batch_axes, dp_size, param_sharding


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one cell (tokens/labels + modality stubs)."""
    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.n_patches and not shape.is_decode:
        specs["patches"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return specs


def batch_sharding(specs: Dict, mesh: Mesh) -> Dict:
    dp = batch_axes(mesh)
    n = dp_size(mesh)
    out = {}
    for k, v in specs.items():
        if v.shape and v.shape[0] % n == 0:
            out[k] = NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
        else:
            out[k] = NamedSharding(mesh, P())  # tiny batch (long_500k): replicate
    return out


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig,
                          kv_dtype: Optional[str] = None):
    B = shape.global_batch
    dt = jnp.dtype(kv_dtype) if kv_dtype else jnp.bfloat16
    if cfg.family == "audio":
        return encdec.abstract_decode_state(cfg, B, shape.seq_len, dt)
    return jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, B, shape.seq_len, dt))


def decode_state_sharding(cfg: ModelConfig, state, mesh: Mesh):
    """Flat kv dims over ``model``; batch over dp when divisible, else the
    cache *sequence* dim over the data axes (long_500k, global_batch=1)."""
    dp = batch_axes(mesh)
    ndp = dp_size(mesh)
    tp = mesh.shape["model"]

    def spec(x, seq_dim: Optional[int] = None, feat_dim: Optional[int] = None):
        if x is None:
            return None
        if len(x.shape) == 0:
            return NamedSharding(mesh, P())
        parts = [None] * len(x.shape)
        if x.shape[1] % ndp == 0:
            parts[1] = dp
        elif seq_dim is not None and x.shape[seq_dim] % ndp == 0:
            parts[seq_dim] = dp
        if feat_dim is not None and x.shape[feat_dim] % tp == 0:
            parts[feat_dim] = "model"
        return NamedSharding(mesh, P(*parts))

    if isinstance(state, encdec.EncDecDecodeState):
        return encdec.EncDecDecodeState(
            cache_k=spec(state.cache_k, seq_dim=2, feat_dim=3),
            cache_v=spec(state.cache_v, seq_dim=2, feat_dim=3),
            cross_k=spec(state.cross_k),
            cross_v=spec(state.cross_v),
            index=NamedSharding(mesh, P()))
    return transformer.DecodeState(
        cache_k=spec(state.cache_k, seq_dim=2, feat_dim=3),
        cache_v=spec(state.cache_v, seq_dim=2, feat_dim=3),
        ssm_ssd=spec(state.ssm_ssd, feat_dim=2),
        ssm_conv=spec(state.ssm_conv),
        index=NamedSharding(mesh, P()))


def abstract_train_state(cfg: ModelConfig, shape: ShapeConfig, tp_total: int,
                         grad_compress: bool = False) -> TrainState:
    params = abstract_params(cfg, max_seq=shape.seq_len, tp_total=tp_total)

    def f32_like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    err = None
    if grad_compress:
        err = {k: jax.ShapeDtypeStruct(v.shape, jnp.bfloat16)
               for k, v in params.items()}
    return TrainState(
        params=params,
        opt=OptState(mu={k: f32_like(v) for k, v in params.items()},
                     nu={k: f32_like(v) for k, v in params.items()},
                     count=jax.ShapeDtypeStruct((), jnp.int32)),
        err_fb=err)


def abstract_inference_params(cfg: ModelConfig, shape: ShapeConfig,
                              tp_total: int):
    return abstract_params(cfg, max_seq=shape.seq_len, tp_total=tp_total)


def param_sharding_for(cfg: ModelConfig, params, mesh: Mesh):
    return param_sharding(params, mesh)
