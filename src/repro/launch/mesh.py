"""Production mesh definition (brief: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2x16x16 = 512 chips ("pod", "data", "model").

``compat_make_mesh`` papers over the ``axis_types`` API gap: newer jax wants
explicit ``jax.sharding.AxisType.Auto`` axes, older jax (<=0.4.x) has neither
the kwarg nor the enum and defaults to auto behaviour anyway.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (smoke tests)."""
    n = len(jax.devices())
    return compat_make_mesh((n // model, model), ("data", "model"))
