"""Production mesh definition (brief: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2x16x16 = 512 chips ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (smoke tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
