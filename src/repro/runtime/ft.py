"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler watchdog, elastic re-mesh restore.

On a real multi-pod deployment the failure signal is a missing heartbeat /
NCCL-equivalent timeout; in this single-process harness failures are injected
(``FailureInjector``), which exercises the identical restart path: resume
params+optimizer+data cursor from the latest atomic checkpoint and continue —
the data stream is resumable-by-construction so the token sequence is
bit-identical to a never-failed run (tested in tests/test_ft.py).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.tokens import DataConfig, batch_at


class FailureInjector:
    """Deterministic fault source for soak/chaos harnesses.

    Three orthogonal modes, all usable together:

    * ``fail_at`` — the original fire-once-per-step API: raise at exactly
      these steps, each at most once (checkpoint/restart tests).
    * ``rate``/``seed`` — seeded probabilistic failures: each ``maybe_fail``
      call draws from its own ``numpy`` generator, so a given seed produces
      the same fault sequence run after run (sustained soak faults).
    * ``delay_at``/``delay_rate``/``delay_s`` — injectable latency: a
      ``maybe_delay`` call sleeps ``delay_s`` when the step is scheduled
      (fire-once, like ``fail_at``) or the seeded draw hits ``delay_rate``
      (straggler/latency-spike simulation).  The sleep function is
      injectable so tests can observe delays without waiting them out.
    """

    def __init__(self, fail_at: Optional[List[int]] = None, *,
                 rate: float = 0.0, seed: int = 0,
                 delay_at: Optional[List[int]] = None,
                 delay_rate: float = 0.0, delay_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if not 0.0 <= delay_rate <= 1.0:
            raise ValueError(f"delay_rate must be in [0, 1], got {delay_rate}")
        if delay_s < 0.0:
            raise ValueError(f"delay_s must be >= 0, got {delay_s}")
        self.fail_at = set(fail_at or [])
        self.fired = set()
        self.rate = rate
        self.delay_at = set(delay_at or [])
        self.delay_fired = set()
        self.delay_rate = delay_rate
        self.delay_s = delay_s
        self.sleep = sleep
        # independent streams so interleaving fail/delay draws cannot shift
        # each other's schedules
        self._fail_rng = np.random.default_rng(seed)
        self._delay_rng = np.random.default_rng(seed + 1)
        self.injected_failures = 0
        self.injected_delays = 0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            self.injected_failures += 1
            raise RuntimeError(f"injected node failure at step {step}")
        if self.rate and float(self._fail_rng.random()) < self.rate:
            self.injected_failures += 1
            raise RuntimeError(
                f"injected probabilistic failure at step {step}")

    def maybe_delay(self, step: int) -> bool:
        """Sleep ``delay_s`` when this step draws a delay; True if it did."""
        hit = False
        if step in self.delay_at and step not in self.delay_fired:
            self.delay_fired.add(step)
            hit = True
        if (not hit and self.delay_rate
                and float(self._delay_rng.random()) < self.delay_rate):
            hit = True
        if hit:
            self.injected_delays += 1
            self.sleep(self.delay_s)
        return hit


@dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` x the running median.

    At pod scale the mitigation hook re-shards data away from the slow host /
    triggers elastic exclusion; here the hook records the event (the decision
    logic is what's under test — the actuation is cluster-specific)."""
    factor: float = 3.0
    window: int = 20
    times: Deque[float] = field(default_factory=deque)
    flagged: List[int] = field(default_factory=list)

    def __post_init__(self):
        # only the last ``window`` samples ever feed the median: bound the
        # buffer so a long run does not grow host memory without limit
        self.times = deque(self.times, maxlen=self.window)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and dt > self.factor * med
        if slow:
            self.flagged.append(step)
        return slow


@dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics_log: List[Dict]
    flagged_steps: List[int]


def run_training(step_fn: Callable, init_state, data_cfg: DataConfig,
                 total_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                 injector: Optional[FailureInjector] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 state_shardings=None, max_restarts: int = 10) -> LoopResult:
    """Run ``total_steps`` with checkpoint/restart until completion."""
    injector = injector or FailureInjector()
    watchdog = watchdog or StragglerWatchdog()
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    restarts = 0
    log: List[Dict] = []

    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        tree, step0, _ = ckpt.restore(ckpt_dir, latest, state_shardings)
        state, step = _to_state(init_state, tree), step0
    else:
        state, step = init_state, 0
        saver.save(_to_tree(state), 0, {"data_step": 0})

    while step < total_steps:
        try:
            t0 = time.monotonic()
            injector.maybe_fail(step)
            batch = batch_at(data_cfg, step)
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            watchdog.observe(step, dt)
            log.append({"step": step,
                        "loss": float(metrics["loss"]), "dt": dt})
            step += 1
            if step % ckpt_every == 0:
                saver.save(_to_tree(state), step, {"data_step": step})
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            saver.wait()
            latest = ckpt.latest_step(ckpt_dir)
            tree, step, _ = ckpt.restore(ckpt_dir, latest, state_shardings)
            state = _to_state(init_state, tree)
    saver.wait()
    saver.save(_to_tree(state), step, {"data_step": step})
    saver.wait()
    return LoopResult(step, restarts, log, watchdog.flagged)


def _to_tree(state) -> Dict:
    """TrainState -> plain nested dict for the checkpointer."""
    d = {"params": state.params, "mu": state.opt.mu, "nu": state.opt.nu,
         "count": {"count": state.opt.count}}
    if state.err_fb is not None:
        d["err_fb"] = state.err_fb
    return d


def _to_state(proto, tree):
    from repro.optim.adamw import OptState
    from repro.runtime.train import TrainState
    import jax.numpy as jnp
    return TrainState(
        params={k: jnp.asarray(v) for k, v in tree["params"].items()},
        opt=OptState(mu={k: jnp.asarray(v) for k, v in tree["mu"].items()},
                     nu={k: jnp.asarray(v) for k, v in tree["nu"].items()},
                     count=jnp.asarray(tree["count"]["count"])),
        err_fb=(None if "err_fb" not in tree else
                {k: jnp.asarray(v) for k, v in tree["err_fb"].items()}))
