"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler watchdog, elastic re-mesh restore.

On a real multi-pod deployment the failure signal is a missing heartbeat /
NCCL-equivalent timeout; in this single-process harness failures are injected
(``FailureInjector``), which exercises the identical restart path: resume
params+optimizer+data cursor from the latest atomic checkpoint and continue —
the data stream is resumable-by-construction so the token sequence is
bit-identical to a never-failed run (tested in tests/test_ft.py).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.tokens import DataConfig, batch_at


class FailureInjector:
    """Deterministically raise at given steps (once each)."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor`` x the running median.

    At pod scale the mitigation hook re-shards data away from the slow host /
    triggers elastic exclusion; here the hook records the event (the decision
    logic is what's under test — the actuation is cluster-specific)."""
    factor: float = 3.0
    window: int = 20
    times: Deque[float] = field(default_factory=deque)
    flagged: List[int] = field(default_factory=list)

    def __post_init__(self):
        # only the last ``window`` samples ever feed the median: bound the
        # buffer so a long run does not grow host memory without limit
        self.times = deque(self.times, maxlen=self.window)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and dt > self.factor * med
        if slow:
            self.flagged.append(step)
        return slow


@dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics_log: List[Dict]
    flagged_steps: List[int]


def run_training(step_fn: Callable, init_state, data_cfg: DataConfig,
                 total_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                 injector: Optional[FailureInjector] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 state_shardings=None, max_restarts: int = 10) -> LoopResult:
    """Run ``total_steps`` with checkpoint/restart until completion."""
    injector = injector or FailureInjector()
    watchdog = watchdog or StragglerWatchdog()
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    restarts = 0
    log: List[Dict] = []

    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        tree, step0, _ = ckpt.restore(ckpt_dir, latest, state_shardings)
        state, step = _to_state(init_state, tree), step0
    else:
        state, step = init_state, 0
        saver.save(_to_tree(state), 0, {"data_step": 0})

    while step < total_steps:
        try:
            t0 = time.monotonic()
            injector.maybe_fail(step)
            batch = batch_at(data_cfg, step)
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            watchdog.observe(step, dt)
            log.append({"step": step,
                        "loss": float(metrics["loss"]), "dt": dt})
            step += 1
            if step % ckpt_every == 0:
                saver.save(_to_tree(state), step, {"data_step": step})
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            saver.wait()
            latest = ckpt.latest_step(ckpt_dir)
            tree, step, _ = ckpt.restore(ckpt_dir, latest, state_shardings)
            state = _to_state(init_state, tree)
    saver.wait()
    saver.save(_to_tree(state), step, {"data_step": step})
    saver.wait()
    return LoopResult(step, restarts, log, watchdog.flagged)


def _to_tree(state) -> Dict:
    """TrainState -> plain nested dict for the checkpointer."""
    d = {"params": state.params, "mu": state.opt.mu, "nu": state.opt.nu,
         "count": {"count": state.opt.count}}
    if state.err_fb is not None:
        d["err_fb"] = state.err_fb
    return d


def _to_state(proto, tree):
    from repro.optim.adamw import OptState
    from repro.runtime.train import TrainState
    import jax.numpy as jnp
    return TrainState(
        params={k: jnp.asarray(v) for k, v in tree["params"].items()},
        opt=OptState(mu={k: jnp.asarray(v) for k, v in tree["mu"].items()},
                     nu={k: jnp.asarray(v) for k, v in tree["nu"].items()},
                     count=jnp.asarray(tree["count"]["count"])),
        err_fb=(None if "err_fb" not in tree else
                {k: jnp.asarray(v) for k, v in tree["err_fb"].items()}))
