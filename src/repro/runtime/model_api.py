"""Family dispatch: one API over decoder-only / enc-dec / vlm models.

``batch`` dicts:
  LM:        {tokens (B,S), labels (B,S)}
  audio:     {tokens, labels, frames (B, enc_seq, d)}
  vlm:       {tokens, labels, patches (B, n_patches, d)}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.common import cross_entropy


def forward_logits(params, batch: Dict, cfg: ModelConfig, *,
                   mesh: Optional[Mesh] = None, tp_total: int = 1,
                   remat: bool = False, unroll: bool = False):
    if cfg.family == "audio":
        return encdec.forward(params, batch["tokens"], batch["frames"], cfg,
                              mesh=mesh, tp_total=tp_total, remat=remat,
                              unroll=unroll)
    return transformer.forward(params, batch["tokens"], cfg, mesh=mesh,
                               tp_total=tp_total, remat=remat,
                               patch_embeds=batch.get("patches"),
                               unroll=unroll)


def loss_fn(params, batch: Dict, cfg: ModelConfig, *,
            mesh: Optional[Mesh] = None, tp_total: int = 1,
            remat: bool = False, unroll: bool = False,
            lb_coef: float = 0.01, z_coef: float = 1e-3
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_logits(params, batch, cfg, mesh=mesh,
                                 tp_total=tp_total, remat=remat, unroll=unroll)
    labels = batch["labels"]
    ce = cross_entropy(logits, labels, cfg.vocab)
    loss = ce + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


def init_decode_state(params, batch: Dict, cfg: ModelConfig, batch_size: int,
                      seq_len: int, dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return encdec.init_decode_state(params, batch["frames"], cfg,
                                        batch_size, seq_len, dtype)
    return transformer.init_decode_state(cfg, batch_size, seq_len, dtype)


def decode_step(params, tokens, state, cfg: ModelConfig, *,
                mesh: Optional[Mesh] = None, tp_total: int = 1,
                unroll: bool = False):
    if cfg.family == "audio":
        return encdec.decode_step(params, tokens, state, cfg, mesh=mesh,
                                  tp_total=tp_total, unroll=unroll)
    return transformer.decode_step(params, tokens, state, cfg, mesh=mesh,
                                   tp_total=tp_total, unroll=unroll)
