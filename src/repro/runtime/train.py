"""Distributed train step factory.

Features (DESIGN.md §7): DP×TP (+pod) sharding, ZeRO-1 optimizer-state
sharding, remat, gradient accumulation (microbatching), optional int8
gradient compression with error feedback (AC applied to the DP collective).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.optim.adamw import OptConfig, OptState, apply_updates, init_opt_state
from repro.quant import gradcomp
from repro.runtime.model_api import loss_fn
from repro.sharding import batch_axes, opt_state_spec, param_sharding


class TrainState(NamedTuple):
    params: Dict[str, jax.Array]
    opt: OptState
    err_fb: Optional[Dict[str, jax.Array]]  # gradient-compression residuals


def init_train_state(params: Dict[str, jax.Array], grad_compress: bool = False
                     ) -> TrainState:
    err = gradcomp.init_error_state(params) if grad_compress else None
    return TrainState(params=params, opt=init_opt_state(params), err_fb=err)


def state_shardings(cfg: ModelConfig, state_shape, mesh: Mesh):
    """NamedShardings for a TrainState (params rule + ZeRO-1 moments)."""
    p_sh = param_sharding(state_shape.params, mesh)
    mu_sh = {k: NamedSharding(mesh, opt_state_spec(k, v.shape, mesh))
             for k, v in state_shape.opt.mu.items()}
    nu_sh = {k: NamedSharding(mesh, opt_state_spec(k, v.shape, mesh))
             for k, v in state_shape.opt.nu.items()}
    err_sh = None
    if state_shape.err_fb is not None:
        err_sh = {k: NamedSharding(mesh, opt_state_spec(k, v.shape, mesh))
                  for k, v in state_shape.err_fb.items()}
    return TrainState(
        params=p_sh,
        opt=OptState(mu=mu_sh, nu=nu_sh, count=NamedSharding(mesh, P())),
        err_fb=err_sh)


def batch_shardings(batch_shape: Dict, mesh: Mesh):
    dp = batch_axes(mesh)
    return {k: NamedSharding(mesh, P(dp, *([None] * (v.ndim - 1))))
            for k, v in batch_shape.items()}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    mesh: Optional[Mesh] = None, tp_total: int = 1,
                    remat: bool = True, grad_compress: bool = False,
                    microbatches: int = 1, unroll: bool = False):
    """Returns ``step(state, batch) -> (state, metrics)`` (un-jitted; the
    caller jits with shardings — see launch/dryrun.py and launch/train.py)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, mesh=mesh, tp_total=tp_total,
                              remat=remat, unroll=unroll), has_aux=True)(params)

    def step(state: TrainState, batch: Dict[str, jax.Array]
             ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if microbatches > 1:
            mb = {k: v.reshape(microbatches, v.shape[0] // microbatches,
                               *v.shape[1:]) for k, v in batch.items()}

            def acc_body(acc, mbatch):
                (loss, metrics), g = grads_of(state.params, mbatch)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda x: x / microbatches, g))
                return acc, metrics

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state.params)
            grads, metrics = jax.lax.scan(acc_body, zero_g, mb,
                                          unroll=microbatches if unroll else 1)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(state.params, batch)

        err_fb = state.err_fb
        if grad_compress:
            grads, err_fb = gradcomp.compress_tree(grads, err_fb)

        params, opt, opt_metrics = apply_updates(state.params, grads,
                                                 state.opt, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return TrainState(params, opt, err_fb), metrics

    return step


def jit_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh: Mesh,
                   state_shape: TrainState, batch_shape: Dict, *,
                   remat: bool = True, grad_compress: bool = False,
                   microbatches: int = 1, donate: bool = True):
    """jit with explicit in/out shardings for the production mesh."""
    tp_total = mesh.shape["model"]
    step = make_train_step(cfg, opt_cfg, mesh=mesh, tp_total=tp_total,
                           remat=remat, grad_compress=grad_compress,
                           microbatches=microbatches)
    st_sh = state_shardings(cfg, state_shape, mesh)
    b_sh = batch_shardings(batch_shape, mesh)
    metric_sh = None  # let xla choose (scalars)
    return jax.jit(
        step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metric_sh),
        donate_argnums=(0,) if donate else (),
    )
