"""Batch-coalescing request scheduler for the accelerator serving runtime.

One compiled streaming accelerator serves an evolving request stream (the
paper's CPS story): requests of varying leading-dim sizes arrive
asynchronously, and the scheduler packs them into batches executed through a
batch-polymorphic :class:`~repro.core.writers.jax_writer.BatchedExecutable`.

Three cooperating pieces:

* :class:`CoalescingScheduler` — a bounded FIFO request queue plus the packing
  rule: pop requests in arrival order while the running total stays within
  ``max_batch``; flush when the packed batch is as full as it can get, when
  the oldest request has waited ``max_wait`` seconds, or on an explicit
  flush.  The clock is injected so tests drive time deterministically.
* :class:`BucketPolicy` — maps a packed size to the leading-dim size actually
  executed.  Candidate sizes come from a bucket ladder (powers of two up to
  ``max_batch`` by default) so the jit cache stays small.  With a
  :class:`LatencyEWMA` attached the choice is *measured*: among candidates
  with latency observations, the lowest-EWMA bucket wins; the static
  pads-no-worse-than-ladder heuristic survives only as the cold-start
  fallback (and as the explorer — an unmeasured heuristic choice executes
  once so it gains an estimate).
* :class:`ScheduledBatch` — the unit handed to the executor: member requests
  in arrival order, the bucket to pad to, and the batch budget (the most
  constrained member, so the precision policy never over-serves a request).

The scheduler never touches arrays; splitting, padding and demux live in the
executor (:class:`repro.runtime.serve.AccelServer`).
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass
from typing import (
    Any,
    Callable,
    Collection,
    Deque,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
)


class QueueFull(RuntimeError):
    """The bounded request queue rejected a submission (backpressure)."""


# per-input (trailing shape, dtype) pairs — what must agree for requests to
# share a padded batch column
RequestSignature = Tuple[Tuple[Tuple[int, ...], str], ...]


def request_signature(inputs: Sequence[Any]) -> RequestSignature:
    return tuple((tuple(int(d) for d in x.shape[1:]), str(x.dtype)) for x in inputs)


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) — the one convention shared by
    server stats and the throughput benchmark."""
    if not samples:
        raise ValueError("percentile of no samples")
    ordered = sorted(samples)
    return ordered[min(len(ordered) - 1, int(round(q * (len(ordered) - 1))))]


@dataclass
class Request:
    """One inference request: a tuple of arrays sharing the leading dim.

    A submission larger than ``max_batch`` is *split*: the queue holds its
    chunk requests and the caller gets back a parent whose ``children`` lists
    the chunk rids in order — the executor demuxes them back to one ticket."""

    rid: int
    inputs: Tuple[Any, ...]
    size: int
    arrival: float
    budget: float = 1.0
    children: Optional[List[int]] = None


@dataclass
class ScheduledBatch:
    """A packed group of requests plus the bucket they execute at."""

    requests: List[Request]
    bucket: int

    @property
    def size(self) -> int:
        """Total useful rows (sum of member request sizes)."""
        return sum(r.size for r in self.requests)

    @property
    def padding(self) -> int:
        """Zero rows appended to reach the bucket (wasted work)."""
        return self.bucket - self.size

    @property
    def budget(self) -> float:
        """Batch energy budget: the most constrained member's budget."""
        return min(r.budget for r in self.requests)


class LatencyEWMA:
    """Per-bucket execution-latency EWMA — the measurement side of the
    closed bucket-selection loop.

    The executor observes how long each bucket actually takes on the device
    (:class:`~repro.runtime.serve.BatchReport.exec_s`); the policy consults
    the estimates when choosing the next bucket.  An exponentially weighted
    moving average keeps the estimate fresh under drift (retraces, cache
    evictions, thermal/clock changes) without storing a window per bucket.
    """

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._est: dict = {}
        self._count: dict = {}

    def observe(self, bucket: int, seconds: float) -> None:
        prev = self._est.get(bucket)
        self._est[bucket] = (
            seconds if prev is None else (1 - self.alpha) * prev + self.alpha * seconds
        )
        self._count[bucket] = self._count.get(bucket, 0) + 1

    def estimate(self, bucket: int) -> Optional[float]:
        """EWMA execution seconds for ``bucket``, or None if never measured."""
        return self._est.get(bucket)

    def snapshot(self) -> dict:
        """{bucket: ewma_seconds} for telemetry."""
        return dict(self._est)


def _pow2_ladder(max_batch: int) -> Tuple[int, ...]:
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class BucketPolicy:
    """Choose the executed leading-dim size for a packed request group.

    ``buckets`` is the ladder of sizes worth owning a trace for (default:
    powers of two capped at ``max_batch``).  When ``latency`` (a
    :class:`LatencyEWMA` fed by the executor) holds measurements, the choice
    is closed-loop: among every fitting candidate (ladder plus LRU-resident
    sizes) with an estimate, the lowest measured execution latency wins.
    The static rule — smallest fitting ladder bucket, preferring an
    LRU-resident size that pads no worse (a cache hit costs a few padded
    rows; a miss costs a fresh trace and may evict a hot one) — is demoted
    to the cold-start fallback: it picks the bucket only while that bucket
    has no measurement yet, which is exactly what routes one execution
    through it and gives the loop its estimate.

    ``packing`` selects how many queued requests a batch takes: ``"fifo"``
    (default) packs the maximal arrival-order prefix fitting ``max_batch``;
    ``"best_fit"`` picks the arrival-order *prefix* whose padded waste is
    minimal (ties favor the longer prefix).  Both are prefixes of the queue,
    so neither reorders requests or starves the head — best-fit only trades
    batch fullness for padding efficiency.
    """

    PACKINGS = ("fifo", "best_fit")

    def __init__(
        self,
        buckets: Optional[Sequence[int]] = None,
        max_batch: int = 8,
        packing: str = "fifo",
        latency: Optional[LatencyEWMA] = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if packing not in self.PACKINGS:
            raise ValueError(f"packing must be one of {self.PACKINGS}, got {packing!r}")
        self.max_batch = max_batch
        self.packing = packing
        self.latency = latency
        ladder = tuple(sorted(set(buckets))) if buckets else _pow2_ladder(max_batch)
        if any(b < 1 for b in ladder):
            raise ValueError(f"buckets must be positive, got {ladder}")
        if ladder[-1] > max_batch:
            # packed totals never exceed max_batch, so a larger bucket would
            # only ever add silent padding waste
            raise ValueError(f"buckets {ladder} exceed max_batch {max_batch}")
        if ladder[-1] < max_batch:
            ladder = ladder + (max_batch,)
        self.buckets = ladder

    def ladder_bucket(self, size: int) -> int:
        """Smallest configured bucket that fits ``size``."""
        for b in self.buckets:
            if b >= size:
                return b
        return size  # size exceeds the ladder: execute at exact size

    def fallback_bucket(self, size: int, cached: Collection[int] = ()) -> int:
        """The static heuristic: smallest fitting ladder bucket, preferring
        an already-traced size in ``cached`` that pads no worse."""
        ladder = self.ladder_bucket(size)
        fits = [c for c in cached if size <= c <= ladder]
        return min(fits) if fits else ladder

    def bucket_for(self, size: int, cached: Collection[int] = ()) -> int:
        """Executed size for a packed total of ``size`` rows.

        Measured mode (``latency`` attached and warm): the fitting candidate
        with the lowest latency EWMA, ties to the smaller bucket.  Cold
        start — no latency model, or the heuristic's own choice is still
        unmeasured — falls back to :meth:`fallback_bucket`; executing that
        choice is what produces its first measurement, so every bucket the
        heuristic would ever pick gets measured before being argued with.
        """
        fallback = self.fallback_bucket(size, cached)
        lat = self.latency
        if lat is None or lat.estimate(fallback) is None:
            return fallback
        measured = [
            (est, b)
            for b in {*self.buckets, *cached}
            if b >= size and (est := lat.estimate(b)) is not None
        ]
        return min(measured)[1]

    def best_fit_take(
        self, sizes: Sequence[int], cached: Collection[int] = ()
    ) -> Tuple[int, int]:
        """(#requests, total rows) of the arrival-order prefix with minimal
        padded waste under the bucket rule; ties prefer the longer prefix
        (more requests served per dispatch at equal waste)."""
        best_take, best_total, best_waste = 0, 0, None
        total = 0
        for take, size in enumerate(sizes, start=1):
            if total + size > self.max_batch:
                break
            total += size
            waste = self.bucket_for(total, cached) - total
            if best_waste is None or waste <= best_waste:
                best_take, best_total, best_waste = take, total, waste
        return best_take, best_total


class CoalescingScheduler:
    """Bounded FIFO queue + continuous-batching packing rule.

    Requests are packed strictly in arrival order (no reordering, so no
    starvation): a batch closes when adding the next request would overflow
    ``max_batch``, when it reaches ``max_batch`` exactly, when the oldest
    member has waited ``max_wait`` seconds, or on an explicit flush.  A
    submission *larger* than ``max_batch`` is split into back-to-back chunk
    requests and returned as a parent carrying their rids (``children``) —
    the executor concatenates the chunk outputs back into one result.  The
    clock is injected (``clock=FakeClock()`` in tests) and only ever read —
    the scheduler never sleeps; the serving loop decides when to poll.
    """

    def __init__(
        self,
        max_batch: int = 8,
        max_wait: float = 0.005,
        queue_depth: int = 1024,
        buckets: Optional[Sequence[int]] = None,
        clock: Callable[[], float] = time.monotonic,
        signature: Optional[RequestSignature] = None,
        packing: str = "fifo",
        latency: Optional[LatencyEWMA] = None,
    ):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.policy = BucketPolicy(buckets, max_batch, packing=packing, latency=latency)
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.queue_depth = queue_depth
        self.clock = clock
        self._queue: Deque[Request] = deque()
        self._rids = itertools.count()
        # the signature every request must match to coalesce: taken from the
        # served artifact when provided (FlowResult.serve passes the graph's
        # input spec), else locked in by the first submission — the artifact
        # form is safer, since a malformed first request cannot poison the
        # lock for everyone after it
        self._sig = signature
        self._sig_source = "served artifact's" if signature else None
        # telemetry
        self.submitted = 0
        self.split_requests = 0
        self.split_chunks = 0
        self.scheduled = 0
        self.scheduled_rows = 0
        self.padded_rows = 0

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending_rows(self) -> int:
        return sum(r.size for r in self._queue)

    def submit(self, inputs: Sequence[Any], budget: float = 1.0) -> Request:
        """Enqueue one request (a tuple of arrays sharing the leading dim)."""
        inputs = tuple(inputs)
        if not inputs:
            raise ValueError("request has no inputs")
        sizes = {int(x.shape[0]) for x in inputs}
        if len(sizes) != 1:
            raise ValueError(f"request inputs disagree on leading dim: {sizes}")
        size = sizes.pop()
        if size < 1:
            raise ValueError("request leading dim must be >= 1")
        sig = request_signature(inputs)
        if self._sig is None:
            self._sig = sig
            self._sig_source = "first submitted request's"
        elif sig != self._sig:
            # arity / trailing-shape / dtype mismatches cannot share a padded
            # column; rejecting here keeps a bad request from poisoning the
            # batch it would have coalesced into
            raise ValueError(
                f"request signature {sig} does not match the "
                f"{self._sig_source} {self._sig}"
            )
        n_chunks = -(-size // self.max_batch)
        if len(self._queue) + n_chunks > self.queue_depth:
            raise QueueFull(
                f"queue_depth {self.queue_depth} reached; retry after a pump"
            )
        if size <= self.max_batch:
            req = Request(next(self._rids), inputs, size, self.clock(), budget)
            self._queue.append(req)
            self.submitted += 1
            return req
        # oversize request: split into max_batch-sized chunk requests (queued
        # back to back, so FIFO packing keeps them contiguous) and hand back
        # a parent the executor demuxes to one ticket
        arrival = self.clock()
        parent = Request(next(self._rids), inputs, size, arrival, budget, children=[])
        for off in range(0, size, self.max_batch):
            chunk = tuple(x[off : off + self.max_batch] for x in inputs)
            child = Request(
                next(self._rids), chunk, int(chunk[0].shape[0]), arrival, budget
            )
            self._queue.append(child)
            parent.children.append(child.rid)
        self.submitted += 1
        self.split_requests += 1
        self.split_chunks += n_chunks
        return parent

    def _packable(self) -> Tuple[int, int]:
        """(#requests, total rows) the head of the queue packs into."""
        total = take = 0
        for r in self._queue:
            if total + r.size > self.max_batch:
                break
            total += r.size
            take += 1
        return take, total

    def ready(
        self, cached: Collection[int] = (), flush: bool = False
    ) -> Optional[ScheduledBatch]:
        """Pop the next executable batch, or None to keep waiting.

        ``cached`` is the executable's set of already-traced leading-dim
        sizes (see ``BatchedExecutable.cached_batches``), consulted by the
        bucket policy.
        """
        if not self._queue:
            return None
        take, total = self._packable()
        full = total == self.max_batch or take < len(self._queue)
        waited = self.clock() - self._queue[0].arrival
        if not (full or flush or waited >= self.max_wait):
            return None
        if self.policy.packing == "best_fit" and take > 1:
            # a batch is due (by the maximal prefix); best-fit may dispatch a
            # shorter prefix whose bucket pads less — the rest stays queued
            take, total = self.policy.best_fit_take(
                [r.size for r in self._queue], cached
            )
        reqs = [self._queue.popleft() for _ in range(take)]
        batch = ScheduledBatch(reqs, self.policy.bucket_for(total, cached))
        self.scheduled += 1
        self.scheduled_rows += batch.size
        self.padded_rows += batch.padding
        return batch

    def drain(
        self, cached: Collection[int] = (), flush: bool = True
    ) -> Iterator[ScheduledBatch]:
        """Yield batches while the queue has something ready."""
        while True:
            batch = self.ready(cached, flush=flush)
            if batch is None:
                return
            yield batch

    def abandon(self) -> List[Request]:
        """Empty the queue without executing, returning the popped requests
        so the caller (server shutdown / pump death) can resolve their
        tickets with an error instead of leaving them queued forever."""
        popped = list(self._queue)
        self._queue.clear()
        return popped

    def stats(self) -> dict:
        rows = self.scheduled_rows + self.padded_rows
        return {
            "submitted": self.submitted,
            "split_requests": self.split_requests,
            "split_chunks": self.split_chunks,
            "scheduled_batches": self.scheduled,
            "scheduled_rows": self.scheduled_rows,
            "padded_rows": self.padded_rows,
            "padding_waste": self.padded_rows / rows if rows else 0.0,
            "pending": len(self._queue),
        }
