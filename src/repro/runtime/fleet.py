"""Fault-tolerant replicated serving: a front-end router over N AccelServers.

The paper's point is *long-term adaptivity* at the edge — and an adaptive
accelerator that falls over on its first fault is not adaptive.
:class:`FleetRouter` fronts N :class:`~repro.runtime.serve.AccelServer`
replicas (each with its own pump thread, all serving point executables over
the SAME shared :class:`~repro.quant.pack.PackedWeights` buffer) and makes
the ensemble survive replica death, hangs and latency spikes without losing
a single ticket:

* **health layer** — per-replica heartbeat probes plus EWMA latency/error
  scoring drive a :class:`HealthState` machine (healthy -> suspect ->
  ejected -> probing -> readmitted), with
  :class:`~repro.runtime.ft.StragglerWatchdog` flagging latency spikes;
* **failure handling** — per-request deadline budgets, bounded retries with
  exponential backoff + jitter routed to a *different* replica, optional
  tail-latency hedging (duplicate the straggling request, first result
  wins, the loser is ``drop()``-ed), and a per-replica
  :class:`CircuitBreaker` that sheds load instead of queueing onto a dead
  pump;
* **graceful degradation** — a fleet-level
  :class:`~repro.core.adaptive.BrownoutSelector` (one shared
  :class:`~repro.core.adaptive.PointSelector`) walks every replica down the
  W8 -> W4 -> W2 ladder together when aggregate p95 or backlog crosses the
  :class:`~repro.core.adaptive.ServiceObjective`, and restores precision on
  recovery;
* **chaos layer** — :class:`ChaosExecutable` wraps any point executable to
  deterministically inject delays, exceptions and pump-killing crashes
  (seeded and schedule-driven via the generalized
  :class:`~repro.runtime.ft.FailureInjector`), used by the tests and by
  ``benchmarks/fleet_chaos.py``.

Every submitted request resolves — to its output, or to a *typed* failure
(:class:`RequestFailed`, :class:`DeadlineExceeded`,
:class:`NoReplicaAvailable`) — never to a silent hang.
"""
from __future__ import annotations

import enum
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

from repro.core.adaptive import BrownoutSelector
from repro.runtime.ft import FailureInjector, StragglerWatchdog
from repro.runtime.integrity import CanarySet, IntegrityError
from repro.runtime.scheduler import QueueFull
from repro.runtime.serve import AccelServer, Ticket

__all__ = [
    "ChaosExecutable", "CircuitBreaker", "DeadlineExceeded", "FleetRouter",
    "FleetTicket", "HealthState", "NoReplicaAvailable", "Replica",
    "ReplicaCrash", "RequestFailed",
]


# ---------------------------------------------------------------------------
# typed outcomes — a fleet ticket resolves to a value or to ONE of these
# ---------------------------------------------------------------------------

class FleetError(RuntimeError):
    """Base class of every typed fleet-level failure."""


class NoReplicaAvailable(FleetError):
    """No routable replica (all ejected, breaker-open, or queue-full):
    the router sheds the request instead of queueing onto a dead pump."""


class DeadlineExceeded(FleetError):
    """The request's deadline budget ran out across all attempts."""


class RequestFailed(FleetError):
    """Every attempt failed and the retry budget is exhausted; the last
    replica error is chained as ``__cause__``."""


class ReplicaCrash(BaseException):
    """Chaos: raised from inside an executable to KILL the replica's pump.

    Deliberately a ``BaseException`` so it escapes the pump's per-batch
    ``except Exception`` containment and triggers the fatal pump-death path
    (every outstanding ticket on that replica resolves with the error) —
    exactly what a segfaulting device runtime would do to a real host.
    """


# ---------------------------------------------------------------------------
# chaos layer
# ---------------------------------------------------------------------------

class ChaosExecutable:
    """Wrap any (point) executable with a deterministic fault schedule.

    Faults come from a generalized :class:`~repro.runtime.ft.FailureInjector`
    (fire-once ``fail_at`` steps, seeded ``rate`` failures, ``delay_at`` /
    ``delay_rate`` latency injection) plus ``crash_at``: call indices that
    raise :class:`ReplicaCrash` and kill the whole pump thread.  The call
    counter is shared across every wrapper holding the same ``counter``
    list, so one schedule can span a replica's W8/W4/W2 point executables.

    Telemetry attributes of the wrapped executable (``bits``, ``packed``,
    ``cached_batches``, ``telemetry`` ...) pass through untouched.
    """

    def __init__(self, inner: Callable, injector: Optional[FailureInjector]
                 = None, *, crash_at: Sequence[int] = (),
                 counter: Optional[List[int]] = None):
        self.inner = inner
        self.injector = injector or FailureInjector()
        self.crash_at = set(crash_at)
        self.crashed: Set[int] = set()
        self.counter = counter if counter is not None else [0]
        self._lock = threading.Lock()

    def __call__(self, *args):
        with self._lock:
            step = self.counter[0]
            self.counter[0] += 1
            crash = step in self.crash_at and step not in self.crashed
            if crash:
                self.crashed.add(step)
        self.injector.maybe_delay(step)
        if crash:
            raise ReplicaCrash(f"injected pump crash at call {step}")
        self.injector.maybe_fail(step)
        return self.inner(*args)

    @property
    def calls(self) -> int:
        return self.counter[0]

    def __getattr__(self, item):
        # only reached for attributes not set on the wrapper: delegate the
        # executable telemetry surface (bits, packed, cached_batches, ...)
        return getattr(self.inner, item)


# ---------------------------------------------------------------------------
# health layer
# ---------------------------------------------------------------------------

class HealthState(enum.Enum):
    HEALTHY = "healthy"    # full traffic
    SUSPECT = "suspect"    # routable but deprioritized; probed by sentinel
    EJECTED = "ejected"    # no traffic; healed + probed after cooldown
    PROBING = "probing"    # rebuilt/suspect replica awaiting probe verdict


@dataclass
class CircuitBreaker:
    """Per-replica breaker: ``threshold`` consecutive failures open it; an
    open breaker sheds routing for ``cooldown_s``, then half-opens to let a
    trickle through — one success closes it, one failure re-opens it."""
    threshold: int = 3
    cooldown_s: float = 0.25
    clock: Callable[[], float] = time.monotonic
    failures: int = 0
    opened_at: Optional[float] = None
    half_open: bool = False
    trips: int = 0

    def record_success(self) -> None:
        self.failures = 0
        self.opened_at = None
        self.half_open = False

    def record_failure(self) -> None:
        self.failures += 1
        if self.opened_at is None:
            if self.failures >= self.threshold:
                self.opened_at = self.clock()
                self.trips += 1
        elif self.half_open:
            self.opened_at = self.clock()   # probe failed: re-open
            self.half_open = False

    @property
    def open(self) -> bool:
        return self.opened_at is not None and not self.half_open and \
            self.clock() - self.opened_at < self.cooldown_s

    def allows(self) -> bool:
        if self.opened_at is None:
            return True
        if self.clock() - self.opened_at >= self.cooldown_s:
            self.half_open = True   # cooldown over: let probes through
            return True
        return False


EWMA_ALPHA = 0.25        # latency / error-rate smoothing
ERR_SUSPECT = 0.5        # error EWMA above this marks a replica suspect


class Replica:
    """One AccelServer replica plus its health bookkeeping.

    Mutable health state is guarded by the router lock; the server itself
    has its own locking."""

    def __init__(self, name: str, factory: Callable[[], AccelServer], *,
                 breaker: Optional[CircuitBreaker] = None,
                 straggler_factor: float = 3.0):
        self.name = name
        self.factory = factory
        self.server: Optional[AccelServer] = None
        self.state = HealthState.HEALTHY
        self.breaker = breaker or CircuitBreaker()
        self.watchdog = StragglerWatchdog(factor=straggler_factor)
        self.lat_ewma: Optional[float] = None
        self.err_ewma = 0.0
        self.outstanding = 0
        self.steps = 0
        self.served = 0
        self.failures = 0
        self.ejections = 0
        self.readmissions = 0
        self.generation = 0      # how many times the server was (re)built
        self.ejected_at: Optional[float] = None
        self.eject_cause: Optional[str] = None   # why the LAST ejection fired

    # -- scoring (caller holds the router lock) ------------------------------
    def record_success(self, latency_s: float) -> bool:
        """Feed one successful request; returns True when the watchdog
        flagged it as a straggler sample."""
        self.served += 1
        self.lat_ewma = (latency_s if self.lat_ewma is None else
                         (1 - EWMA_ALPHA) * self.lat_ewma
                         + EWMA_ALPHA * latency_s)
        self.err_ewma *= (1 - EWMA_ALPHA)
        self.breaker.record_success()
        self.steps += 1
        return self.watchdog.observe(self.steps, latency_s)

    def record_failure(self) -> None:
        self.failures += 1
        self.err_ewma = (1 - EWMA_ALPHA) * self.err_ewma + EWMA_ALPHA
        self.breaker.record_failure()

    def routable(self) -> bool:
        return (self.state in (HealthState.HEALTHY, HealthState.SUSPECT)
                and self.server is not None and self.server.alive
                and self.breaker.allows())

    def snapshot(self) -> Dict[str, Any]:
        srv = self.server
        return {
            "state": self.state.value,
            "lat_ewma_s": self.lat_ewma,
            "err_ewma": round(self.err_ewma, 4),
            "outstanding": self.outstanding,
            "served": self.served,
            "failures": self.failures,
            "ejections": self.ejections,
            "readmissions": self.readmissions,
            "generation": self.generation,
            "eject_cause": self.eject_cause,
            "breaker": {"open": self.breaker.open,
                        "trips": self.breaker.trips},
            "straggler_flags": len(self.watchdog.flagged),
            "alive": bool(srv is not None and srv.alive),
            "queue_depth": (srv.queue_depth()
                            if srv is not None and srv.fatal is None else 0),
        }


# ---------------------------------------------------------------------------
# request lifecycle
# ---------------------------------------------------------------------------

@dataclass
class _Attempt:
    """One in-flight submission.  ``server`` is the exact AccelServer
    instance the ticket was submitted to: a replica may be healed (rebuilt)
    while the attempt is outstanding, and the fresh server restarts its rid
    counter — settling against ``replica.server`` could then claim or drop
    an UNRELATED request's result on the new generation."""
    replica: Replica
    server: AccelServer
    ticket: Ticket
    t0: float
    hedge: bool = False


class FleetTicket:
    """Future-style handle for one fleet request.

    ``result()`` drives failover in the calling thread: it waits on the
    current attempt, retries failures on a different replica (bounded, with
    backoff), hedges stragglers, and ALWAYS terminates by the request
    deadline — returning the output or raising a typed fleet error."""

    __slots__ = ("rid", "inputs", "budget", "tenant", "deadline", "_router",
                 "live", "attempts", "hedges", "retries_left", "_terminal",
                 "_claimed", "_resolving", "_result_value")

    def __init__(self, router: "FleetRouter", rid: int, inputs: tuple,
                 budget: float, tenant: str, deadline: float):
        self.rid = rid
        self.inputs = inputs
        self.budget = budget
        self.tenant = tenant
        self.deadline = deadline
        self._router = router
        self.live: List[_Attempt] = []
        self.attempts = 0
        self.hedges = 0
        self.retries_left = router.retries
        self._terminal: Optional[Exception] = None
        self._claimed = False
        self._resolving = False

    def done(self) -> bool:
        return (self._terminal is not None or self._claimed
                or any(a.ticket.done() for a in self.live))

    def result(self, timeout: Optional[float] = None):
        return self._router.result(self, timeout=timeout)

    def __repr__(self) -> str:
        state = ("failed" if self._terminal is not None else
                 "claimed" if self._claimed else
                 f"pending({len(self.live)} attempts)")
        return f"FleetTicket(rid={self.rid}, {state})"


class FleetRouter:
    """Health-checked, failover-routing front end over N AccelServer replicas.

    ``replicas`` maps replica names to zero-argument factories building a
    ready-to-start :class:`~repro.runtime.serve.AccelServer` (each replica's
    point executables should read the ONE shared
    :class:`~repro.quant.pack.PackedWeights` buffer — replication multiplies
    pumps, not weight memory).  The factory is re-invoked to *heal* a
    replica whose pump died, so it must be safe to call repeatedly.

    A sentinel thread heartbeats the fleet every ``probe_interval_s``:
    suspect replicas are probed (``probe`` inputs, served end-to-end) and
    readmitted on success; ejected replicas are healed (rebuilt when their
    pump died) after ``heal_cooldown_s`` and probed back in; the aggregate
    queue depth feeds the shared ``brownout`` selector, which every
    replica's tenant consults — the whole fleet walks the precision ladder
    together.
    """

    def __init__(self, replicas: Dict[str, Callable[[], AccelServer]], *,
                 brownout: Optional[BrownoutSelector] = None,
                 retries: int = 2,
                 backoff_s: float = 0.01,
                 backoff_jitter: float = 0.5,
                 hedge_after_s: Optional[float] = None,
                 default_deadline_s: float = 30.0,
                 probe: Optional[Sequence[Any]] = None,
                 canaries: Optional[CanarySet] = None,
                 probe_interval_s: float = 0.05,
                 probe_timeout_s: float = 2.0,
                 heal_cooldown_s: float = 0.25,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 0.25,
                 straggler_factor: float = 3.0,
                 seed: int = 0):
        if not replicas:
            raise ValueError("FleetRouter needs at least one replica")
        if retries < 0:
            raise ValueError("retries must be >= 0")
        if default_deadline_s <= 0:
            raise ValueError("default_deadline_s must be > 0")
        self.replicas: Dict[str, Replica] = {
            name: Replica(name, factory,
                          breaker=CircuitBreaker(threshold=breaker_threshold,
                                                 cooldown_s=breaker_cooldown_s),
                          straggler_factor=straggler_factor)
            for name, factory in replicas.items()}
        self.brownout = brownout
        self.retries = retries
        self.backoff_s = backoff_s
        self.backoff_jitter = backoff_jitter
        self.hedge_after_s = hedge_after_s
        self.default_deadline_s = default_deadline_s
        self.probe_inputs = tuple(probe) if probe is not None else None
        # semantic canaries: probes with known-good expected outputs (any
        # working point's fingerprint within tolerance passes) — corruption
        # the checksums can't see becomes eject-worthy
        self.canaries = canaries
        if canaries is not None and self.probe_inputs is None:
            self.probe_inputs = canaries.inputs(0)
        self.probe_interval_s = probe_interval_s
        self.probe_timeout_s = probe_timeout_s
        self.heal_cooldown_s = heal_cooldown_s
        self._rng = random.Random(seed)
        self._lock = threading.RLock()
        self._rids = 0
        self._running = False
        self._sentinel: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._rr = 0                       # round-robin tiebreak cursor
        # fleet counters
        self.submitted = 0
        self.succeeded = 0
        self.failed = 0
        self.retried = 0
        self.hedged = 0
        self.hedge_wins = 0
        self.shed = 0
        self.deadlines_exceeded = 0
        self.probes = 0
        self.canary_failures = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FleetRouter":
        with self._lock:
            if self._running:
                raise RuntimeError("fleet router already running")
            for rep in self.replicas.values():
                if rep.server is None or not rep.server.alive:
                    self._build_server(rep)
            self._running = True
            self._stop_evt.clear()
            self._sentinel = threading.Thread(
                target=self._sentinel_loop, name="fleet-sentinel", daemon=True)
            self._sentinel.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = 10.0) -> None:
        with self._lock:
            if not self._running:
                return
            self._running = False
            self._stop_evt.set()
            sentinel = self._sentinel
            self._sentinel = None
        if sentinel is not None:
            sentinel.join(timeout)
        for rep in self.replicas.values():
            srv = rep.server
            if srv is None:
                continue
            try:
                srv.stop(drain=drain, timeout=timeout)
            except RuntimeError:
                # a wedged or already-dead pump: its tickets were resolved
                # with typed errors by AccelServer.stop / _die
                pass

    def __enter__(self) -> "FleetRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    def _build_server(self, rep: Replica) -> None:
        """(Re)build and start a replica's server (caller holds the lock)."""
        srv = rep.factory()
        if self.brownout is not None:
            for tenant in srv.tenants:
                srv.set_selector(self.brownout, tenant=tenant)
        srv.start()
        rep.server = srv
        rep.generation += 1

    # -- routing -------------------------------------------------------------
    def _route(self, exclude: Set[str] = frozenset()) -> Optional[Replica]:
        """Pick the routing target (caller holds the lock): healthy before
        suspect, then least outstanding, then lowest latency EWMA, with a
        rotating tiebreak so equal replicas share load."""
        names = list(self.replicas)
        candidates = []
        for i, name in enumerate(names):
            rep = self.replicas[name]
            if name in exclude or not rep.routable():
                continue
            rank = (rep.state != HealthState.HEALTHY, rep.outstanding,
                    rep.lat_ewma or 0.0, (i - self._rr) % len(names))
            candidates.append((rank, rep))
        if not candidates:
            return None
        rep = min(candidates, key=lambda c: c[0])[1]
        self._rr = (self._rr + 1) % len(names)
        return rep

    def _dispatch(self, ft: FleetTicket, exclude: Set[str] = frozenset(),
                  hedge: bool = False) -> _Attempt:
        """Route + submit one attempt; raises NoReplicaAvailable when every
        routable replica rejected it (shed, not queued).

        ``exclude`` is a soft preference (avoid the replica that just
        failed); it is relaxed once when nobody else is routable.  A replica
        that REJECTED during this dispatch pass (queue-full / dead pump) is
        a hard exclusion — it is never re-tried within the pass, so a fleet
        whose every queue is full sheds instead of busy-spinning."""
        tried: Set[str] = set()      # hard: rejected during THIS pass
        avoid = set(exclude)         # soft: retry-ring preference
        while True:
            with self._lock:
                rep = self._route(tried | avoid)
                if rep is None and avoid:
                    avoid = set()                 # any port in a storm
                    rep = self._route(tried)
                # bind to the exact server instance we submit to: rep.server
                # may be swapped by a heal while this attempt is in flight
                srv = rep.server if rep is not None else None
            if rep is None or srv is None:
                raise NoReplicaAvailable(
                    f"no routable replica (states: "
                    f"{ {n: r.state.value for n, r in self.replicas.items()} })")
            try:
                tk = srv.submit(*ft.inputs, budget=ft.budget,
                                tenant=ft.tenant)
            except QueueFull:
                tried.add(rep.name)           # backpressure: try a sibling
                continue
            except RuntimeError:
                # dead pump hit between health checks: score + try a sibling
                with self._lock:
                    rep.record_failure()
                    if rep.server is srv and srv.fatal is not None:
                        self._eject(rep, cause=self._fatal_cause(srv))
                tried.add(rep.name)
                continue
            with self._lock:
                rep.outstanding += 1
                att = _Attempt(rep, srv, tk, time.monotonic(), hedge)
                ft.live.append(att)
                ft.attempts += 1
                if hedge:
                    ft.hedges += 1
                    self.hedged += 1
            return att

    # -- request lifecycle ---------------------------------------------------
    def submit(self, *inputs, budget: float = 1.0,
               deadline_s: Optional[float] = None,
               tenant: str = "default") -> FleetTicket:
        """Route one request to a replica; returns a :class:`FleetTicket`.

        Raises :class:`NoReplicaAvailable` when the whole fleet is
        unroutable (typed load shedding — nothing is queued onto dead
        pumps)."""
        with self._lock:
            if not self._running:
                raise RuntimeError(
                    "fleet router is not running; start() it first")
            rid = self._rids
            self._rids += 1
        ft = FleetTicket(self, rid, tuple(inputs), budget, tenant,
                         time.monotonic()
                         + (deadline_s if deadline_s is not None
                            else self.default_deadline_s))
        try:
            self._dispatch(ft)
        except NoReplicaAvailable:
            with self._lock:
                self.shed += 1
            raise
        with self._lock:
            self.submitted += 1
        return ft

    def _settle_attempts(self, ft: FleetTicket, keep: Optional[_Attempt]
                         ) -> None:
        """Drop every live attempt except ``keep`` (hedge losers, deadline
        cleanup).  Caller holds the lock."""
        for att in ft.live:
            if att is keep:
                continue
            att.replica.outstanding = max(0, att.replica.outstanding - 1)
            try:
                # always the server the ticket was SUBMITTED to — a healed
                # replica's fresh server reuses rids for other requests
                att.server.drop(att.ticket)
            except Exception:           # dead server: nothing left to drop
                pass
        ft.live = [keep] if keep is not None else []

    def _terminate(self, ft: FleetTicket, err: Exception) -> None:
        with self._lock:
            self._settle_attempts(ft, None)
            ft._terminal = err
            self.failed += 1
            if isinstance(err, DeadlineExceeded):
                self.deadlines_exceeded += 1

    def result(self, ticket: FleetTicket, timeout: Optional[float] = None):
        """Resolve one fleet ticket: the output rows, or a typed error.

        Runs the failover loop in the calling thread — bounded waits, retry
        on a different replica with backoff+jitter, optional hedging — and
        is GUARANTEED to return or raise by ``min(deadline, timeout)``:
        a fleet ticket can time out (claimable again later) but never hang.

        Single consumption, like AccelServer: a second ``result()`` call —
        after a claim OR concurrently with another resolving thread —
        raises ``KeyError`` rather than racing on the attempt list.
        """
        ft = ticket
        with self._lock:
            if ft._terminal is not None:
                raise ft._terminal
            if ft._claimed or ft._resolving:
                raise KeyError(ft.rid)
            ft._resolving = True
        try:
            return self._resolve(ft, timeout)
        finally:
            # a TimeoutError exit leaves the ticket claimable again; a
            # claim / terminal exit is already recorded on the ticket
            ft._resolving = False

    def _resolve(self, ft: FleetTicket, timeout: Optional[float]):
        caller_deadline = (None if timeout is None
                           else time.monotonic() + timeout)
        while True:
            now = time.monotonic()
            if now >= ft.deadline:
                self._terminate(ft, DeadlineExceeded(
                    f"fleet request {ft.rid} exceeded its deadline after "
                    f"{ft.attempts} attempt(s)"))
                raise ft._terminal
            if caller_deadline is not None and now >= caller_deadline:
                raise TimeoutError(
                    f"fleet request {ft.rid} not served within {timeout}s "
                    "(ticket still claimable)")
            att = next((a for a in ft.live if a.ticket.done()), None)
            if att is not None:
                if self._settle_one(ft, att, now):
                    return self._claim(ft)
                continue           # failure consumed: retry was dispatched
            if (self.hedge_after_s is not None and len(ft.live) == 1
                    and ft.hedges == 0
                    and now - ft.live[0].t0 >= self.hedge_after_s):
                try:
                    self._dispatch(ft, exclude={ft.live[0].replica.name},
                                   hedge=True)
                except NoReplicaAvailable:
                    ft.hedges = 1      # nobody to hedge to: don't retry it
            remaining = ft.deadline - now
            if caller_deadline is not None:
                remaining = min(remaining, caller_deadline - now)
            if self.hedge_after_s is not None and len(ft.live) == 1 \
                    and ft.hedges == 0:
                remaining = min(
                    remaining, self.hedge_after_s - (now - ft.live[0].t0))
            if ft.live:
                # waits on the newest attempt but re-polls every slice so a
                # sibling attempt's resolution is seen promptly
                ft.live[-1].ticket.wait(min(max(remaining, 0.0), 0.005))
            else:
                # no live attempt (all replicas rejected a retry): re-try
                # dispatch until the deadline shuts the request down
                try:
                    self._dispatch(ft)
                except NoReplicaAvailable as e:
                    if ft.retries_left <= 0:
                        self._terminate(ft, RequestFailed(
                            f"fleet request {ft.rid} found no replica after "
                            f"{ft.attempts} attempt(s)"))
                        raise ft._terminal from e
                    ft.retries_left -= 1
                    self._stop_evt.wait(min(0.005, max(remaining, 0.0)))

    def _settle_one(self, ft: FleetTicket, att: _Attempt, now: float) -> bool:
        """Claim one resolved attempt.  True -> success (value stashed in
        ``ft``); False -> failure consumed and, when budget allows, a retry
        dispatched."""
        rep = att.replica
        try:
            val = att.server.result(att.ticket, timeout=self.probe_timeout_s)
        except TimeoutError:
            return False               # raced done(): just poll again
        except Exception as e:
            with self._lock:
                rep.outstanding = max(0, rep.outstanding - 1)
                ft.live.remove(att)
                rep.record_failure()
                # eject only when the CURRENT server is the one that died —
                # a failure from a pre-heal generation must not eject the
                # freshly rebuilt replica
                if rep.server is att.server and att.server.fatal is not None:
                    self._eject(rep, cause=self._fatal_cause(att.server))
                elif (rep.err_ewma > ERR_SUSPECT or rep.breaker.open) \
                        and rep.state == HealthState.HEALTHY:
                    rep.state = HealthState.SUSPECT
                can_retry = ft.retries_left > 0 and not ft.live
            if ft.live:
                return False           # a hedge sibling is still running
            if not can_retry:
                self._terminate(ft, RequestFailed(
                    f"fleet request {ft.rid} failed after {ft.attempts} "
                    f"attempt(s): {e}"))
                raise ft._terminal from e
            ft.retries_left -= 1
            with self._lock:
                self.retried += 1
            backoff = self.backoff_s * (2 ** (ft.attempts - 1))
            backoff *= 1.0 + self.backoff_jitter * self._rng.random()
            self._stop_evt.wait(min(backoff, max(ft.deadline - now, 0.0)))
            try:
                self._dispatch(ft, exclude={rep.name})
            except NoReplicaAvailable as e2:
                self._terminate(ft, RequestFailed(
                    f"fleet request {ft.rid} failed and no replica was "
                    f"available to retry: {e}"))
                raise ft._terminal from e2
            return False
        # success
        with self._lock:
            rep.outstanding = max(0, rep.outstanding - 1)
            ft.live.remove(att)
            self._settle_attempts(ft, None)    # drop hedge losers
            slow = rep.record_success(now - att.t0)
            if slow and rep.state == HealthState.HEALTHY:
                rep.state = HealthState.SUSPECT   # latency spike: watch it
            if rep.state == HealthState.PROBING:
                self._readmit(rep)
            elif rep.state == HealthState.SUSPECT and not rep.breaker.open \
                    and rep.err_ewma < ERR_SUSPECT / 2:
                rep.state = HealthState.HEALTHY
            if att.hedge:
                self.hedge_wins += 1
            self.succeeded += 1
        ft._result_value = val
        return True

    def _claim(self, ft: FleetTicket):
        val = ft._result_value
        del ft._result_value
        ft._claimed = True
        return val

    def drop(self, ticket: FleetTicket) -> None:
        """Release an abandoned fleet ticket: every live attempt is dropped
        on its replica so no output stays resident."""
        with self._lock:
            self._settle_attempts(ticket, None)
            ticket._terminal = RequestFailed(
                f"fleet request {ticket.rid} was dropped")

    def __call__(self, *inputs, budget: float = 1.0,
                 deadline_s: Optional[float] = None, tenant: str = "default"):
        return self.result(self.submit(*inputs, budget=budget,
                                       deadline_s=deadline_s, tenant=tenant))

    # -- health machine ------------------------------------------------------
    @staticmethod
    def _fatal_cause(srv: Optional[AccelServer]) -> str:
        """Name a dead pump's ejection: ``quarantined`` when the scrubber's
        typed IntegrityError killed it (weight-memory corruption), else the
        generic ``dead-pump``."""
        if srv is not None and isinstance(srv.fatal, IntegrityError):
            return "quarantined"
        return "dead-pump"

    def _eject(self, rep: Replica, cause: str = "dead-pump") -> None:
        """Caller holds the lock."""
        if rep.state != HealthState.EJECTED:
            rep.state = HealthState.EJECTED
            rep.ejections += 1
            rep.eject_cause = cause
        rep.ejected_at = time.monotonic()

    def _readmit(self, rep: Replica) -> None:
        """Caller holds the lock."""
        rep.state = HealthState.HEALTHY
        rep.readmissions += 1
        rep.err_ewma = 0.0
        rep.ejected_at = None
        rep.breaker.record_success()

    def _probe(self, rep: Replica) -> Optional[str]:
        """Serve one probe request end-to-end through the replica (outside
        the router lock — probes ride the real request path).  Returns None
        on success, or the failure cause: ``probe`` (the request errored)
        or ``canary`` (it answered, but outside every working point's
        captured fingerprint — semantic corruption)."""
        srv = rep.server
        if srv is None or not srv.alive:
            return "probe"
        with self._lock:
            self.probes += 1
            idx = self.probes - 1
        if self.probe_inputs is None and self.canaries is None:
            return None                 # aliveness-only probe
        inputs = (self.canaries.inputs(idx) if self.canaries is not None
                  else self.probe_inputs)
        tk = None
        try:
            tk = srv.submit(*inputs)
            val = srv.result(tk, timeout=self.probe_timeout_s)
        except Exception:
            if tk is not None:
                try:
                    # release the canary so repeated probes of a persistently
                    # suspect replica never accumulate unclaimed results
                    srv.drop(tk)
                except Exception:       # dead server / already consumed
                    pass
            return "probe"
        if self.canaries is not None and not self.canaries.check(idx, val):
            with self._lock:
                self.canary_failures += 1
            return "canary"
        return None

    def _sentinel_loop(self) -> None:
        while not self._stop_evt.wait(self.probe_interval_s):
            self._sentinel_tick()

    def _sentinel_tick(self) -> None:
        """One heartbeat pass: detect dead pumps, heal + probe ejected
        replicas after cooldown, probe suspects, feed the brownout backlog."""
        now = time.monotonic()
        with self._lock:
            reps = list(self.replicas.values())
        for rep in reps:
            with self._lock:
                srv = rep.server
                dead = srv is None or srv.fatal is not None or not srv.alive
                if dead and rep.state not in (HealthState.EJECTED,
                                              HealthState.PROBING):
                    self._eject(rep, cause=self._fatal_cause(srv))
                state, ejected_at = rep.state, rep.ejected_at
            if state == HealthState.EJECTED:
                if ejected_at is None or now - ejected_at < self.heal_cooldown_s:
                    continue
                with self._lock:
                    if rep.server is None or not rep.server.alive:
                        try:
                            self._build_server(rep)    # heal: fresh pump
                        except Exception:
                            rep.ejected_at = time.monotonic()
                            continue
                    rep.state = HealthState.PROBING
                state = HealthState.PROBING
            if state in (HealthState.PROBING, HealthState.SUSPECT):
                cause = self._probe(rep)
                ok = cause is None
                with self._lock:
                    if ok and rep.state == HealthState.PROBING:
                        self._readmit(rep)
                    elif ok and rep.state == HealthState.SUSPECT \
                            and not rep.breaker.open:
                        rep.state = HealthState.HEALTHY
                    elif not ok:
                        rep.record_failure()
                        srv2 = rep.server
                        if srv2 is None or srv2.fatal is not None:
                            # the pump died under the probe: name the death,
                            # not the probe (quarantined beats probe)
                            cause = self._fatal_cause(srv2)
                        self._eject(rep, cause=cause)
        if self.brownout is not None:
            depth = 0
            for rep in reps:
                srv = rep.server
                if srv is not None and srv.fatal is None:
                    depth += srv.queue_depth()
            self.brownout.observe_depth(depth)

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Fleet counters, per-replica health snapshots, and the brownout
        trajectory (when a shared selector is attached)."""
        with self._lock:
            resolved = self.succeeded + self.failed
            s: Dict[str, Any] = {
                "running": self._running,
                "submitted": self.submitted,
                "succeeded": self.succeeded,
                "failed": self.failed,
                "retries": self.retried,
                "hedges": self.hedged,
                "hedge_wins": self.hedge_wins,
                "shed": self.shed,
                "deadlines_exceeded": self.deadlines_exceeded,
                "probes": self.probes,
                "canary_failures": self.canary_failures,
                "availability": (self.succeeded / resolved if resolved
                                 else 1.0),
                "replicas": {n: r.snapshot()
                             for n, r in self.replicas.items()},
            }
            # aggregate weight-memory integrity telemetry across every
            # replica server with an attached scrubber
            scrubs = [rep.server.scrubber for rep in self.replicas.values()
                      if rep.server is not None
                      and rep.server.scrubber is not None]
        if scrubs:
            tels = [sc.telemetry() for sc in scrubs]
            s["integrity"] = {
                key: sum(t[key] for t in tels)
                for key in ("scrubbed_bytes", "scrub_passes",
                            "detected_flips", "repaired_views",
                            "quarantines")}
            s["integrity"]["quarantined"] = sorted(
                {lbl for t in tels for lbl in t["quarantined"]})
        if self.brownout is not None:
            s["brownout"] = self.brownout.telemetry()
        return s
