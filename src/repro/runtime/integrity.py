"""Weight-memory integrity: SDC detection, scrubbing, self-healing buffers.

PR 9 made the fleet survive *fail-stop* faults (crashes, hangs, latency
spikes).  The remaining robustness gap at the edge is **silent data
corruption**: a single-event upset in the ONE shared
:class:`~repro.quant.pack.PackedWeights` master-code buffer corrupts every
W8/W4/W2 working point on every replica at once — and the fleet would keep
serving garbage with 100% availability.  This module closes that gap:

* :class:`Scrubber` — a rate-bounded daemon (bytes/sec cap, so scrubbing
  never starves the serving pump) that walks the buffer's checksummed
  regions round-robin.  On a mismatch it quarantines the region; corrupted
  W4/W2 packed views are **repaired in place** (re-derived bit-exactly from
  the intact master codes — nested truncation makes repair free) while
  master-code or scale corruption is unrepairable and escalates through
  ``on_quarantine`` — :meth:`AccelServer.attach_scrubber
  <repro.runtime.serve.AccelServer.attach_scrubber>` turns that into a
  fatal typed :class:`IntegrityError` (no post-detection corrupted result
  is ever served) and the fleet sentinel ejects the replica with a
  ``quarantined`` cause and heals it through its factory.
* :class:`CanarySet` — semantic canaries: K calibration input → output
  pairs fingerprinted per working point at build time and replayed through
  the REAL submit/result path by the fleet sentinel.  Out-of-tolerance
  results catch corruption the checksums cannot see (an autotune mis-tile,
  a kernel regression, scale drift inside a traced executable) and are
  eject-worthy.
* :class:`BitFlipInjector` — seeded SEU chaos, generalizing
  :class:`~repro.runtime.ft.FailureInjector`'s schedule/rate idiom from
  raised exceptions to in-place bit flips in the live master / view / scale
  buffers; drives ``benchmarks/integrity_sdc.py`` and the CI soak.

Telemetry (``scrubbed_bytes``, ``detected_flips``, ``repaired_views``,
``canary_failures``, ``quarantines``) surfaces through
``AccelServer.stats()`` and ``FleetRouter.stats()``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.quant.pack import PackedWeights, Region, RegionMismatch

__all__ = [
    "BitFlipInjector", "CanarySet", "FlipRecord", "IntegrityError",
    "Scrubber",
]


class IntegrityError(RuntimeError):
    """Typed fatal: unrepairable weight-memory corruption was detected
    (master codes or scales — no redundant source to re-derive from).  A
    server whose scrubber raises this refuses further work, so no
    post-detection corrupted result is ever served; the fleet sentinel
    ejects it with a ``quarantined`` cause and heals via the factory."""

    def __init__(self, message: str,
                 mismatches: Sequence[RegionMismatch] = ()):
        super().__init__(message)
        self.mismatches = list(mismatches)


# ---------------------------------------------------------------------------
# background scrubber
# ---------------------------------------------------------------------------

class Scrubber:
    """Rate-bounded background memory scrubber over ONE shared
    :class:`~repro.quant.pack.PackedWeights` buffer.

    Regions (master codes, per-channel scales, each cached sub-byte packed
    view) are walked round-robin; each pass over the full region list is one
    *scrub period*.  ``rate_bytes_s`` caps how many bytes are re-hashed per
    second so scrubbing never starves the serving pump; ``interval_s`` is
    the daemon's tick.  Detection is deterministic: any flip in a region is
    caught the next time the cursor reaches it, i.e. within one full period
    of the flip (the benchmark gates on a small multiple to absorb
    rate-bounding).

    On mismatch the region is quarantined, then:

    * **view** regions are repaired in place (re-derived from the master
      codes after verifying the master is itself intact) and released from
      quarantine — ``on_repair(mismatch)`` fires;
    * **codes** / **scale** regions stay quarantined and
      ``on_quarantine(mismatch)`` fires exactly once per region —
      :meth:`~repro.runtime.serve.AccelServer.attach_scrubber` escalates
      this to a fatal :class:`IntegrityError`.

    Drive it as a daemon (:meth:`start`/:meth:`stop`) or deterministically
    with :meth:`scrub_once` (tests).  All state is lock-guarded.
    """

    def __init__(self, packed: PackedWeights, *,
                 rate_bytes_s: float = 8e6,
                 interval_s: float = 0.005,
                 on_repair: Optional[Callable[[RegionMismatch], None]] = None,
                 on_quarantine: Optional[Callable[[RegionMismatch], None]]
                 = None,
                 clock: Callable[[], float] = time.monotonic):
        if rate_bytes_s <= 0:
            raise ValueError(f"rate_bytes_s must be > 0, got {rate_bytes_s}")
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.packed = packed
        self.rate_bytes_s = float(rate_bytes_s)
        self.interval_s = float(interval_s)
        self.clock = clock
        self._on_repair = [on_repair] if on_repair else []
        self._on_quarantine = [on_quarantine] if on_quarantine else []
        self._lock = threading.RLock()
        self._cursor = 0
        self._budget = 0.0           # accumulated byte allowance
        self._last_tick: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        # telemetry
        self.scrubbed_bytes = 0
        self.scrub_passes = 0        # completed full walks of the region list
        self.detected_flips = 0
        self.repaired_views = 0
        self.quarantines = 0         # unrepairable regions quarantined
        self.quarantined: Dict[str, RegionMismatch] = {}

    # -- observer registration ----------------------------------------------
    def add_on_repair(self, fn: Callable[[RegionMismatch], None]) -> None:
        with self._lock:
            self._on_repair.append(fn)

    def add_on_quarantine(self, fn: Callable[[RegionMismatch], None]) -> None:
        with self._lock:
            self._on_quarantine.append(fn)

    @property
    def fatal(self) -> Optional[IntegrityError]:
        """The unrepairable-corruption error, once any region is
        permanently quarantined (None while the buffer is servable)."""
        with self._lock:
            if not self.quarantined:
                return None
            return IntegrityError(
                "unrepairable weight-memory corruption: "
                + "; ".join(str(m) for m in self.quarantined.values()),
                list(self.quarantined.values()))

    # -- one region ----------------------------------------------------------
    def _handle(self, mismatch: RegionMismatch) -> None:
        """Quarantine + repair-or-escalate one detected mismatch.  Caller
        holds the lock; callbacks run under it (they must not re-enter)."""
        label = mismatch.region.label()
        self.detected_flips += 1
        if mismatch.repairable:
            # repair only from a verified-intact master: re-deriving from a
            # corrupted master would launder the corruption
            master = Region(mismatch.region.tensor, "codes")
            if self.packed.verify_region(master) is None:
                self.packed.repair(mismatch)
                self.repaired_views += 1
                for fn in self._on_repair:
                    fn(mismatch)
                return
            # master is corrupt too: fall through to escalate the view as
            # collateral (the master's own walk will quarantine it as well)
        if label not in self.quarantined:
            self.quarantined[label] = mismatch
            self.quarantines += 1
            for fn in self._on_quarantine:
                fn(mismatch)

    # -- scrub passes --------------------------------------------------------
    def scrub_once(self, max_bytes: Optional[float] = None) -> int:
        """Verify regions from the round-robin cursor until ``max_bytes``
        is spent (None = one full pass).  Returns the number of regions
        verified.  The deterministic entry point the daemon ticks call."""
        with self._lock:
            regions = self.packed.regions()
            if not regions:
                return 0
            n = len(regions)
            budget = float("inf") if max_bytes is None else float(max_bytes)
            verified = 0
            # cap at one full pass per call: the cursor wrapping to its
            # start means every live region was checked once
            for _ in range(n):
                if budget <= 0:
                    break
                region = regions[self._cursor % n]
                self._cursor = (self._cursor + 1) % n
                if self._cursor == 0:
                    self.scrub_passes += 1
                if region.label() in self.quarantined:
                    continue   # off-duty: unrepairable, already escalated
                mismatch = self.packed.verify_region(region)
                self.scrubbed_bytes += region.nbytes
                budget -= region.nbytes
                verified += 1
                if mismatch is not None:
                    self._handle(mismatch)
            return verified

    def _tick(self) -> int:
        """One daemon tick: accrue byte allowance from elapsed wall time
        (the rate bound) and spend it."""
        now = self.clock()
        with self._lock:
            if self._last_tick is None:
                self._last_tick = now
                return 0
            elapsed, self._last_tick = now - self._last_tick, now
            # cap the accrued budget at ~2 full passes so a long stall does
            # not burst an unbounded scan into one tick
            total = sum(r.nbytes for r in self.packed.regions()) or 1
            self._budget = min(self._budget + elapsed * self.rate_bytes_s,
                               2.0 * total)
            budget = self._budget
            before = self.scrubbed_bytes
        verified = self.scrub_once(max_bytes=budget)
        with self._lock:
            self._budget = max(0.0, self._budget
                               - (self.scrubbed_bytes - before))
        return verified

    def _run(self) -> None:
        while not self._stop_evt.wait(self.interval_s):
            self._tick()

    def start(self) -> "Scrubber":
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                raise RuntimeError("scrubber already running")
            self._stop_evt.clear()
            self._last_tick = None
            self._thread = threading.Thread(
                target=self._run, name="weight-scrubber", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 5.0) -> None:
        with self._lock:
            t = self._thread
            self._thread = None
        self._stop_evt.set()
        if t is not None:
            t.join(timeout)

    @property
    def alive(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def __enter__(self) -> "Scrubber":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- telemetry -----------------------------------------------------------
    def period_bytes(self) -> int:
        """Bytes in one full scrub period (the current region list)."""
        return sum(r.nbytes for r in self.packed.regions())

    def telemetry(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "scrubbed_bytes": self.scrubbed_bytes,
                "scrub_passes": self.scrub_passes,
                "detected_flips": self.detected_flips,
                "repaired_views": self.repaired_views,
                "quarantines": self.quarantines,
                "quarantined": sorted(self.quarantined),
                "rate_bytes_s": self.rate_bytes_s,
            }


# ---------------------------------------------------------------------------
# semantic canaries
# ---------------------------------------------------------------------------

@dataclass
class _Canary:
    inputs: Tuple[np.ndarray, ...]
    # point name -> expected outputs (tuple of arrays, len 1 if single)
    expected: Dict[str, Tuple[np.ndarray, ...]]


@dataclass
class CanarySet:
    """K calibration input → output pairs fingerprinted per working point.

    Checksums see *storage* corruption; canaries see *semantic* corruption —
    an autotune mis-tile, a kernel regression, scale drift baked into a
    traced executable — by replaying known inputs through the REAL
    submit/result path and comparing against the outputs captured at build
    time.  The fleet sentinel runs one canary per probe; an out-of-tolerance
    result is eject-worthy (``canary`` cause).

    A probe's serving point depends on the live selector (brownout may have
    downshifted the fleet), so :meth:`check` accepts a result that matches
    ANY captured point's fingerprint within tolerance.
    """

    canaries: List[_Canary] = field(default_factory=list)
    rtol: float = 1e-4
    atol: float = 1e-5

    @classmethod
    def capture(cls, point_executables: Dict[str, Callable],
                calib_inputs: Sequence[Sequence[Any]], *, k: int = 2,
                rtol: float = 1e-4, atol: float = 1e-5) -> "CanarySet":
        """Fingerprint ``k`` calibration requests through every point
        executable at build time.  ``calib_inputs`` is a sequence of
        argument tuples (one per request, each the positional inputs a
        submit would take)."""
        cs = cls(rtol=rtol, atol=atol)
        for args in list(calib_inputs)[:k]:
            args = tuple(np.asarray(a) for a in args)
            expected: Dict[str, Tuple[np.ndarray, ...]] = {}
            for name, exe in point_executables.items():
                out = exe(*args)
                outs = out if isinstance(out, tuple) else (out,)
                expected[name] = tuple(np.asarray(o) for o in outs)
            cs.canaries.append(_Canary(args, expected))
        if not cs.canaries:
            raise ValueError("CanarySet.capture needs at least one "
                             "calibration request")
        return cs

    def __len__(self) -> int:
        return len(self.canaries)

    def inputs(self, i: int) -> Tuple[np.ndarray, ...]:
        return self.canaries[i % len(self.canaries)].inputs

    def check(self, i: int, result: Any) -> bool:
        """True when ``result`` matches any captured working point's
        fingerprint for canary ``i`` within tolerance (and is finite)."""
        outs = result if isinstance(result, tuple) else (result,)
        outs = tuple(np.asarray(o) for o in outs)
        for o in outs:
            if np.issubdtype(o.dtype, np.floating) and not np.isfinite(o).all():
                return False
        for expected in self.canaries[i % len(self.canaries)].expected.values():
            if len(expected) != len(outs):
                continue
            if all(np.allclose(o, e, rtol=self.rtol, atol=self.atol)
                   for o, e in zip(outs, expected)):
                return True
        return False


# ---------------------------------------------------------------------------
# SEU chaos
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FlipRecord:
    """One injected bit flip (for the benchmark's detection accounting)."""
    step: int
    region: Region
    byte: int
    bit: int


class BitFlipInjector:
    """Seeded single-event-upset source for the live packed buffers.

    Generalizes :class:`~repro.runtime.ft.FailureInjector`'s deterministic
    schedule/rate idiom from raised exceptions to *in-place corruption*:
    ``flip_at`` steps fire once each, a seeded ``rate`` draws continuous
    soak flips, and every flip picks a region (master codes / cached packed
    view / scales, filtered by ``kinds``), a byte and a bit from the same
    seeded stream — a given seed produces the identical flip sequence run
    after run.  Flips mutate the buffers the scrubber hashes (and that new
    executable traces would read), NOT copies, so detection and repair are
    exercised end-to-end.
    """

    def __init__(self, packed: PackedWeights, *,
                 flip_at: Optional[List[int]] = None,
                 rate: float = 0.0, seed: int = 0,
                 kinds: Sequence[str] = ("codes", "view", "scale")):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        bad = set(kinds) - {"codes", "view", "scale"}
        if bad:
            raise ValueError(f"unknown region kinds: {sorted(bad)}")
        self.packed = packed
        self.flip_at = set(flip_at or [])
        self.fired: set = set()
        self.rate = rate
        self.kinds = tuple(kinds)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.flips: List[FlipRecord] = []

    @property
    def injected_flips(self) -> int:
        return len(self.flips)

    def _candidates(self) -> List[Region]:
        return [r for r in self.packed.regions() if r.kind in self.kinds]

    def _corrupt(self, region: Region, byte: int, bit: int) -> None:
        """Flip one bit of one region's live buffer in place (the jax array
        is replaced by its flipped copy — same dtype/shape, one bit off)."""
        t = self.packed.tensors[region.tensor]
        if region.kind == "codes":
            buf = np.array(t.codes)
        elif region.kind == "scale":
            buf = np.array(t.scale)
        else:
            with t._lock:
                buf = np.array(t._packed[(region.bits, region.align)])
        flat = buf.reshape(-1).view(np.uint8)
        flat[byte % flat.size] ^= np.uint8(1 << bit)
        arr = jnp.asarray(buf)
        if region.kind == "codes":
            t.codes = arr
        elif region.kind == "scale":
            t.scale = arr
        else:
            with t._lock:
                t._packed[(region.bits, region.align)] = arr

    def flip(self, step: int = -1, region: Optional[Region] = None
             ) -> Optional[FlipRecord]:
        """Inject one bit flip (into ``region``, or a seeded-random
        candidate).  Returns the record, or None when no candidate region
        exists yet (no views cached and ``kinds`` excludes the master)."""
        with self._lock:
            if region is None:
                cands = self._candidates()
                if not cands:
                    return None
                region = cands[int(self._rng.integers(len(cands)))]
            byte = int(self._rng.integers(max(region.nbytes, 1)))
            bit = int(self._rng.integers(8))
            self._corrupt(region, byte, bit)
            rec = FlipRecord(step, region, byte, bit)
            self.flips.append(rec)
            return rec

    def maybe_flip(self, step: int) -> Optional[FlipRecord]:
        """The FailureInjector-style entry: fire scheduled ``flip_at`` steps
        once each, then seeded ``rate`` draws."""
        with self._lock:
            scheduled = step in self.flip_at and step not in self.fired
            if scheduled:
                self.fired.add(step)
            drawn = (not scheduled and self.rate
                     and float(self._rng.random()) < self.rate)
        if scheduled or drawn:
            return self.flip(step)
        return None
