"""Serving runtime: prefill/decode steps, the adaptive mixed-precision LM
server, and the batch-coalescing accelerator server.

The adaptive LM server is the paper's CPS story at pod scale (DESIGN.md §7):
one int8 master weight buffer, per-request-batch working-point selection
driven by an energy/SLA policy — switching precision costs no weight reload.
:class:`AccelServer` brings the same story to the graph-flow accelerators:
asynchronously arriving requests of varying sizes are coalesced into padded
bucket-sized batches executed through one batch-polymorphic artifact
(:class:`~repro.core.writers.jax_writer.BatchedExecutable`), with an optional
:class:`~repro.core.adaptive.RuntimePolicy` selecting a precision working
point per scheduled batch.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from dataclasses import dataclass
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.adaptive import (PointSelector, RuntimePolicy,
                                 ServiceObjective, SLOController,
                                 WorkingPoint)
from repro.models import encdec, transformer
from repro.quant.ptq import QuantizedParams, dequantize_tree, quantize_tree_native
from repro.runtime import model_api
from repro.runtime.scheduler import (CoalescingScheduler, LatencyEWMA,
                                     QueueFull, RequestSignature,
                                     ScheduledBatch, percentile)
from repro.sharding import batch_axes

__all__ = [
    "AccelServer", "AdaptiveLMServer", "BatchReport", "NumericalFault",
    "QueueFull", "ServeMetrics", "ServerStopped", "ServiceObjective",
    "Ticket", "decode_state_shardings", "greedy_generate", "make_decode_step",
    "make_prefill_step",
]


class ServerStopped(RuntimeError):
    """Typed shutdown error: the server stopped (or its stop timed out)
    before this request was served.  Callers that retry elsewhere (the fleet
    router) can distinguish it from an execution failure."""


class NumericalFault(RuntimeError):
    """Typed demux error: a request's output rows contained non-finite
    values (NaN/Inf — corrupted weights, a numerically unstable trace, an
    SEU the checksums have not caught yet).  The poisoned rows are withheld:
    the member ticket resolves to this error instead of silently returning
    garbage, and the tenant's ``numerical_faults`` counter increments.
    Like :class:`ServerStopped` it survives :meth:`AccelServer.result`
    un-wrapped so the fleet router can retry the request elsewhere."""


def decode_state_shardings(cfg: ModelConfig, state, mesh: Mesh):
    """Shardings for a DecodeState / EncDecDecodeState (flat kv dims)."""
    dp = batch_axes(mesh)
    tp = mesh.shape["model"]

    def spec_for(path, x):
        if x is None:
            return None
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        # (L, B, ..., feat): batch over dp; last dim over model when divisible
        parts = [None] * x.ndim
        parts[1] = dp
        if x.shape[-1] % tp == 0 and x.shape[-1] >= tp:
            parts[-1] = "model"
        return NamedSharding(mesh, P(*parts))

    if isinstance(state, transformer.DecodeState):
        return transformer.DecodeState(
            cache_k=spec_for("k", state.cache_k),
            cache_v=spec_for("v", state.cache_v),
            ssm_ssd=(None if state.ssm_ssd is None else NamedSharding(
                mesh, P(None, dp, "model", None))),
            ssm_conv=(None if state.ssm_conv is None else NamedSharding(
                mesh, P(None, dp, None, None))),
            index=NamedSharding(mesh, P()))
    return encdec.EncDecDecodeState(
        cache_k=spec_for("k", state.cache_k),
        cache_v=spec_for("v", state.cache_v),
        cross_k=NamedSharding(mesh, P(None, dp, None, None, None)),
        cross_v=NamedSharding(mesh, P(None, dp, None, None, None)),
        index=NamedSharding(mesh, P()))


def make_prefill_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                      tp_total: int = 1):
    def prefill(params, batch):
        logits, aux = model_api.forward_logits(params, batch, cfg, mesh=mesh,
                                               tp_total=tp_total)
        return logits

    return prefill


def make_decode_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                     tp_total: int = 1):
    def step(params, tokens, state):
        return model_api.decode_step(params, tokens, state, cfg, mesh=mesh,
                                     tp_total=tp_total)

    return step


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int,
                    seq_len: int, batch_extras: Optional[Dict] = None):
    """Host-loop greedy decoding (examples / integration tests).

    Always returns ``max_new`` generated tokens after the prompt.  A
    zero-length prompt is legal: with nothing to condition on, generation is
    seeded with token 0 (BOS convention) and that seed counts as the first
    generated token."""
    B, S0 = prompt.shape
    batch = {"tokens": prompt, **(batch_extras or {})}
    state = model_api.init_decode_state(params, batch, cfg, B, seq_len)
    step = jax.jit(lambda p, t, s: model_api.decode_step(p, t, s, cfg))
    out = [prompt]
    if S0:
        # feed the prompt token by token (cache warmup), then generate
        for i in range(S0):
            logits, state = step(params, prompt[:, i:i + 1], state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    else:
        tok = jnp.zeros((B, 1), prompt.dtype)
    for _ in range(max_new):
        out.append(tok)
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Adaptive mixed-precision LM server
# ---------------------------------------------------------------------------

@dataclass
class ServeMetrics:
    point: str
    weight_bytes_read: int
    est_step_energy_uj: float


class AdaptiveLMServer:
    """Batched decode serving with runtime-switchable weight precision.

    One int8 master + scales (shared substrate); each working point is a
    compiled decode step reading the same buffers — switching is picking a
    different executable (CG-reconfiguration analogue, no weight movement).
    """

    def __init__(self, params, cfg: ModelConfig,
                 points: Sequence[WorkingPoint] = (
                     WorkingPoint("w8", 8), WorkingPoint("w4", 4),
                     WorkingPoint("w2", 2)),
                 policy: Optional[RuntimePolicy] = None):
        self.cfg = cfg
        self.points = list(points)
        self.policy = policy or RuntimePolicy(self.points)
        self.qparams = quantize_tree_native(params)
        self._steps: Dict[str, Callable] = {}

    def _step_for(self, pt: WorkingPoint) -> Callable:
        if pt.name not in self._steps:
            bits = pt.weight_bits
            cfg = self.cfg

            @jax.jit
            def step(qtree, tokens, state, _bits=bits):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, jnp.bfloat16)
                return model_api.decode_step(params, tokens, state, cfg)

            self._steps[pt.name] = step
        return self._steps[pt.name]

    def decode(self, tokens, state, energy_budget_frac: float = 1.0
               ) -> Tuple[jax.Array, object, ServeMetrics]:
        pt = self.policy.select(energy_budget_frac)
        logits, state = self._step_for(pt)(self.qparams.tree(), tokens, state)
        nbytes = sum(int(c.size) for c in self.qparams.codes.values())
        wbytes = nbytes * pt.weight_bits // 8
        # energy model: pJ/byte HBM + pJ/flop (roofline constants)
        metrics = ServeMetrics(pt.name, wbytes, wbytes * 2.0e-6)
        return logits, state, metrics


# ---------------------------------------------------------------------------
# Batch-coalescing accelerator server (async, multi-tenant)
# ---------------------------------------------------------------------------

@dataclass
class _BatchFailure:
    """Stored per ticket when its batch's executable raised: the ticket
    resolves to an error instead of silently disappearing."""
    error: Exception


@dataclass
class BatchReport:
    """Telemetry for one executed batch."""
    bucket: int          # leading-dim size actually executed (after padding)
    rows: int            # useful rows (sum of member request sizes)
    padding: int         # zero rows appended to reach the bucket
    requests: int        # member request count
    point: Optional[str]  # precision working point, if a policy is attached
    bits: Optional[int] = None   # weight-bits view the executed artifact used
    tenant: str = "default"      # which resident graph served the batch
    exec_s: Optional[float] = None  # device execution seconds (feeds LatencyEWMA)


class Ticket:
    """Future-style handle for one submitted request.

    ``submit`` returns immediately; the ticket resolves when the pump (the
    background thread, or a synchronous ``pump()`` call) executes the batch
    the request coalesced into.  ``result()`` blocks until then (optionally
    bounded by ``timeout`` when the background pump is running) and raises
    the batch's error if execution failed.  Results are single-consumption;
    an abandoned ticket is released with :meth:`AccelServer.drop`.
    """

    __slots__ = ("tenant", "rid", "_server", "_event")

    def __init__(self, server: "AccelServer", tenant: str, rid: int):
        self.tenant = tenant
        self.rid = rid
        self._server = server
        self._event = threading.Event()

    def done(self) -> bool:
        """True once the request resolved (result or error ready)."""
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the ticket resolves (True) or ``timeout`` elapses
        (False) without claiming the result — the fleet router's hedging
        loop waits on several replicas' tickets this way."""
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        return self._server.result(self, timeout=timeout)

    def __repr__(self) -> str:
        state = "done" if self.done() else "pending"
        return f"Ticket(tenant={self.tenant!r}, rid={self.rid}, {state})"


@dataclass
class _Pending:
    """A dispatched-but-unforced batch: the device may still be executing
    while the pump assembles and dispatches the next one (host batch assembly
    overlapping device execution)."""
    tenant: "_Tenant"
    batch: ScheduledBatch
    outs: tuple
    multi: bool
    point: Optional[str]
    bits: Optional[int]
    t0: float


class _Tenant:
    """One resident graph: scheduler, executables, QoS class, SLO loop."""

    def __init__(self, name: str, executable: Callable, *,
                 max_batch: int = 8, max_wait: float = 0.005,
                 queue_depth: int = 1024,
                 buckets: Optional[Sequence[int]] = None,
                 policy: Optional[PointSelector] = None,
                 point_executables: Optional[Dict[str, Callable]] = None,
                 signature: Optional[RequestSignature] = None,
                 packing: str = "fifo", weight: int = 1,
                 slo: Optional[ServiceObjective] = None,
                 latency: Optional[LatencyEWMA] = None,
                 selector: Optional[PointSelector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 history: int = 4096):
        if weight < 1:
            raise ValueError(f"tenant weight must be >= 1, got {weight}")
        self.name = name
        self.executable = executable
        self.point_executables: Dict[str, Callable] = dict(point_executables or {})
        self.weight = int(weight)
        # the measurement side of the closed bucket loop: the executor feeds
        # per-bucket execution seconds in, the BucketPolicy reads them back
        self.latency = latency if latency is not None else LatencyEWMA()
        self.scheduler = CoalescingScheduler(
            max_batch=max_batch, max_wait=max_wait, queue_depth=queue_depth,
            buckets=buckets, clock=clock, signature=signature,
            packing=packing, latency=self.latency)
        # ONE point-selection surface: the legacy policy=/slo= pair is
        # normalized into a PointSelector here, so the dispatch/feedback
        # paths below speak only the protocol
        if selector is not None:
            if policy is not None or slo is not None:
                raise ValueError(
                    "pass either selector= or the legacy policy=/slo= pair, "
                    "not both")
        elif slo is not None:
            if policy is None:
                raise ValueError(
                    "an SLO tenant needs a RuntimePolicy: its working points "
                    "are the precision ladder the controller walks")
            selector = SLOController(policy.points, slo)
        else:
            selector = policy
        self.selector: Optional[PointSelector] = selector
        # per-ticket state (guarded by the server lock)
        self.results: Dict[int, Any] = {}
        self.dropped: set = set()
        self.split: Dict[int, List[int]] = {}
        self.child_parent: Dict[int, int] = {}
        self.parent_left: Dict[int, int] = {}
        self.tickets: Dict[int, Ticket] = {}
        # bounded telemetry windows: a long-running server keeps the last
        # ``history`` entries (the scheduler's totals stay cumulative)
        self.reports: Deque[BatchReport] = deque(maxlen=history)
        self.latencies: Deque[float] = deque(maxlen=history)
        self.executed_batches = 0
        self.numerical_faults = 0   # requests withheld by the NaN/Inf guard

    # legacy views of the unified selector, kept for telemetry/test surfaces
    @property
    def controller(self) -> Optional[SLOController]:
        sel = self.selector
        return sel if isinstance(sel, SLOController) else None

    @property
    def policy(self) -> Optional[PointSelector]:
        sel = self.selector
        return None if isinstance(sel, SLOController) else sel

    def executables(self) -> List[Callable]:
        uniq, seen = [], set()
        for exe in (self.executable, *self.point_executables.values()):
            if id(exe) not in seen:
                seen.add(id(exe))
                uniq.append(exe)
        return uniq

    def cached(self) -> Tuple[int, ...]:
        """Union of traced leading-dim sizes across the default and every
        per-point executable (the bucket is chosen before the point is)."""
        sizes = set()
        for exe in self.executables():
            sizes.update(getattr(exe, "cached_batches", ()))
        return tuple(sorted(sizes))


class AccelServer:
    """Async, multi-tenant batch-coalescing serving front-end.

    Several resident graphs (*tenants*) are multiplexed onto one device.
    Each tenant owns a :class:`~repro.runtime.scheduler.CoalescingScheduler`
    (bounded queue — per-tenant :class:`QueueFull` admission control — FIFO
    packing, ``max_wait`` flush, measured-latency bucket selection) over a
    batch-polymorphic executable (plus optional per-precision-point
    executables sharing one weight substrate).  Member inputs are
    concatenated along the leading dim, zero-padded to the chosen bucket,
    executed once, and the outputs sliced back per request — coalescing is
    invisible to callers.

    Two drive modes:

    * **Synchronous** (default, fully deterministic under an injected
      clock): the caller drives :meth:`pump`, exactly the pre-async
      behaviour.
    * **Background pump** (:meth:`start` / :meth:`stop`): ``submit`` returns
      a :class:`Ticket` immediately and a pump thread assembles and
      dispatches batches, keeping up to ``pipeline_depth`` batches dispatched
      but unforced so host batch assembly overlaps device execution.
      Tenants share the device via weighted round-robin (``weight`` = QoS
      class: how many batches a tenant may dispatch per cycle while
      backlogged).  ``stop()`` drains every queue before the thread exits; a
      batch failure resolves its member tickets to per-ticket errors and the
      pump keeps serving; an unexpected pump crash resolves *every*
      outstanding and queued ticket with the error so no caller blocks
      forever.

    Two control loops close over measured latency:

    * per-bucket execution time feeds each tenant's
      :class:`~repro.runtime.scheduler.LatencyEWMA`, which the
      :class:`~repro.runtime.scheduler.BucketPolicy` consults — the static
      pads-no-worse heuristic is only the cold-start fallback;
    * end-to-end request latency feeds the tenant's
      :class:`~repro.core.adaptive.SLOController` (when an ``slo`` is set),
      which walks the precision ladder W8 -> W4 -> W2 down under p95
      pressure and back up when there is headroom — the paper's
      no-weight-reload precision switch, driven by a real signal.
    """

    def __init__(self, executable: Optional[Callable] = None, *,
                 max_batch: int = 8, max_wait: float = 0.005,
                 queue_depth: int = 1024,
                 buckets: Optional[Sequence[int]] = None,
                 policy: Optional[PointSelector] = None,
                 point_executables: Optional[Dict[str, Callable]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 history: int = 4096,
                 signature: Optional[RequestSignature] = None,
                 packing: str = "fifo",
                 weight: int = 1,
                 slo: Optional[ServiceObjective] = None,
                 latency: Optional[LatencyEWMA] = None,
                 selector: Optional[PointSelector] = None,
                 pipeline_depth: int = 2):
        self.clock = clock
        self.pipeline_depth = max(0, int(pipeline_depth))
        self.tenants: Dict[str, _Tenant] = {}
        self._order: List[str] = []          # WRR ring, registration order
        self._rr_pos = 0
        self._rr_credit = 0
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._ever_started = False
        self._stopping = False
        self._drain_on_stop = True
        self._fatal: Optional[BaseException] = None
        self._scrubber = None   # attach_scrubber: weight-memory integrity
        # per-batch executable failures survive here in async mode, where no
        # caller frame exists for pump() to re-raise into
        self.pump_errors: Deque[BaseException] = deque(maxlen=64)
        if executable is not None:
            self.add_tenant("default", executable, max_batch=max_batch,
                            max_wait=max_wait, queue_depth=queue_depth,
                            buckets=buckets, policy=policy,
                            point_executables=point_executables,
                            signature=signature, packing=packing,
                            weight=weight, slo=slo, latency=latency,
                            selector=selector, history=history)

    # -- tenant registry -----------------------------------------------------
    def add_tenant(self, name: str, executable: Callable, **kwargs) -> str:
        """Register a resident graph under ``name``; returns the name.

        Keyword arguments mirror the constructor's per-tenant set:
        ``max_batch``, ``max_wait``, ``queue_depth``, ``buckets``,
        ``policy``, ``point_executables``, ``signature``, ``packing``,
        ``weight`` (QoS: batches per WRR cycle while backlogged), ``slo`` (a
        :class:`~repro.core.adaptive.ServiceObjective` — requires a
        ``policy`` whose points form the precision ladder), ``latency``,
        ``selector`` (any :class:`~repro.core.adaptive.PointSelector` — the
        unified surface; mutually exclusive with ``policy``/``slo``) and
        ``history``."""
        with self._lock:
            if name in self.tenants:
                raise ValueError(f"tenant {name!r} already registered")
            ten = _Tenant(name, executable, clock=self.clock, **kwargs)
            self.tenants[name] = ten
            self._order.append(name)
            if len(self._order) == 1:
                self._rr_credit = ten.weight
        return name

    def _tenant(self, name: str) -> _Tenant:
        try:
            return self.tenants[name]
        except KeyError:
            raise KeyError(f"no tenant {name!r}; have {tuple(self.tenants)}")

    # -- single-tenant compatibility surface ---------------------------------
    @property
    def _default(self) -> _Tenant:
        return self._tenant("default")

    @property
    def scheduler(self) -> CoalescingScheduler:
        return self._default.scheduler

    @property
    def executable(self) -> Callable:
        return self._default.executable

    @property
    def point_executables(self) -> Dict[str, Callable]:
        return self._default.point_executables

    @property
    def policy(self) -> Optional[PointSelector]:
        return self._default.policy

    @property
    def selector(self) -> Optional[PointSelector]:
        return self._default.selector

    @property
    def reports(self) -> Deque[BatchReport]:
        return self._default.reports

    @property
    def latencies(self) -> Deque[float]:
        return self._default.latencies

    @property
    def executed_batches(self) -> int:
        return self._default.executed_batches

    @property
    def _results(self) -> Dict[int, Any]:
        return self._default.results

    @property
    def _dropped(self) -> set:
        return self._default.dropped

    @property
    def _split(self) -> Dict[int, List[int]]:
        return self._default.split

    # -- request lifecycle ---------------------------------------------------
    def submit(self, *inputs, budget: float = 1.0,
               tenant: str = "default") -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` immediately.

        Raises the tenant's :class:`QueueFull` when its bounded queue is at
        depth (admission control — other tenants are unaffected).  A request
        whose leading dim exceeds the tenant's ``max_batch`` is transparently
        split into chunk requests and demuxed back to this one ticket."""
        with self._cond:
            if self._fatal is not None:
                raise RuntimeError(
                    "server pump died; no new requests accepted"
                ) from self._fatal
            ten = self._tenant(tenant)
            req = ten.scheduler.submit(inputs, budget=budget)
            tk = Ticket(self, ten.name, req.rid)
            ten.tickets[req.rid] = tk
            if req.children:
                ten.split[req.rid] = list(req.children)
                ten.parent_left[req.rid] = len(req.children)
                for c in req.children:
                    ten.child_parent[c] = req.rid
            self._cond.notify_all()
        return tk

    # -- batch selection (weighted round-robin across tenants) ---------------
    def _next_batch(self, flush: bool) -> Optional[Tuple[_Tenant, ScheduledBatch]]:
        """Pop the next due batch under WRR, or None.  Caller holds the lock.

        Each tenant may dispatch up to ``weight`` batches per turn while it
        has work ready; an idle or exhausted tenant forfeits the rest of its
        turn, so QoS ratios only bind under contention (work-conserving)."""
        names = self._order
        for _ in range(len(names) + 1):
            if not names:
                return None
            ten = self.tenants[names[self._rr_pos % len(names)]]
            if self._rr_credit > 0:
                batch = ten.scheduler.ready(ten.cached(), flush=flush)
                if batch is not None:
                    self._rr_credit -= 1
                    return ten, batch
            self._rr_pos = (self._rr_pos + 1) % len(names)
            self._rr_credit = self.tenants[names[self._rr_pos]].weight
        return None

    # -- execution -----------------------------------------------------------
    def _select(self, ten: _Tenant, batch: ScheduledBatch
                ) -> Tuple[Callable, Optional[str], Optional[int]]:
        exe, point, pt = ten.executable, None, None
        if ten.selector is not None:
            # one protocol call: open-loop selectors read the batch budget,
            # closed-loop ones (SLOController) ignore it and use observe()
            pt = ten.selector.select(batch.budget)
        if pt is not None:
            point = pt.name
            exe = ten.point_executables.get(pt.name, exe)
        # which weight-bits view served this batch: the artifact's own stamp
        # (packed-weight executables carry it), else the selected point's
        bits = getattr(exe, "bits", None)
        if bits is None and pt is not None:
            bits = pt.weight_bits
        return exe, point, bits

    def _dispatch(self, ten: _Tenant, batch: ScheduledBatch) -> _Pending:
        exe, point, bits = self._select(ten, batch)
        # batch assembly and demux stay on the host: jnp.concatenate /
        # per-slice demux would XLA-compile a fresh kernel per distinct
        # request-shape combination, which dwarfs the accelerator call on a
        # varied stream (one compiled graph per bucket is the whole point)
        cols = []
        for j in range(len(batch.requests[0].inputs)):
            parts = [np.asarray(r.inputs[j]) for r in batch.requests]
            col = np.zeros((batch.bucket, *parts[0].shape[1:]),
                           parts[0].dtype)
            off = 0
            for p in parts:
                col[off:off + p.shape[0]] = p
                off += p.shape[0]
            cols.append(col)
        t0 = self.clock()
        out = exe(*cols)
        multi = isinstance(out, tuple)
        return _Pending(ten, batch, tuple(out if multi else (out,)), multi,
                        point, bits, t0)

    @staticmethod
    def _finite(sliced: Tuple[np.ndarray, ...]) -> bool:
        """True when every float output slice is NaN/Inf-free (integer
        outputs — token ids — vacuously pass)."""
        return all(np.isfinite(o).all()
                   for o in sliced if np.issubdtype(o.dtype, np.floating))

    def _finish(self, pending: _Pending) -> None:
        # forcing to numpy blocks on the device; everything after is host
        outs = tuple(np.asarray(o) for o in pending.outs)
        done = self.clock()
        ten, batch = pending.tenant, pending.batch
        exec_s = done - pending.t0
        with self._lock:
            off = 0
            for r in batch.requests:
                sliced = tuple(o[off:off + r.size] for o in outs)
                if r.rid in ten.dropped:
                    ten.dropped.discard(r.rid)   # abandoned pre-execution
                elif not self._finite(sliced):
                    # poisoned rows are withheld per request, not per batch:
                    # a NaN in one member's slice must not fail its batch
                    # neighbours (padding made them share an execution only)
                    ten.numerical_faults += 1
                    self._resolve(ten, r.rid, _BatchFailure(NumericalFault(
                        f"request {r.rid} (tenant {ten.name!r}) produced "
                        "non-finite outputs; rows withheld")))
                else:
                    self._resolve(ten, r.rid,
                                  sliced if pending.multi else sliced[0])
                    lat = done - r.arrival
                    ten.latencies.append(lat)
                    if ten.selector is not None:
                        ten.selector.observe(lat)
                off += r.size
            # close the bucket loop: this bucket's measured execution time
            ten.latency.observe(batch.bucket, exec_s)
            ten.executed_batches += 1
            ten.reports.append(BatchReport(
                batch.bucket, batch.size, batch.padding, len(batch.requests),
                pending.point, pending.bits, ten.name, exec_s))

    def _fail_batch(self, ten: _Tenant, batch: ScheduledBatch,
                    err: BaseException) -> None:
        """Resolve every member ticket of a failed batch to its error — the
        requests already left the queue, and losing them would leave their
        result() callers waiting on tickets that can never be served."""
        with self._lock:
            for r in batch.requests:
                if r.rid in ten.dropped:
                    ten.dropped.discard(r.rid)
                else:
                    self._resolve(ten, r.rid, _BatchFailure(err))

    def _run_batch(self, ten: _Tenant, batch: ScheduledBatch) -> None:
        """Synchronous execute: dispatch + force, re-raising on failure
        (after resolving the member tickets)."""
        try:
            self._finish(self._dispatch(ten, batch))
        except Exception as e:
            self._fail_batch(ten, batch, e)
            raise

    def _resolve(self, ten: _Tenant, rid: int, value: Any) -> None:
        """Store a leaf result and fire ticket events.  Caller holds the
        lock.  A chunk resolution decrements its split parent; the parent's
        ticket fires when the last chunk lands."""
        ten.results[rid] = value
        parent = ten.child_parent.pop(rid, None)
        if parent is not None:
            left = ten.parent_left.get(parent, 1) - 1
            if left > 0:
                ten.parent_left[parent] = left
                return
            ten.parent_left.pop(parent, None)
            rid = parent
        tk = ten.tickets.get(rid)
        if tk is not None:
            tk._event.set()

    # -- synchronous pump ----------------------------------------------------
    def pump(self, flush: bool = False) -> int:
        """Execute every batch the schedulers deem ready (weighted
        round-robin across tenants); ``flush=True`` forces out partial
        batches (stream end / result demand).  Returns the number of batches
        executed.  Only valid while no background pump is running."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError(
                "background pump running: results arrive via result()/"
                "tickets; stop() the server to drive it synchronously")
        n = 0
        while True:
            with self._lock:
                nxt = self._next_batch(flush)
            if nxt is None:
                return n
            ten, batch = nxt
            self._run_batch(ten, batch)
            n += 1

    # -- background pump -----------------------------------------------------
    def start(self) -> "AccelServer":
        """Spawn the background pump thread; ``submit`` now overlaps host
        batch assembly with device execution.  Idempotent lifecycle:
        ``start`` -> ``stop(drain=True)``; usable as a context manager."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                raise RuntimeError("pump thread already running")
            if self._fatal is not None:
                raise RuntimeError(
                    "server pump died; create a fresh server") from self._fatal
            self._stopping = False
            self._drain_on_stop = True
            self._ever_started = True
            self._thread = threading.Thread(
                target=self._pump_loop, name="accel-server-pump", daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop the pump thread.  ``drain=True`` (default) serves everything
        still queued first; ``drain=False`` abandons the queues, resolving
        their tickets with an error so no caller blocks forever.

        A ``timeout`` that expires with the pump still running (a hung device
        call, a wedged executable) marks the server fatal, resolves *every*
        outstanding and queued ticket with a typed :class:`ServerStopped`
        error — no caller may block on a pump that will never answer — and
        then raises.  Repeated ``stop()`` calls are safe no-ops."""
        with self._cond:
            t = self._thread
            if t is None or self._fatal is not None:
                return   # never started, already stopped, or already fatal
            self._stopping = True
            self._drain_on_stop = drain
            self._cond.notify_all()
        t.join(timeout)
        if t.is_alive():
            # the pump is wedged: its tickets can never be served.  Resolve
            # them all with the typed shutdown error (idempotently — if the
            # pump un-wedges later, already-resolved rids are left alone) and
            # refuse further work so a repeated stop() is a no-op.
            err = ServerStopped(
                f"pump thread did not exit within {timeout}s; outstanding "
                "tickets resolved with this error")
            with self._cond:
                self._fatal = err
                self.pump_errors.append(err)
                self._resolve_all_outstanding(err)
                self._cond.notify_all()
            raise RuntimeError("pump thread did not exit within timeout")
        with self._cond:
            self._thread = None
            self._stopping = False
            if not drain and self._fatal is None:
                err = ServerStopped(
                    "server stopped before serving this request")
                for ten in self.tenants.values():
                    for r in ten.scheduler.abandon():
                        if r.rid in ten.dropped:
                            ten.dropped.discard(r.rid)
                        else:
                            self._resolve(ten, r.rid, _BatchFailure(err))

    def __enter__(self) -> "AccelServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    # -- fleet hooks (health probes / drain / brownout) ----------------------
    @property
    def alive(self) -> bool:
        """True while the background pump thread is running and the server
        has not failed fatally — the fleet router's aliveness probe."""
        t = self._thread
        return self._fatal is None and t is not None and t.is_alive()

    @property
    def fatal(self) -> Optional[BaseException]:
        """The error that killed the pump (None while healthy)."""
        return self._fatal

    def queue_depth(self) -> int:
        """Total queued requests across all tenants — the fleet brownout
        selector's backlog signal."""
        with self._lock:
            return sum(len(t.scheduler) for t in self.tenants.values())

    def attach_scrubber(self, scrubber) -> None:
        """Wire a :class:`~repro.runtime.integrity.Scrubber` over this
        server's weight buffer: unrepairable corruption (master codes or
        scales) becomes a fatal typed
        :class:`~repro.runtime.integrity.IntegrityError` — the pump dies,
        every outstanding ticket resolves to the error, new work is refused,
        so no post-detection corrupted result is ever served — and the fleet
        sentinel sees ``fatal`` and ejects the replica with a
        ``quarantined`` cause.  The scrubber's telemetry surfaces under
        ``stats()["integrity"]``.  Lifecycle stays the caller's: attach
        does not :meth:`~repro.runtime.integrity.Scrubber.start` it."""
        from repro.runtime.integrity import IntegrityError

        def _quarantine(mismatch):
            self._die(IntegrityError(
                f"weight memory quarantined: {mismatch}", [mismatch]))

        self._scrubber = scrubber
        scrubber.add_on_quarantine(_quarantine)

    @property
    def scrubber(self):
        return self._scrubber

    def set_selector(self, selector: Optional[PointSelector],
                     tenant: str = "default") -> None:
        """Swap a tenant's point selector at runtime.  The fleet router uses
        this to wire ONE shared :class:`~repro.core.adaptive.BrownoutSelector`
        into every replica so the whole fleet walks the precision ladder
        together."""
        with self._lock:
            self._tenant(tenant).selector = selector

    def _any_queued(self) -> bool:
        return any(len(t.scheduler) for t in self.tenants.values())

    def _poll_s(self) -> float:
        waits = [t.scheduler.max_wait for t in self.tenants.values()]
        w = min(waits) if waits else 0.005
        return min(max(w / 2, 1e-4), 0.05)

    def _pump_loop(self) -> None:
        try:
            while True:
                with self._cond:
                    while (not self._stopping and self._fatal is None
                           and not self._any_queued()):
                        self._cond.wait(timeout=self._poll_s())
                    if self._fatal is not None:
                        # a timed-out stop() already resolved every ticket
                        # and marked the server dead: a late-unwedged pump
                        # must not keep serving a server callers gave up on
                        return
                    if self._stopping and (not self._drain_on_stop
                                           or not self._any_queued()):
                        return
                    flush = self._stopping
                executed = self._pump_async(flush)
                if not executed and not self._stopping:
                    # work is queued but not yet due (max_wait still
                    # running): nap instead of spinning
                    with self._cond:
                        self._cond.wait(timeout=self._poll_s())
        except BaseException as e:   # noqa: BLE001 — the pump must not die silently
            self._die(e)

    def _pump_async(self, flush: bool) -> int:
        """One pass over the due batches, pipelined: up to
        ``pipeline_depth`` batches stay dispatched-but-unforced, so the host
        assembles batch k+1 while the device executes batch k.  A batch
        failure resolves its member tickets and the pump keeps serving."""
        inflight: Deque[_Pending] = deque()
        executed = 0
        while True:
            with self._lock:
                nxt = self._next_batch(flush)
            if nxt is None:
                break
            ten, batch = nxt
            try:
                inflight.append(self._dispatch(ten, batch))
                executed += 1
            except Exception as e:
                self._fail_batch(ten, batch, e)
                self.pump_errors.append(e)
                continue
            if len(inflight) > self.pipeline_depth:
                self._finish_safe(inflight.popleft())
        while inflight:
            self._finish_safe(inflight.popleft())
        return executed

    def _finish_safe(self, pending: _Pending) -> None:
        try:
            self._finish(pending)
        except Exception as e:
            self._fail_batch(pending.tenant, pending.batch, e)
            self.pump_errors.append(e)

    def _resolve_all_outstanding(self, err: BaseException) -> None:
        """Resolve every outstanding and queued ticket with ``err`` (caller
        holds the lock).  Idempotent: already-resolved rids keep their
        results, so a wedged pump that finishes late cannot double-resolve
        split-parent bookkeeping."""
        for ten in self.tenants.values():
            ten.scheduler.abandon()
            for rid in list(ten.child_parent):
                if rid not in ten.results:
                    self._resolve(ten, rid, _BatchFailure(err))
            for rid, tk in list(ten.tickets.items()):
                if rid not in ten.split and rid not in ten.results:
                    self._resolve(ten, rid, _BatchFailure(err))
                tk._event.set()

    def _die(self, err: BaseException) -> None:
        """Pump-thread crash: resolve EVERY outstanding and queued ticket
        with the error so no caller blocks forever, and refuse new work."""
        with self._cond:
            self._fatal = err
            self.pump_errors.append(err)
            self._resolve_all_outstanding(err)
            self._cond.notify_all()

    # -- results -------------------------------------------------------------
    def _locate(self, ticket: Union[Ticket, int]) -> Tuple[_Tenant, int]:
        if isinstance(ticket, Ticket):
            return self._tenant(ticket.tenant), ticket.rid
        return self._default, ticket

    def result(self, ticket: Union[Ticket, int],
               timeout: Optional[float] = None):
        """The output rows for ``ticket``.

        With the background pump running this blocks until the ticket
        resolves (``TimeoutError`` after ``timeout`` seconds, with the
        ticket left claimable); synchronously it flushes the pump on demand.
        Results are single-consumption: each ticket must be claimed exactly
        once (or released with :meth:`drop`), else its output stays
        resident."""
        ten, rid = self._locate(ticket)
        if isinstance(ticket, Ticket) and self._thread is not None:
            # wait in bounded slices, re-checking pump liveness: a pump
            # thread that died without resolving this ticket (a crashed
            # start, a wedged stop) must fail fast instead of blocking a
            # timeout=None caller forever
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not ticket._event.is_set():
                with self._lock:
                    th, stopping = self._thread, self._stopping
                if th is None:
                    break   # pump stopped meanwhile: sync claim below
                if not th.is_alive() and not stopping:
                    raise RuntimeError(
                        f"ticket {rid} (tenant {ten.name!r}) cannot be "
                        "served: the background pump thread is not running "
                        "(it exited without resolving this ticket); create "
                        "a fresh server and resubmit")
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"ticket {rid} (tenant {ten.name!r}) not served "
                        f"within {timeout}s")
                ticket._event.wait(0.05 if remaining is None
                                   else min(0.05, remaining))
        return self._claim(ten, rid)

    def _claim(self, ten: _Tenant, rid: int):
        with self._lock:
            children = ten.split.pop(rid, None)
            if children is not None:
                ten.tickets.pop(rid, None)
        if children is not None:
            parts = []
            try:
                for c in children:
                    parts.append(self._claim(ten, c))
            except Exception:
                # a chunk claim failed: release every unclaimed chunk so no
                # output stays resident forever, and unwind the parent's
                # split bookkeeping.  A still-queued chunk (child_parent
                # entry alive) is marked dropped so its output is discarded
                # at demux; a resolved-but-unclaimed chunk has its result
                # popped; a chunk with NO remaining state was already fully
                # consumed (the raising chunk's usual fate) — dropping it
                # would only grow the dropped set with a rid that can never
                # be demuxed again, so it is skipped.
                with self._lock:
                    ten.parent_left.pop(rid, None)
                    for c in children[len(parts):]:
                        queued = ten.child_parent.pop(c, None) is not None
                        if queued or c in ten.results or c in ten.tickets:
                            self._drop_rid(ten, c)
                raise
            if parts and isinstance(parts[0], tuple):
                return tuple(np.concatenate(col) for col in zip(*parts))
            return np.concatenate(parts)
        async_pump = self._thread is not None
        if not async_pump:
            with self._lock:
                resolved = rid in ten.results
            if not resolved:
                try:
                    self.pump(flush=True)
                except Exception:
                    # the pump's batch may have been ours: if our ticket was
                    # resolved (to a _BatchFailure) fall through and raise
                    # the per-ticket error; else it was someone else's problem
                    with self._lock:
                        if rid not in ten.results:
                            raise
        with self._lock:
            if rid not in ten.results and rid in ten.tickets:
                # a live ticket with no result and nobody pumping: name the
                # un-started pump instead of a bare KeyError (or blocking a
                # caller forever on a pump nobody is running)
                state = ("was never start()ed"
                         if not self._ever_started else "is not running")
                raise RuntimeError(
                    f"ticket {rid} (tenant {ten.name!r}) is unresolved and "
                    f"the background pump {state}; a synchronous pump did "
                    "not produce it (taken by a concurrent pump?) — "
                    "start() the server or retry")
            res = ten.results.pop(rid)   # double claim / dropped: KeyError
            ten.tickets.pop(rid, None)
        if isinstance(res, _BatchFailure):
            if isinstance(res.error, (ServerStopped, NumericalFault)):
                raise res.error    # typed errors must survive the claim
            raise RuntimeError(
                f"batch execution failed for ticket {rid}: {res.error}"
            ) from res.error
        return res

    def _drop_rid(self, ten: _Tenant, rid: int) -> None:
        """Caller holds the lock."""
        tk = ten.tickets.pop(rid, None)
        if tk is not None:
            tk._event.set()   # a dropped ticket must never block a waiter
        children = ten.split.pop(rid, None)
        if children is not None:
            ten.parent_left.pop(rid, None)
            for c in children:
                ten.child_parent.pop(c, None)
                self._drop_rid(ten, c)
            return
        if ten.results.pop(rid, None) is None:
            ten.dropped.add(rid)

    def drop(self, ticket: Union[Ticket, int]) -> None:
        """Release an abandoned ticket (client gave up / timed out) so its
        result does not stay resident forever — whether it already executed
        or is still queued (the batch still runs; the output is discarded
        at demux).  Dropping a split parent releases every chunk."""
        ten, rid = self._locate(ticket)
        with self._lock:
            self._drop_rid(ten, rid)

    def __call__(self, *inputs, budget: float = 1.0,
                 tenant: str = "default"):
        """Synchronous convenience: submit + resolve one request (drives the
        pump inline, or waits on the background pump when running)."""
        return self.result(self.submit(*inputs, budget=budget, tenant=tenant))

    # -- telemetry -----------------------------------------------------------
    def _tenant_stats(self, ten: _Tenant) -> Dict[str, Any]:
        s = ten.scheduler.stats()
        tels = [exe.telemetry() for exe in ten.executables()
                if hasattr(exe, "telemetry")]
        if tels:
            hits = sum(t["hits"] for t in tels)
            misses = sum(t["misses"] for t in tels)
            s["hits"], s["misses"] = hits, misses
            s["hit_rate"] = hits / (hits + misses) if hits + misses else 0.0
            s["cached_batches"] = tuple(sorted(
                {b for t in tels for b in t["cached_batches"]}))
        if ten.latencies:
            s["p50_latency_s"] = percentile(ten.latencies, 0.50)
            s["p95_latency_s"] = percentile(ten.latencies, 0.95)
        s["executed_batches"] = ten.executed_batches
        s["numerical_faults"] = ten.numerical_faults
        s["weight"] = ten.weight
        s["points"] = dict(Counter(r.point for r in ten.reports
                                   if r.point is not None))
        # per-bits batch counts: lets the adaptive-switch benchmark attribute
        # latency to weight working points (W8/W4/W2) over the same window
        s["bits_views"] = dict(Counter(r.bits for r in ten.reports
                                       if r.bits is not None))
        # per-bits resident weight bytes: packed-weight executables stream
        # sub-byte packed buffers at W4/W2, so the bytes actually moving
        # HBM -> VMEM per view are what this reports (not bucket counts)
        s["bits_bytes"] = {
            exe.bits: exe.packed.view_bytes(exe.bits)
            for exe in ten.executables()
            if getattr(exe, "packed", None) is not None
            and getattr(exe, "bits", None) is not None}
        # the closed loops' state: measured per-bucket execution EWMAs and
        # the SLO controller's point/shift telemetry
        s["bucket_latency_s"] = ten.latency.snapshot()
        if ten.controller is not None:
            s["slo"] = ten.controller.telemetry()
        return s

    def stats(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """Scheduler counters + executable hit/miss telemetry + latency
        percentiles, per-point batch counts, measured bucket latencies and
        SLO-controller state.  ``tenant=None`` keeps the single-tenant shape
        when only one tenant is registered; with several it returns
        aggregate counters plus a per-tenant breakdown under ``tenants``."""
        with self._lock:
            if tenant is not None:
                return self._tenant_stats(self._tenant(tenant))
            if len(self.tenants) == 1:
                s = self._tenant_stats(next(iter(self.tenants.values())))
                s["pump_errors"] = len(self.pump_errors)
                if self._scrubber is not None:
                    s["integrity"] = self._scrubber.telemetry()
                return s
            per = {n: self._tenant_stats(t) for n, t in self.tenants.items()}
            agg: Dict[str, Any] = {"tenants": per}
            for key in ("submitted", "split_requests", "split_chunks",
                        "scheduled_batches", "scheduled_rows", "padded_rows",
                        "pending", "executed_batches", "numerical_faults"):
                agg[key] = sum(p.get(key, 0) for p in per.values())
            rows = agg["scheduled_rows"] + agg["padded_rows"]
            agg["padding_waste"] = agg["padded_rows"] / rows if rows else 0.0
            all_lat = [lat for t in self.tenants.values()
                       for lat in t.latencies]
            if all_lat:
                agg["p50_latency_s"] = percentile(all_lat, 0.50)
                agg["p95_latency_s"] = percentile(all_lat, 0.95)
            agg["pump_errors"] = len(self.pump_errors)
            if self._scrubber is not None:
                agg["integrity"] = self._scrubber.telemetry()
            return agg
