"""Serving runtime: prefill/decode steps + the adaptive mixed-precision server.

The adaptive server is the paper's CPS story at pod scale (DESIGN.md §7): one
int8 master weight buffer, per-request-batch working-point selection driven by
an energy/SLA policy — switching precision costs no weight reload.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.models import encdec, transformer
from repro.quant.ptq import QuantizedParams, dequantize_tree, quantize_tree_native
from repro.runtime import model_api
from repro.sharding import batch_axes


def decode_state_shardings(cfg: ModelConfig, state, mesh: Mesh):
    """Shardings for a DecodeState / EncDecDecodeState (flat kv dims)."""
    dp = batch_axes(mesh)
    tp = mesh.shape["model"]

    def spec_for(path, x):
        if x is None:
            return None
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        # (L, B, ..., feat): batch over dp; last dim over model when divisible
        parts = [None] * x.ndim
        parts[1] = dp
        if x.shape[-1] % tp == 0 and x.shape[-1] >= tp:
            parts[-1] = "model"
        return NamedSharding(mesh, P(*parts))

    if isinstance(state, transformer.DecodeState):
        return transformer.DecodeState(
            cache_k=spec_for("k", state.cache_k),
            cache_v=spec_for("v", state.cache_v),
            ssm_ssd=(None if state.ssm_ssd is None else NamedSharding(
                mesh, P(None, dp, "model", None))),
            ssm_conv=(None if state.ssm_conv is None else NamedSharding(
                mesh, P(None, dp, None, None))),
            index=NamedSharding(mesh, P()))
    return encdec.EncDecDecodeState(
        cache_k=spec_for("k", state.cache_k),
        cache_v=spec_for("v", state.cache_v),
        cross_k=NamedSharding(mesh, P(None, dp, None, None, None)),
        cross_v=NamedSharding(mesh, P(None, dp, None, None, None)),
        index=NamedSharding(mesh, P()))


def make_prefill_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                      tp_total: int = 1):
    def prefill(params, batch):
        logits, aux = model_api.forward_logits(params, batch, cfg, mesh=mesh,
                                               tp_total=tp_total)
        return logits

    return prefill


def make_decode_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                     tp_total: int = 1):
    def step(params, tokens, state):
        return model_api.decode_step(params, tokens, state, cfg, mesh=mesh,
                                     tp_total=tp_total)

    return step


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int,
                    seq_len: int, batch_extras: Optional[Dict] = None):
    """Host-loop greedy decoding (examples / integration tests)."""
    B, S0 = prompt.shape
    batch = {"tokens": prompt, **(batch_extras or {})}
    state = model_api.init_decode_state(params, batch, cfg, B, seq_len)
    step = jax.jit(lambda p, t, s: model_api.decode_step(p, t, s, cfg))
    # feed the prompt token by token (cache warmup), then generate
    out = [prompt]
    tok = prompt[:, :1]
    for i in range(S0):
        logits, state = step(params, prompt[:, i:i + 1], state)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    for _ in range(max_new):
        out.append(tok)
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Adaptive mixed-precision LM server
# ---------------------------------------------------------------------------

@dataclass
class ServeMetrics:
    point: str
    weight_bytes_read: int
    est_step_energy_uj: float


class AdaptiveLMServer:
    """Batched decode serving with runtime-switchable weight precision.

    One int8 master + scales (shared substrate); each working point is a
    compiled decode step reading the same buffers — switching is picking a
    different executable (CG-reconfiguration analogue, no weight movement).
    """

    def __init__(self, params, cfg: ModelConfig,
                 points: Sequence[WorkingPoint] = (
                     WorkingPoint("w8", 8), WorkingPoint("w4", 4),
                     WorkingPoint("w2", 2)),
                 policy: Optional[RuntimePolicy] = None):
        self.cfg = cfg
        self.points = list(points)
        self.policy = policy or RuntimePolicy(self.points)
        self.qparams = quantize_tree_native(params)
        self._steps: Dict[str, Callable] = {}

    def _step_for(self, pt: WorkingPoint) -> Callable:
        if pt.name not in self._steps:
            bits = pt.weight_bits
            cfg = self.cfg

            @jax.jit
            def step(qtree, tokens, state, _bits=bits):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, jnp.bfloat16)
                return model_api.decode_step(params, tokens, state, cfg)

            self._steps[pt.name] = step
        return self._steps[pt.name]

    def decode(self, tokens, state, energy_budget_frac: float = 1.0
               ) -> Tuple[jax.Array, object, ServeMetrics]:
        pt = self.policy.select(energy_budget_frac)
        logits, state = self._step_for(pt)(self.qparams.tree(), tokens, state)
        nbytes = sum(int(c.size) for c in self.qparams.codes.values())
        wbytes = nbytes * pt.weight_bits // 8
        # energy model: pJ/byte HBM + pJ/flop (roofline constants)
        metrics = ServeMetrics(pt.name, wbytes, wbytes * 2.0e-6)
        return logits, state, metrics
