"""Serving runtime: prefill/decode steps, the adaptive mixed-precision LM
server, and the batch-coalescing accelerator server.

The adaptive LM server is the paper's CPS story at pod scale (DESIGN.md §7):
one int8 master weight buffer, per-request-batch working-point selection
driven by an energy/SLA policy — switching precision costs no weight reload.
:class:`AccelServer` brings the same story to the graph-flow accelerators:
asynchronously arriving requests of varying sizes are coalesced into padded
bucket-sized batches executed through one batch-polymorphic artifact
(:class:`~repro.core.writers.jax_writer.BatchedExecutable`), with an optional
:class:`~repro.core.adaptive.RuntimePolicy` selecting a precision working
point per scheduled batch.
"""
from __future__ import annotations

import time
from collections import Counter, deque
from dataclasses import dataclass
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.models import encdec, transformer
from repro.quant.ptq import QuantizedParams, dequantize_tree, quantize_tree_native
from repro.runtime import model_api
from repro.runtime.scheduler import (CoalescingScheduler, QueueFull,
                                     RequestSignature, ScheduledBatch,
                                     percentile)
from repro.sharding import batch_axes

__all__ = [
    "AccelServer", "AdaptiveLMServer", "BatchReport", "QueueFull",
    "ServeMetrics", "decode_state_shardings", "greedy_generate",
    "make_decode_step", "make_prefill_step",
]


def decode_state_shardings(cfg: ModelConfig, state, mesh: Mesh):
    """Shardings for a DecodeState / EncDecDecodeState (flat kv dims)."""
    dp = batch_axes(mesh)
    tp = mesh.shape["model"]

    def spec_for(path, x):
        if x is None:
            return None
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        # (L, B, ..., feat): batch over dp; last dim over model when divisible
        parts = [None] * x.ndim
        parts[1] = dp
        if x.shape[-1] % tp == 0 and x.shape[-1] >= tp:
            parts[-1] = "model"
        return NamedSharding(mesh, P(*parts))

    if isinstance(state, transformer.DecodeState):
        return transformer.DecodeState(
            cache_k=spec_for("k", state.cache_k),
            cache_v=spec_for("v", state.cache_v),
            ssm_ssd=(None if state.ssm_ssd is None else NamedSharding(
                mesh, P(None, dp, "model", None))),
            ssm_conv=(None if state.ssm_conv is None else NamedSharding(
                mesh, P(None, dp, None, None))),
            index=NamedSharding(mesh, P()))
    return encdec.EncDecDecodeState(
        cache_k=spec_for("k", state.cache_k),
        cache_v=spec_for("v", state.cache_v),
        cross_k=NamedSharding(mesh, P(None, dp, None, None, None)),
        cross_v=NamedSharding(mesh, P(None, dp, None, None, None)),
        index=NamedSharding(mesh, P()))


def make_prefill_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                      tp_total: int = 1):
    def prefill(params, batch):
        logits, aux = model_api.forward_logits(params, batch, cfg, mesh=mesh,
                                               tp_total=tp_total)
        return logits

    return prefill


def make_decode_step(cfg: ModelConfig, *, mesh: Optional[Mesh] = None,
                     tp_total: int = 1):
    def step(params, tokens, state):
        return model_api.decode_step(params, tokens, state, cfg, mesh=mesh,
                                     tp_total=tp_total)

    return step


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int,
                    seq_len: int, batch_extras: Optional[Dict] = None):
    """Host-loop greedy decoding (examples / integration tests)."""
    B, S0 = prompt.shape
    batch = {"tokens": prompt, **(batch_extras or {})}
    state = model_api.init_decode_state(params, batch, cfg, B, seq_len)
    step = jax.jit(lambda p, t, s: model_api.decode_step(p, t, s, cfg))
    # feed the prompt token by token (cache warmup), then generate
    out = [prompt]
    tok = prompt[:, :1]
    for i in range(S0):
        logits, state = step(params, prompt[:, i:i + 1], state)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    for _ in range(max_new):
        out.append(tok)
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Adaptive mixed-precision LM server
# ---------------------------------------------------------------------------

@dataclass
class ServeMetrics:
    point: str
    weight_bytes_read: int
    est_step_energy_uj: float


class AdaptiveLMServer:
    """Batched decode serving with runtime-switchable weight precision.

    One int8 master + scales (shared substrate); each working point is a
    compiled decode step reading the same buffers — switching is picking a
    different executable (CG-reconfiguration analogue, no weight movement).
    """

    def __init__(self, params, cfg: ModelConfig,
                 points: Sequence[WorkingPoint] = (
                     WorkingPoint("w8", 8), WorkingPoint("w4", 4),
                     WorkingPoint("w2", 2)),
                 policy: Optional[RuntimePolicy] = None):
        self.cfg = cfg
        self.points = list(points)
        self.policy = policy or RuntimePolicy(self.points)
        self.qparams = quantize_tree_native(params)
        self._steps: Dict[str, Callable] = {}

    def _step_for(self, pt: WorkingPoint) -> Callable:
        if pt.name not in self._steps:
            bits = pt.weight_bits
            cfg = self.cfg

            @jax.jit
            def step(qtree, tokens, state, _bits=bits):
                qp = QuantizedParams(qtree["codes"], qtree["scales"],
                                     qtree["passthrough"])
                params = dequantize_tree(qp, _bits, jnp.bfloat16)
                return model_api.decode_step(params, tokens, state, cfg)

            self._steps[pt.name] = step
        return self._steps[pt.name]

    def decode(self, tokens, state, energy_budget_frac: float = 1.0
               ) -> Tuple[jax.Array, object, ServeMetrics]:
        pt = self.policy.select(energy_budget_frac)
        logits, state = self._step_for(pt)(self.qparams.tree(), tokens, state)
        nbytes = sum(int(c.size) for c in self.qparams.codes.values())
        wbytes = nbytes * pt.weight_bits // 8
        # energy model: pJ/byte HBM + pJ/flop (roofline constants)
        metrics = ServeMetrics(pt.name, wbytes, wbytes * 2.0e-6)
        return logits, state, metrics


# ---------------------------------------------------------------------------
# Batch-coalescing accelerator server (continuous batching over the flow)
# ---------------------------------------------------------------------------

@dataclass
class _BatchFailure:
    """Stored per ticket when its batch's executable raised: the ticket
    resolves to an error instead of silently disappearing."""
    error: Exception


@dataclass
class BatchReport:
    """Telemetry for one executed batch."""
    bucket: int          # leading-dim size actually executed (after padding)
    rows: int            # useful rows (sum of member request sizes)
    padding: int         # zero rows appended to reach the bucket
    requests: int        # member request count
    point: Optional[str]  # precision working point, if a policy is attached
    bits: Optional[int] = None   # weight-bits view the executed artifact used


class AccelServer:
    """Batch-coalescing serving front-end over a batch-polymorphic artifact.

    Wires a :class:`~repro.runtime.scheduler.CoalescingScheduler` (bounded
    queue, FIFO packing up to ``max_batch``, ``max_wait`` flush, bucket
    selection against the executable's LRU) to a
    :class:`~repro.core.writers.jax_writer.BatchedExecutable` (or any
    callable, e.g. ``DistWriter.build_batched(mesh)`` for the SPMD path).
    Member inputs are concatenated along the leading dim, zero-padded up to
    the chosen bucket, executed once, and the outputs sliced back
    per request — coalescing is invisible to callers.

    When a :class:`~repro.core.adaptive.RuntimePolicy` is attached, every
    scheduled batch selects a precision working point from the batch budget
    (the most constrained member); ``point_executables`` maps point names to
    per-point executables sharing one weight substrate (the paper's
    no-weight-reload precision switch).
    """

    def __init__(self, executable: Callable, *,
                 max_batch: int = 8, max_wait: float = 0.005,
                 queue_depth: int = 1024,
                 buckets: Optional[Sequence[int]] = None,
                 policy: Optional[RuntimePolicy] = None,
                 point_executables: Optional[Dict[str, Callable]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 history: int = 4096,
                 signature: Optional[RequestSignature] = None,
                 packing: str = "fifo"):
        self.executable = executable
        self.scheduler = CoalescingScheduler(
            max_batch=max_batch, max_wait=max_wait, queue_depth=queue_depth,
            buckets=buckets, clock=clock, signature=signature,
            packing=packing)
        self.policy = policy
        self.point_executables = dict(point_executables or {})
        self.clock = clock
        self._results: Dict[int, Any] = {}
        self._dropped: set = set()
        # oversize submissions: parent ticket -> ordered chunk tickets (the
        # scheduler split them; result() concatenates the chunk outputs)
        self._split: Dict[int, List[int]] = {}
        # bounded telemetry windows: a long-running server keeps the last
        # ``history`` entries, not one record per request forever (the
        # scheduler's totals stay cumulative)
        self.reports: Deque[BatchReport] = deque(maxlen=history)
        self.latencies: Deque[float] = deque(maxlen=history)
        self.executed_batches = 0

    # -- request lifecycle ---------------------------------------------------
    def submit(self, *inputs, budget: float = 1.0) -> int:
        """Enqueue one request; returns the ticket for :meth:`result`.

        A request whose leading dim exceeds ``max_batch`` is transparently
        split into chunk requests and demuxed back to this one ticket."""
        req = self.scheduler.submit(inputs, budget=budget)
        if req.children:
            self._split[req.rid] = list(req.children)
        return req.rid

    def _executables(self) -> List[Callable]:
        uniq, seen = [], set()
        for exe in (self.executable, *self.point_executables.values()):
            if id(exe) not in seen:
                seen.add(id(exe))
                uniq.append(exe)
        return uniq

    def _cached(self) -> Tuple[int, ...]:
        """Union of traced leading-dim sizes across the default and every
        per-point executable (the bucket is chosen before the point is)."""
        sizes = set()
        for exe in self._executables():
            sizes.update(getattr(exe, "cached_batches", ()))
        return tuple(sorted(sizes))

    def _execute(self, batch: ScheduledBatch) -> None:
        exe, point, pt = self.executable, None, None
        if self.policy is not None:
            pt = self.policy.select(batch.budget)
            point = pt.name
            exe = self.point_executables.get(pt.name, exe)
        # which weight-bits view served this batch: the artifact's own stamp
        # (packed-weight executables carry it), else the selected point's
        bits = getattr(exe, "bits", None)
        if bits is None and pt is not None:
            bits = pt.weight_bits
        # batch assembly and demux stay on the host: jnp.concatenate /
        # per-slice demux would XLA-compile a fresh kernel per distinct
        # request-shape combination, which dwarfs the accelerator call on a
        # varied stream (one compiled graph per bucket is the whole point)
        cols = []
        for j in range(len(batch.requests[0].inputs)):
            parts = [np.asarray(r.inputs[j]) for r in batch.requests]
            col = np.zeros((batch.bucket, *parts[0].shape[1:]),
                           parts[0].dtype)
            off = 0
            for p in parts:
                col[off:off + p.shape[0]] = p
                off += p.shape[0]
            cols.append(col)
        try:
            out = exe(*cols)
            multi = isinstance(out, tuple)
            outs = tuple(np.asarray(o) for o in (out if multi else (out,)))
        except Exception as e:
            # resolve every member ticket to an error before propagating —
            # the requests already left the queue, and losing them would
            # leave their result() callers waiting on tickets that can
            # never be served
            for r in batch.requests:
                if r.rid in self._dropped:
                    self._dropped.discard(r.rid)
                else:
                    self._results[r.rid] = _BatchFailure(e)
            raise
        off, done = 0, self.clock()
        for r in batch.requests:
            sliced = tuple(o[off:off + r.size] for o in outs)
            if r.rid in self._dropped:
                self._dropped.discard(r.rid)   # abandoned pre-execution
            else:
                self._results[r.rid] = sliced if multi else sliced[0]
                self.latencies.append(done - r.arrival)
            off += r.size
        self.executed_batches += 1
        self.reports.append(BatchReport(batch.bucket, batch.size,
                                        batch.padding, len(batch.requests),
                                        point, bits))

    def pump(self, flush: bool = False) -> int:
        """Execute every batch the scheduler deems ready; ``flush=True``
        forces out a partial batch (used on stream end / result demand).
        Returns the number of batches executed."""
        n = 0
        for batch in self.scheduler.drain(self._cached(), flush=flush):
            self._execute(batch)
            n += 1
        return n

    def result(self, ticket: int):
        """The output rows for ``ticket`` (flushes if still queued).

        Results are single-consumption: each ticket must be claimed exactly
        once (or released with :meth:`drop`), else its output stays resident.
        """
        children = self._split.pop(ticket, None)
        if children is not None:
            parts = []
            try:
                for c in children:
                    parts.append(self.result(c))
            except Exception:
                # a chunk claim failed: release every unclaimed chunk so no
                # output stays resident forever.  The raising chunk is
                # included — its pump may have re-raised a DIFFERENT batch's
                # failure while this chunk was still queued, in which case it
                # was never consumed; if it WAS consumed the drop leaves at
                # most a stale rid in _dropped (never an array).
                for c in children[len(parts):]:
                    self.drop(c)
                raise
            if parts and isinstance(parts[0], tuple):
                return tuple(np.concatenate(col) for col in zip(*parts))
            return np.concatenate(parts)
        if ticket not in self._results:
            try:
                self.pump(flush=True)
            except Exception:
                # the pump's batch may have been ours: if our ticket was
                # resolved (to a _BatchFailure) fall through and raise the
                # per-ticket error; otherwise it was someone else's problem
                if ticket not in self._results:
                    raise
        res = self._results.pop(ticket)
        if isinstance(res, _BatchFailure):
            raise RuntimeError(
                f"batch execution failed for ticket {ticket}") from res.error
        return res

    def drop(self, ticket: int) -> None:
        """Release an abandoned ticket (client gave up / timed out) so its
        result does not stay resident forever — whether it already executed
        or is still queued (the batch still runs; the output is discarded
        at demux).  Dropping a split parent releases every chunk."""
        children = self._split.pop(ticket, None)
        if children is not None:
            for c in children:
                self.drop(c)
            return
        if self._results.pop(ticket, None) is None:
            self._dropped.add(ticket)

    def __call__(self, *inputs, budget: float = 1.0):
        """Synchronous convenience: submit + flush + demux one request."""
        return self.result(self.submit(*inputs, budget=budget))

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Scheduler counters + executable hit/miss telemetry + latency
        percentiles and per-point batch counts (both over the last
        ``history`` entries)."""
        s = self.scheduler.stats()
        tels = [exe.telemetry() for exe in self._executables()
                if hasattr(exe, "telemetry")]
        if tels:
            hits = sum(t["hits"] for t in tels)
            misses = sum(t["misses"] for t in tels)
            s["hits"], s["misses"] = hits, misses
            s["hit_rate"] = hits / (hits + misses) if hits + misses else 0.0
            s["cached_batches"] = tuple(sorted(
                {b for t in tels for b in t["cached_batches"]}))
        if self.latencies:
            s["p50_latency_s"] = percentile(self.latencies, 0.50)
            s["p95_latency_s"] = percentile(self.latencies, 0.95)
        s["executed_batches"] = self.executed_batches
        s["points"] = dict(Counter(r.point for r in self.reports
                                   if r.point is not None))
        # per-bits batch counts: lets the adaptive-switch benchmark attribute
        # latency to weight working points (W8/W4/W2) over the same window
        s["bits_views"] = dict(Counter(r.bits for r in self.reports
                                       if r.bits is not None))
        # per-bits resident weight bytes: packed-weight executables stream
        # sub-byte packed buffers at W4/W2, so the bytes actually moving
        # HBM -> VMEM per view are what this reports (not bucket counts)
        s["bits_bytes"] = {
            exe.bits: exe.packed.view_bytes(exe.bits)
            for exe in self._executables()
            if getattr(exe, "packed", None) is not None
            and getattr(exe, "bits", None) is not None}
        return s
