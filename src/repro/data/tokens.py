"""Deterministic, shardable, *resumable-by-construction* synthetic token stream.

Every (step, position) token is a pure function of (seed, step, index) via a
counter-based generator (threefry through jax.random.fold_in), so restarting
from a checkpoint at step k reproduces exactly the batches a never-failed run
would have seen — the property a production loader gets from checkpointing
its cursor, with zero loader state.  Tokens follow a Zipf-ish distribution
with short-range structure so LM losses are non-trivially learnable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Materialize the full global batch for ``step`` (tokens + labels)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    # zipf-ish marginal: inverse-CDF on u^3
    u = jax.random.uniform(key, (B, S + 1))
    base = (u ** 3 * (V - 2)).astype(jnp.int32) + 1
    # short-range structure: every 4th token repeats (t-3) -- learnable signal
    idx = jnp.arange(S + 1)
    rep = jnp.roll(base, 3, axis=1)
    toks = jnp.where((idx % 4 == 0)[None, :], rep, base)
    return {"tokens": toks[:, :S], "labels": toks[:, 1:]}


def host_batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    b = batch_at(cfg, step)
    return {k: np.asarray(v) for k, v in b.items()}


class TokenStream:
    """Iterator facade with an explicit cursor (for the fault-tolerant loop)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = batch_at(self.cfg, self.step)
        self.step += 1
        return b

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: Dict[str, int]) -> "TokenStream":
        assert state["seed"] == cfg.seed, "data seed mismatch on restore"
        return cls(cfg, start_step=state["step"])
