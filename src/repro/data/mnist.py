"""Procedural MNIST-like dataset (offline container — no download).

Digits 0-9 are rendered from 7x5 glyph bitmaps, upscaled to 28x28, and
perturbed with random shift, scale, shear and pixel noise.  Deterministic in
the seed.  Absolute accuracies differ from real MNIST; the paper-validation
targets the *orderings* of Table II (DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np

_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    g = _glyph_array(digit)
    # upscale 7x5 -> 21x15 then place on 28x28 canvas with jitter
    up = np.kron(g, np.ones((3, 3), np.float32))
    canvas = np.zeros((28, 28), np.float32)
    oy = rng.integers(0, 28 - up.shape[0] + 1)
    ox = rng.integers(0, 28 - up.shape[1] + 1)
    canvas[oy:oy + up.shape[0], ox:ox + up.shape[1]] = up
    # shear
    shear = rng.uniform(-0.2, 0.2)
    rows = np.arange(28)
    shift = np.round(shear * (rows - 14)).astype(int)
    sheared = np.zeros_like(canvas)
    for r in range(28):
        sheared[r] = np.roll(canvas[r], shift[r])
    # intensity jitter + noise + slight blur
    img = sheared * rng.uniform(0.7, 1.0)
    img = img + rng.normal(0, 0.08, img.shape).astype(np.float32)
    k = np.array([0.25, 0.5, 0.25], np.float32)
    img = np.apply_along_axis(lambda r: np.convolve(r, k, "same"), 1, img)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int = 0):
    """Returns (images (n, 28, 28, 1) f32, labels (n,) i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.stack([_render(int(lab), rng) for lab in labels])[..., None]
    return imgs.astype(np.float32), labels


def batches(images, labels, batch_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(labels)
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sl = idx[i:i + batch_size]
            yield images[sl], labels[sl]
