"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP patch embeddings (stub frontend).

[hf:microsoft/Phi-3-vision-128k-instruct; hf].  input_specs() provides 576
precomputed (B, 576, d_model) patch embeddings fused ahead of the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    d_head=96,
    n_patches=576,
)
