"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; input-shape
cells are :class:`ShapeConfig`.  Reduced ("smoke") variants of each config are
derived with :meth:`ModelConfig.smoke` so CPU tests stay cheap while the full
configs are exercised structurally via the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # vocab padded so embedding tables shard 16-way cleanly


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert hidden size
    capacity_factor: float = 1.0
    router_jitter: float = 0.0
    # shared dense FFN run for every token in addition to experts (granite has none)
    n_shared_experts: int = 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_head: int = 64           # SSD head dim (P)
    n_groups: int = 1          # B/C groups (G)
    d_conv: int = 4            # depthwise conv width
    chunk: int = 256           # SSD chunk length
    expand: int = 2            # d_inner = expand * d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None           # default d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    act: str = "swiglu"                    # swiglu | gelu
    sliding_window: Optional[int] = None   # SWA width (mixtral / danube)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                       # fixed encoder frame count (audio stub)
    # vlm stub
    n_patches: int = 0                     # vision patch embeddings prepended
    # hybrid: run attention and ssm paths in parallel in every block
    hybrid: bool = False
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab, VOCAB_PAD_MULTIPLE)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context (long_500k cell)?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.d_head

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND roofline)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab=512,
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2, d_ff_expert=32)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, d_head=16, chunk=32)
        if self.enc_layers:
            kw["enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.n_patches:
            kw["n_patches"] = 4
        if self.sliding_window is not None:
            kw["sliding_window"] = 32
        return replace(self, name=self.name + "-smoke", **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells that apply to an architecture (skips noted in DESIGN.md §5)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return tuple(out)
