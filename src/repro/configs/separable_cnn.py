"""MobileNet-style depthwise-separable classifier config.

The workload class the direct depthwise kernels open up: a standard conv
stem, then blocks of DepthwiseConv(3x3) + BN + ReLU followed by a pointwise
Conv(1x1) + BN + ReLU — the factorization MobileNet popularized.  Spatial
downsampling happens in the depthwise stage (its ``stride``), exactly where
the legacy im2col lowering pays its kh*kw patch-blowup for zero reuse.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class SeparableCNNConfig:
    name: str = "separable-cnn"
    image_hw: Tuple[int, int] = (28, 28)
    in_channels: int = 1
    stem_channels: int = 8
    # (out_channels, depthwise stride) per separable block
    blocks: Tuple[Tuple[int, int], ...] = ((16, 1), (32, 2))
    kernel_size: int = 3
    pool: int = 2
    n_classes: int = 10

    @property
    def fc_in(self) -> int:
        h, w = self.image_hw
        h, w = h // self.pool, w // self.pool        # stem maxpool
        for _, s in self.blocks:
            h, w = -(-h // s), -(-w // s)            # SAME depthwise stride
        return h * w * self.blocks[-1][0]


CONFIG = SeparableCNNConfig()
