"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality) blocks.  d_inner = 2*d_model = 4096, 64 SSD heads of
dim 64, n_groups=1, depthwise conv width 4.  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,      # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    d_head=64,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_head=64, n_groups=1, d_conv=4, expand=2,
                  chunk=64),
)
