"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.

[arXiv:2411.13676; hf].  Parallel attention + mamba heads per block; most layers
use sliding-window attention (window 2048 here), which together with the SSM
path makes the arch sub-quadratic for the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    d_head=64,
    hybrid=True,
    sliding_window=2048,
    ssm=SSMConfig(d_state=16, d_head=64, n_groups=1, expand=2, chunk=64),
)
