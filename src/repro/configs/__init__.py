"""Architecture registry: ``get_config(arch_id)`` and the assigned-arch list."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shapes_for,
)

# arch-id -> module name
_REGISTRY: Dict[str, str] = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-base": "whisper_base",
    "hymba-1.5b": "hymba_1_5b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCH_IDS: List[str] = list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def get_cnn_config():
    from repro.configs.mnist_cnn import CONFIG
    return CONFIG


def all_cells():
    """Every applicable (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            yield arch, shape.name
