"""The paper's own accelerator model (Table II): 2 convolutional blocks
(conv + maxpool + batchnorm + relu) followed by 1 fully connected layer,
classifying 28x28 MNIST digits into 10 classes.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str = "mnist-cnn"
    image_hw: Tuple[int, int] = (28, 28)
    in_channels: int = 1
    conv_channels: Tuple[int, ...] = (16, 32)
    kernel_size: int = 3
    pool: int = 2
    n_classes: int = 10

    @property
    def fc_in(self) -> int:
        h, w = self.image_hw
        for _ in self.conv_channels:
            h, w = h // self.pool, w // self.pool
        return h * w * self.conv_channels[-1]


CONFIG = CNNConfig()
