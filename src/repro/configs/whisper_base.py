"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec, conv frontend stub.

[arXiv:2212.04356; unverified].  The conv1d mel frontend is a STUB per the
assignment: input_specs() provides precomputed (B, 1500, d_model) frame
embeddings for the encoder.  Decoder is 6 layers with cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    d_head=64,
    rope_theta=0.0,  # learned absolute positions (enc_pos / dec_pos), no RoPE
    norm="layernorm",
    act="gelu",
    enc_layers=6,
    enc_seq=1500,
)
