"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) vocab=49155, MoE 40e top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base; hf].  The assignment line lists both
"40e" and "32 experts"; 40 experts top-8 matches the 3b-a800m config
(d_model=1536, 24 heads, expert d_ff=512) and is used here (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    d_head=64,
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
)
