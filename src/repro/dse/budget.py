"""Resource budgets for the design-space explorer.

The FPGA survey's constrained-DSE framing (DSP/BRAM ceilings) mapped onto
the terms this repo already measures:

* ``weight_bytes``  — resident streamed weight buffer of a working point
  (:meth:`repro.quant.pack.PackedWeights.view_bytes`, sub-byte packed below
  W8, per-layer caps applied) — the BRAM-column analogue;
* ``fifo_bytes``    — ``total_fifo_bytes`` of the sized stream topology
  (:meth:`repro.core.writers.stream_writer.StreamWriter.topology`) — the
  inter-actor buffer memory;
* ``scratch_bytes`` — im2col patch-tensor traffic
  (:func:`repro.launch.roofline.im2col_scratch_bytes`) at the largest batch
  bucket — the lowering's hidden byte term;
* ``total_bytes``   — sum of the three (one ceiling when the split does not
  matter);
* ``latency_s``     — the analytical roofline latency
  (:func:`repro.launch.roofline.predict_latency_s`) at the largest bucket.

Every ceiling is optional; ``None`` means unconstrained.  ``max_batch``
bounds the batch-bucket ladder the candidates are costed (and later served)
at.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Dict, List, Optional


class BudgetInfeasibleError(ValueError):
    """No candidate working point fits the budget.

    ``violations`` maps each violated term of the *closest* candidate (the
    one with the smallest total bytes) to ``(value, ceiling)`` so the caller
    can see which ceiling to relax."""

    def __init__(self, message: str,
                 violations: Optional[Dict[str, tuple]] = None):
        super().__init__(message)
        self.violations = dict(violations or {})


@dataclass(frozen=True)
class ResourceBudget:
    """Explicit resource ceilings for :class:`~repro.dse.DesignSpaceExplorer`
    (all optional — ``ResourceBudget()`` is the unconstrained search)."""

    weight_bytes: Optional[int] = None
    fifo_bytes: Optional[int] = None
    scratch_bytes: Optional[int] = None
    total_bytes: Optional[int] = None
    latency_s: Optional[float] = None
    max_batch: int = 8

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        for f in fields(self):
            if f.name == "max_batch":
                continue
            v = getattr(self, f.name)
            if v is not None and float(v) <= 0:
                raise ValueError(f"budget ceiling {f.name} must be positive, "
                                 f"got {v}")

    def check(self, metrics: Dict[str, float]) -> Dict[str, tuple]:
        """Violated ceilings for one candidate's metric dict: ``{term:
        (value, ceiling)}`` — empty means the candidate is feasible.  The
        ``latency_s`` ceiling is checked against ``predicted_latency_s``."""
        out: Dict[str, tuple] = {}
        pairs = [("weight_bytes", metrics.get("weight_bytes")),
                 ("fifo_bytes", metrics.get("fifo_bytes")),
                 ("scratch_bytes", metrics.get("scratch_bytes")),
                 ("total_bytes", metrics.get("total_bytes")),
                 ("latency_s", metrics.get("predicted_latency_s"))]
        for term, value in pairs:
            ceiling = getattr(self, term)
            if ceiling is not None and value is not None and value > ceiling:
                out[term] = (value, ceiling)
        return out

    def violations_str(self, violations: Dict[str, tuple]) -> str:
        return "; ".join(f"{t}={v:g} > ceiling {c:g}"
                         for t, (v, c) in sorted(violations.items()))

    def to_dict(self) -> Dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @classmethod
    def from_dict(cls, d: Dict) -> "ResourceBudget":
        names = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"unknown budget terms {unknown}; "
                             f"valid: {sorted(names)}")
        return cls(**d)

    @property
    def constrained(self) -> bool:
        return any(getattr(self, f.name) is not None for f in fields(self)
                   if f.name != "max_batch")

    def describe(self) -> List[str]:
        return [f"{f.name}<={getattr(self, f.name):g}" for f in fields(self)
                if f.name != "max_batch" and getattr(self, f.name) is not None]
