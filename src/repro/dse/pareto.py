"""Pareto points and the serializable front the runtime ladder walks.

A :class:`ParetoPoint` is one costed-and-validated working point: the
runtime rung (a :class:`~repro.core.adaptive.WorkingPoint`) plus the byte /
latency / accuracy metrics the explorer derived for it.  Dominance is over
the three minimized objectives ``(total_bytes, latency, -agreement)``;
:func:`prune_dominated` is deterministic (stable order, strict dominance).

A :class:`ParetoFront` bundles the surviving points with the *compile-time*
configuration they share — activation code bits, FIFO slack, per-layer
weight-bit caps, the batch-bucket ladder, and the budget they were screened
against — because every point on one front must be servable from ONE
packed-weight writer (the paper's zero-reload precision switch).  It
round-trips through JSON (``save``/``load``) and plugs into the runtime
directly: ``working_points()`` feeds ``shared_point_executables`` /
``serve_adaptive(points=front)``, ``selector(slo=...)`` builds the
:class:`~repro.core.adaptive.PointSelector` that walks it.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.adaptive import (BudgetSelector, PointSelector,
                                 ServiceObjective, SLOController,
                                 WorkingPoint)
from repro.dse.budget import ResourceBudget
from repro.quant.qtypes import DatatypeConfig, PrecisionMap

# bump on any front-layout change; `load` refuses mismatched files rather
# than mis-reading them
FRONT_SCHEMA = 1


class FrontFormatError(ValueError):
    """Typed deserialization failure: a front file carried wrong-typed,
    non-finite, or negative metric fields.  Raised instead of letting
    corrupted bytes/latency values propagate into ``run_kwargs()`` and
    runtime block picks — a bit-flipped cache file must fail loudly."""


def _req_int(d: Dict, key: str, *, minimum: int = 0) -> int:
    """A required non-negative integral field (bool is NOT an int here)."""
    v = d.get(key)
    if isinstance(v, bool) or not isinstance(v, (int, float)) \
            or not math.isfinite(v) or int(v) != v or int(v) < minimum:
        raise FrontFormatError(
            f"field {key!r} must be an integer >= {minimum}, got {v!r}")
    return int(v)


def _req_float(d: Dict, key: str, *, minimum: float = 0.0,
               required: bool = True) -> Optional[float]:
    """A finite non-negative float field (None allowed when optional)."""
    v = d.get(key)
    if v is None and not required:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)) \
            or not math.isfinite(v) or v < minimum:
        raise FrontFormatError(
            f"field {key!r} must be a finite number >= {minimum}, got {v!r}")
    return float(v)


@dataclass(frozen=True)
class ParetoPoint:
    """One working point with the metrics the explorer screened it on."""

    point: WorkingPoint
    weight_bytes: int            # PackedWeights.view_bytes(bits, caps)
    fifo_bytes: int              # stream topology total_fifo_bytes
    scratch_bytes: int           # im2col patch traffic at the max bucket
    predicted_latency_s: float   # roofline max(compute, memory) term
    agreement: float             # top-1 agreement vs the float reference
    measured_latency_s: Optional[float] = None   # LatencyEWMA, when warm

    @property
    def total_bytes(self) -> int:
        return self.weight_bytes + self.fifo_bytes + self.scratch_bytes

    @property
    def latency_s(self) -> float:
        """The latency objective: measured when available, else predicted."""
        return (self.measured_latency_s if self.measured_latency_s is not None
                else self.predicted_latency_s)

    def objectives(self) -> Tuple[float, float, float]:
        """Minimized objective vector."""
        return (float(self.total_bytes), self.latency_s, -self.agreement)

    def dominates(self, other: "ParetoPoint") -> bool:
        """Strict Pareto dominance: no worse in every objective, strictly
        better in at least one."""
        a, b = self.objectives(), other.objectives()
        return all(x <= y for x, y in zip(a, b)) and a != b

    def metrics(self) -> Dict[str, float]:
        return {
            "weight_bytes": self.weight_bytes,
            "fifo_bytes": self.fifo_bytes,
            "scratch_bytes": self.scratch_bytes,
            "total_bytes": self.total_bytes,
            "predicted_latency_s": self.predicted_latency_s,
            "measured_latency_s": self.measured_latency_s,
            "agreement": self.agreement,
        }

    def to_dict(self) -> Dict:
        return {
            "name": self.point.name,
            "weight_bits": self.point.weight_bits,
            "act_dtype": self.point.act_dtype,
            "act_bits": self.point.act_bits,
            **self.metrics(),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ParetoPoint":
        """Build from a JSON dict, rejecting corrupted metric fields
        (non-finite, negative, or wrong-typed) with a typed
        :class:`FrontFormatError` — garbage here would otherwise steer
        ``run_kwargs()`` and runtime ladder picks silently."""
        if not isinstance(d, dict):
            raise FrontFormatError(f"point entry must be a dict, got "
                                   f"{type(d).__name__}")
        name = d.get("name")
        if not isinstance(name, str) or not name:
            raise FrontFormatError(f"field 'name' must be a non-empty "
                                   f"string, got {name!r}")
        wp = WorkingPoint(name, _req_int(d, "weight_bits", minimum=1),
                          d.get("act_dtype", "bfloat16"),
                          d.get("act_bits"))
        return cls(wp,
                   weight_bytes=_req_int(d, "weight_bytes"),
                   fifo_bytes=_req_int(d, "fifo_bytes"),
                   scratch_bytes=_req_int(d, "scratch_bytes"),
                   predicted_latency_s=_req_float(d, "predicted_latency_s"),
                   agreement=_req_float(d, "agreement"),
                   measured_latency_s=_req_float(d, "measured_latency_s",
                                                 required=False))


def prune_dominated(points: Sequence[ParetoPoint]) -> List[ParetoPoint]:
    """Drop every strictly dominated point, preserving input order.

    Deterministic: dominance is strict, so objective-identical duplicates
    all survive (the explorer never emits duplicates, but property tests
    feed arbitrary sets)."""
    pts = list(points)
    return [p for p in pts
            if not any(q.dominates(p) for q in pts if q is not p)]


@dataclass
class ParetoFront:
    """The explorer's output: non-dominated points + their shared compile
    configuration, ordered highest precision first (the ladder an
    :class:`~repro.core.adaptive.SLOController` walks down under load)."""

    graph_name: str
    points: List[ParetoPoint]
    act_bits: int = 8                     # activation code bits (compile axis)
    fifo_slack: float = 1.0               # stream FIFO headroom (compile axis)
    per_layer_bits: Dict[str, int] = field(default_factory=dict)  # weight caps
    buckets: Tuple[int, ...] = ()         # batch-bucket ladder candidates cost
    budget: Optional[ResourceBudget] = None
    tuned_tilings: int = 0                # autotune-cache hits at explore time
    schema: int = FRONT_SCHEMA

    def __post_init__(self):
        self.points = sorted(self.points,
                             key=lambda p: -p.point.weight_bits)

    def __len__(self) -> int:
        return len(self.points)

    # -- runtime plumbing ----------------------------------------------------
    def working_points(self) -> List[WorkingPoint]:
        """The ladder ``shared_point_executables`` / ``serve_adaptive``
        consume (highest precision first)."""
        return [p.point for p in self.points]

    def precision_map(self) -> PrecisionMap:
        """The per-layer precision annotation realizing this front's caps:
        the runtime rung is further clamped per node by
        ``QJaxContext.weight_bits`` (a W4-capped layer stays W4 at the W8
        point)."""
        default = DatatypeConfig(self.act_bits, 8)
        return PrecisionMap(default,
                            {n: DatatypeConfig(self.act_bits, b)
                             for n, b in sorted(self.per_layer_bits.items())})

    def run_kwargs(self) -> Dict:
        """Keyword arguments reproducing this front's compile configuration
        through ``DesignFlow.run`` (the one documented ONNX -> constrained
        points -> server path)."""
        return {"dtconfig": self.precision_map(),
                "fifo_slack": self.fifo_slack}

    def selector(self, slo: Optional[ServiceObjective] = None
                 ) -> PointSelector:
        """A :class:`~repro.core.adaptive.PointSelector` over this front:
        closed-loop (:class:`SLOController`) when an ``slo`` is given, else
        the open-loop :class:`BudgetSelector`."""
        pts = self.working_points()
        if slo is not None:
            return SLOController(pts, slo)
        return BudgetSelector(pts)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "schema": self.schema,
            "graph": self.graph_name,
            "act_bits": self.act_bits,
            "fifo_slack": self.fifo_slack,
            "per_layer_bits": dict(sorted(self.per_layer_bits.items())),
            "buckets": list(self.buckets),
            "budget": self.budget.to_dict() if self.budget else None,
            "tuned_tilings": self.tuned_tilings,
            "points": [p.to_dict() for p in self.points],
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict) -> "ParetoFront":
        if d.get("schema") != FRONT_SCHEMA:
            raise ValueError(
                f"ParetoFront schema mismatch: file has {d.get('schema')!r}, "
                f"this build reads {FRONT_SCHEMA} — re-run the explorer")
        budget = (ResourceBudget.from_dict(d["budget"])
                  if d.get("budget") else None)
        pts = d.get("points")
        if not isinstance(pts, list):
            raise FrontFormatError(
                f"field 'points' must be a list, got {type(pts).__name__}")
        return cls(graph_name=d["graph"],
                   points=[ParetoPoint.from_dict(p) for p in pts],
                   act_bits=int(d.get("act_bits", 8)),
                   fifo_slack=float(d.get("fifo_slack", 1.0)),
                   per_layer_bits={k: int(v) for k, v in
                                   d.get("per_layer_bits", {}).items()},
                   buckets=tuple(int(b) for b in d.get("buckets", ())),
                   budget=budget,
                   tuned_tilings=int(d.get("tuned_tilings", 0)))

    @classmethod
    def from_json(cls, text: str) -> "ParetoFront":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ParetoFront":
        with open(path) as f:
            return cls.from_json(f.read())
