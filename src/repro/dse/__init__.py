"""Resource-constrained design-space exploration.

``DesignSpaceExplorer`` screens candidate working points analytically
against a ``ResourceBudget`` (roofline bytes/FLOPs, stream FIFO bytes,
im2col scratch, predicted latency), validates the survivors on the
calibration set, and emits a serializable ``ParetoFront`` the serving
runtime walks directly — see ``DesignFlow.explore`` for the one-call entry
point and ``FlowResult.serve_adaptive(points=front)`` for consumption.
"""
from repro.dse.budget import BudgetInfeasibleError, ResourceBudget
from repro.dse.explorer import DesignSpaceExplorer, scratch_bytes_for
from repro.dse.pareto import (FRONT_SCHEMA, ParetoFront, ParetoPoint,
                              prune_dominated)

__all__ = [
    "BudgetInfeasibleError", "DesignSpaceExplorer", "FRONT_SCHEMA",
    "ParetoFront", "ParetoPoint", "ResourceBudget", "prune_dominated",
    "scratch_bytes_for",
]
