"""Resource-constrained design-space explorer.

FPGA-HART-style constrained DSE over the flow's working points, in two
stages:

1. **Analytical screen** (cheap, no model execution): every candidate
   configuration — activation code bits x FIFO slack x per-layer weight-bit
   caps x runtime rung — is costed in the roofline model's already-measurable
   terms (``PackedWeights.view_bytes`` with caps, stream-topology
   ``total_fifo_bytes``, im2col scratch bytes at the largest batch bucket,
   ``predict_latency_s`` over the graph's MAC count) and checked against the
   :class:`~repro.dse.budget.ResourceBudget`.  Infeasible rungs are dropped
   here, before anything runs.
2. **Accuracy check on survivors**: the surviving rungs of the selected
   compile configuration execute the calibration batch through the packed
   qjax path and are scored by top-1 agreement with the float reference.

Dominated points are pruned and the result is a serializable
:class:`~repro.dse.pareto.ParetoFront` the serving runtime consumes
directly — the :class:`~repro.core.adaptive.SLOController` then walks a
front computed for THIS graph under THIS resource ceiling instead of the
hardcoded W8/W4/W2 ladder.

The two kinds of search axes are deliberately factored:

* **runtime axes** (the rung ladder, default W8/W4/W2) become points of the
  front — all servable from ONE packed writer with zero weight reload;
* **compile axes** (act bits, FIFO slack, per-layer caps) are shared by the
  whole front; candidates are enumerated and the best feasible one is
  chosen deterministically (most feasible rungs, then largest FIFO slack —
  headroom is free when it fits — then highest act precision, then fewest
  bytes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.adaptive import WorkingPoint
from repro.core.passes import (PassManager, make_assign_precision,
                               quantizable_layers, structural_pipeline)
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.qjax_writer import QJaxWriter
from repro.core.writers.stream_writer import StreamWriter
from repro.dse.budget import BudgetInfeasibleError, ResourceBudget
from repro.dse.pareto import ParetoFront, ParetoPoint, prune_dominated
from repro.kernels.autotune import tuned_entries
from repro.launch.roofline import (graph_mac_count, im2col_scratch_bytes,
                                   predict_latency_s)
from repro.quant.pack import PackedWeights
from repro.quant.ptq import top1_agreement
from repro.quant.qtypes import DatatypeConfig, PrecisionMap
from repro.runtime.scheduler import LatencyEWMA, _pow2_ladder

_DW_OPS = ("DepthwiseConv", "FusedDepthwiseConv")


def scratch_bytes_for(graph, *, batch: int, act_bytes: int,
                      dw_mode: str = "direct") -> int:
    """The im2col scratch term of one candidate: patch-tensor bytes at the
    largest batch bucket.  With the direct depthwise kernels
    (``dw_mode="direct"``, the default engine path) depthwise convs read the
    padded activation in place, so only regular convs materialize patches."""
    per_node = im2col_scratch_bytes(graph, batch=batch, act_bytes=act_bytes)
    if dw_mode != "direct":
        return per_node["_total"]
    ops = {n.name: n.op for n in graph.nodes}
    return sum(v for k, v in per_node.items()
               if k != "_total" and ops.get(k) not in _DW_OPS)


@dataclass
class _Candidate:
    """One compile configuration with its screened rungs."""
    act_bits: int
    fifo_slack: float
    caps: Dict[str, int]
    graph: object                      # precision-annotated graph
    pm: PrecisionMap
    fifo_bytes: int
    feasible: List[Tuple[int, Dict]] = field(default_factory=list)
    violations: Dict[int, Dict] = field(default_factory=dict)

    def sort_key(self):
        best = min((m["total_bytes"] for _, m in self.feasible),
                   default=float("inf"))
        return (-len(self.feasible), -self.fifo_slack, -self.act_bits, best)


class DesignSpaceExplorer:
    """Joint search over per-layer weight bits, activation bits, FIFO slack
    and the batch-bucket ladder under a :class:`ResourceBudget`.

    ``ladder`` is the runtime rung ladder (uniform view bits, highest
    first); ``act_bits_choices`` / ``fifo_slack_choices`` the compile axes;
    ``per_layer`` enables the sensitivity sweep assigning sub-rung weight
    caps to layers that tolerate them (``layer_tol`` top-1 agreement loss);
    ``latency`` optionally feeds the measured term from a serving tenant's
    :class:`~repro.runtime.scheduler.LatencyEWMA`."""

    def __init__(self, graph, calib_inputs: tuple, *,
                 budget: Optional[ResourceBudget] = None,
                 ladder: Sequence[int] = (8, 4, 2),
                 act_bits_choices: Sequence[int] = (8,),
                 fifo_slack_choices: Sequence[float] = (2.0, 1.0),
                 per_layer: bool = True,
                 layer_tol: float = 0.02,
                 dw_mode: str = "direct",
                 latency: Optional[LatencyEWMA] = None):
        if not ladder:
            raise ValueError("ladder must name at least one rung")
        self.graph = PassManager(structural_pipeline()).run(graph)
        self.calib_inputs = calib_inputs
        self.budget = budget or ResourceBudget()
        self.ladder = tuple(sorted({int(b) for b in ladder}, reverse=True))
        self.act_bits_choices = tuple(sorted({int(a) for a in act_bits_choices},
                                             reverse=True))
        self.fifo_slack_choices = tuple(sorted({float(s) for s in
                                                fifo_slack_choices},
                                               reverse=True))
        self.per_layer = per_layer
        self.layer_tol = float(layer_tol)
        self.dw_mode = dw_mode
        self.latency = latency
        # shared substrate: quantize ONCE; every candidate is a view of it
        self.packed = PackedWeights.from_initializers(self.graph.initializers)
        # float reference + calibrated activation ranges, one capture
        ref_logits, env = JaxWriter(self.graph).build(capture=True)(
            *calib_inputs)
        self.ref_logits = ref_logits
        self.act_ranges = {
            k: float(jnp.max(jnp.abs(v))) for k, v in env.items()
            if hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.floating)}
        self.buckets = _pow2_ladder(self.budget.max_batch)

    # -- accuracy oracle -----------------------------------------------------
    def _agreement(self, pm: PrecisionMap, graph, bits: int) -> float:
        """Top-1 agreement of the packed qjax path at one rung vs the float
        reference (ref backend: deterministic on any host)."""
        w = QJaxWriter(graph, pm.default, self.act_ranges, use_kernel=False)
        return top1_agreement(w.build(bits=bits)(*self.calib_inputs),
                              self.ref_logits)

    # -- per-layer sensitivity sweep ----------------------------------------
    def layer_caps(self) -> Dict[str, int]:
        """Per-layer weight-bit caps: the lowest sub-rung each weighted layer
        tolerates alone (others at the top rung) within ``layer_tol``
        agreement.  Realized at runtime through ``QJaxContext.weight_bits``
        — a capped layer streams its cap even at the W8 point, shrinking
        every rung's weight bytes (the NN2CAM per-layer mapping, searched
        per layer instead of per partition)."""
        if not self.per_layer or len(self.ladder) < 2:
            return {}
        act = self.act_bits_choices[0]
        caps: Dict[str, int] = {}
        for n in quantizable_layers(self.graph):
            for b in sorted(self.ladder[1:]):        # most aggressive first
                pm = PrecisionMap(DatatypeConfig(act, self.ladder[0]),
                                  {n.name: DatatypeConfig(act, b)})
                ga = make_assign_precision(pm)(self.graph)
                if self._agreement(pm, ga, self.ladder[0]) \
                        >= 1.0 - self.layer_tol:
                    caps[n.name] = b
                    break
        return caps

    # -- analytical screen ---------------------------------------------------
    def _screen(self, caps: Dict[str, int]) -> List[_Candidate]:
        macs = graph_mac_count(self.graph, batch=self.buckets[-1])["_total"]
        flops = 2.0 * macs
        cands: List[_Candidate] = []
        for a in self.act_bits_choices:
            pm = PrecisionMap(DatatypeConfig(a, self.ladder[0]),
                              {name: DatatypeConfig(a, b)
                               for name, b in sorted(caps.items())})
            ga = make_assign_precision(pm)(self.graph)
            act_bytes = 1 if a <= 8 else 4
            scratch = scratch_bytes_for(ga, batch=self.buckets[-1],
                                        act_bytes=act_bytes,
                                        dw_mode=self.dw_mode)
            for s in self.fifo_slack_choices:
                sw = StreamWriter(ga, pm.default, self.act_ranges,
                                  fifo_slack=s)
                fifo = int(sw.topology()["total_fifo_bytes"])
                cand = _Candidate(a, s, dict(caps), ga, pm, fifo)
                for b in self.ladder:
                    wb = int(self.packed.view_bytes(b, caps=caps))
                    metrics = {
                        "weight_bytes": wb,
                        "fifo_bytes": fifo,
                        "scratch_bytes": scratch,
                        "total_bytes": wb + fifo + scratch,
                        "predicted_latency_s": predict_latency_s(
                            flops, wb + scratch),
                    }
                    bad = self.budget.check(metrics)
                    if bad:
                        cand.violations[b] = bad
                    else:
                        cand.feasible.append((b, metrics))
                cands.append(cand)
        return cands

    # -- the full pipeline ---------------------------------------------------
    def explore(self) -> ParetoFront:
        caps = self.layer_caps()
        cands = self._screen(caps)
        best = min(cands, key=_Candidate.sort_key)
        if not best.feasible:
            # every rung of every configuration missed a ceiling: report the
            # closest rung (fewest bytes) of the closest configuration
            rung = self.ladder[-1]
            bad = best.violations.get(rung, {})
            raise BudgetInfeasibleError(
                f"no working point of {self.graph.name!r} fits the budget "
                f"({', '.join(self.budget.describe()) or 'unconstrained'}); "
                f"closest candidate (W{rung}, act={best.act_bits}, "
                f"fifo_slack={best.fifo_slack:g}) violates: "
                f"{self.budget.violations_str(bad)}",
                violations=bad)
        measured = (self.latency.estimate(self.buckets[-1])
                    if self.latency is not None else None)
        pts = []
        for b, metrics in best.feasible:
            agree = self._agreement(best.pm, best.graph, b)
            pts.append(ParetoPoint(
                WorkingPoint(f"w{b}", b, act_bits=best.act_bits),
                weight_bytes=metrics["weight_bytes"],
                fifo_bytes=metrics["fifo_bytes"],
                scratch_bytes=metrics["scratch_bytes"],
                predicted_latency_s=metrics["predicted_latency_s"],
                agreement=agree,
                measured_latency_s=measured))
        return ParetoFront(
            graph_name=self.graph.name,
            points=prune_dominated(pts),
            act_bits=best.act_bits,
            fifo_slack=best.fifo_slack,
            per_layer_bits=dict(best.caps),
            buckets=self.buckets,
            budget=self.budget if self.budget.constrained else None,
            tuned_tilings=len(tuned_entries()))
