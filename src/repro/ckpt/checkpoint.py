"""Sharded, async, elastic checkpointing (np-based; orbax unavailable offline).

Design (scales to multi-host; degenerates gracefully on 1 process):
  * every array is saved full-size from host RAM (``jax.device_get`` gathers
    shards); on a multi-host deployment each host would write only the shards
    it owns (addressable_shards) into the same layout — the manifest format
    already records per-array shape/dtype so either producer works;
  * *elastic restore*: arrays are re-``device_put`` against whatever mesh /
    sharding the restoring job provides — checkpoints written on N chips
    restore on M (tested in tests/test_checkpoint.py);
  * *async*: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes to disk on a daemon thread, so the train loop is not blocked;
  * atomicity: writes go to ``<dir>.tmp`` then ``os.replace`` -> a crash
    mid-save never corrupts the latest good checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}|"))
        return out
    return {prefix[:-1]: tree}


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("|")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(tree: Dict[str, Any], directory: str, step: int,
         extra: Optional[Dict] = None) -> str:
    """Synchronous checkpoint write.  Returns the checkpoint path."""
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    return _write(host, directory, step, extra)


def _storage_view(v: np.ndarray):
    """np.save can't round-trip ml_dtypes (bfloat16 etc.): store a same-width
    unsigned view and record the logical dtype in the manifest."""
    if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return v.view({1: np.uint8, 2: np.uint16}[v.dtype.itemsize])
    return v


def _logical_view(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) != dtype_str:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def _write(host: Dict[str, np.ndarray], directory: str, step: int,
           extra: Optional[Dict]) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "arrays": {}}
    for k, v in host.items():
        fname = k.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), _storage_view(v))
        manifest["arrays"][k] = {"file": fname, "shape": list(v.shape),
                                 "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


class AsyncCheckpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree: Dict[str, Any], step: int,
             extra: Optional[Dict] = None) -> None:
        self.wait()  # one in-flight save at a time
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def work():
            _write(host, self.directory, step, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(list_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None):
    """Load a checkpoint; optionally re-place arrays onto new shardings
    (elastic re-mesh).  Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_sh = _flatten(shardings) if shardings else {}
    flat = {}
    for k, meta in manifest["arrays"].items():
        arr = _logical_view(np.load(os.path.join(path, meta["file"])),
                            meta["dtype"])
        if k in flat_sh and flat_sh[k] is not None:
            flat[k] = jax.device_put(arr, flat_sh[k])
        else:
            flat[k] = arr
    return _unflatten(flat), manifest["step"], manifest["extra"]
