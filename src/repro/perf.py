"""Performance flags (§Perf hillclimb knobs).

Defaults are the OPTIMIZED configuration; ``--baseline`` in launch/dryrun.py
restores the paper-faithful first-cut behavior so both rows of EXPERIMENTS.md
§Perf stay reproducible from the same tree.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class PerfFlags:
    # H1 (collective): constrain q/k/v to head-sharded layouts so GSPMD never
    # splits the d_head contraction (which all-reduces full score tensors)
    attn_head_constraint: bool = True
    # H2 (memory): intra-chunk SSD math in bf16 (states stay f32)
    ssd_bf16_intra: bool = True
    # H2b (memory): constrain SSD inner activations to model-sharded layouts
    ssd_constraint: bool = True
    # H3 (memory): GQA attention without materializing repeated kv heads
    gqa_grouped: bool = True
    # H4 (memory): sliding-window prefill computes only the key band
    swa_banded: bool = True
    # H5 (memory): keep attention score tensors in bf16 when activations are
    # bf16 (softmax max-subtraction keeps this stable at inference precision)
    attn_bf16_scores: bool = True


FLAGS = PerfFlags()


def set_baseline() -> None:
    global FLAGS
    FLAGS = PerfFlags(attn_head_constraint=False, ssd_bf16_intra=False,
                      ssd_constraint=False, gqa_grouped=False,
                      swa_banded=False, attn_bf16_scores=False)
