"""jit'd wrapper matching the ``repro.models.ssm.ssd_chunked`` signature."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import build_call
from repro.models.ssm import ssd_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, A, Bm, C, D, chunk: int, init_state=None,
                       interpret: bool = True):
    """Same contract as ``ssd_chunked``: x (B,S,H,P), dt (B,S,H) f32, A (H,),
    Bm/C (B,S,G,N), D (H,) -> (y (B,S,H,P), state (B,H,P,N))."""
    if init_state is not None:
        # kernel carries state from zero; warm starts go through the oracle
        return ssd_chunked(x, dt, A, Bm, C, D, chunk, init_state)
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtk = dt.transpose(0, 2, 1).reshape(B * H, S).astype(jnp.float32)
    Bk = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Ck = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Ak = jnp.tile(A.reshape(1, H), (B, 1)).reshape(B * H, 1).astype(jnp.float32)
    Dk = jnp.tile(D.reshape(1, H), (B, 1)).reshape(B * H, 1).astype(jnp.float32)
    call = build_call(B * H, S, P, N, chunk, dtype=x.dtype, interpret=interpret)
    y, fin = call(xk, dtk, Bk.astype(x.dtype), Ck.astype(x.dtype), Ak, Dk)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    state = fin.reshape(B, H, N, P).transpose(0, 1, 3, 2)
    return y, state
