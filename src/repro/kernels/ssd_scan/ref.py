"""Oracle for the fused SSD kernel = the models' chunked implementation."""
from repro.models.ssm import ssd_chunked as ssd_ref  # noqa: F401
