"""Pallas TPU kernel: fused Mamba-2 SSD chunk scan.

Grid = (B*H, n_chunks).  The TPU grid executes *sequentially*, so the running
(N, P) state lives in a VMEM scratch that carries across the chunk dim — the
inter-chunk recurrence costs no HBM round-trips (vs. the jnp reference, which
materializes per-chunk states through a lax.scan).  Per chunk the intra part
is two MXU matmuls: ``C B^T`` (Q,Q) and ``att @ x`` (Q,P), plus the state
in/out products.  All math f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, fin_ref,
               state_ref, *, nc: int, chunk: int):
    """Blocks per (bh, c) step:
      x: (1, Q, P), dt: (1, Q), b/c: (1, Q, N), a/d: (1, 1) scalar params,
      y: (1, Q, P) out, fin: (1, N, P) final-state out, state: (N, P) scratch.
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    Q = chunk
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    A = a_ref[0, 0].astype(jnp.float32)
    D = d_ref[0, 0].astype(jnp.float32)

    dA = dt * A                               # (Q,) decays (<= 0)
    ld = jnp.cumsum(dA)                       # cumulative log decay
    l_last = ld[Q - 1]

    # intra-chunk: att[i,j] = (C_i.B_j) * exp(l_i - l_j) * dt_j for j <= i
    li = ld[:, None]
    lj = ld[None, :]
    decay = jnp.exp(jnp.minimum(li - lj, 0.0))
    cb = jax.lax.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jota = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    att = jnp.where(jota <= iota, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot(att, x, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += C_i . (exp(l_i) * state_prev)
    y += jax.lax.dot(Cm * jnp.exp(ld)[:, None], state_ref[...],
                     preferred_element_type=jnp.float32)

    # state update: S <- S*exp(l_last) + sum_j exp(l_last-l_j) dt_j B_j x_j^T
    wj = jnp.exp(l_last - ld) * dt            # (Q,)
    s_new = jax.lax.dot((Bm * wj[:, None]).T, x,
                        preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = state_ref[...] * jnp.exp(l_last) + s_new

    y_ref[0] = (y + x * D).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        fin_ref[0] = state_ref[...].astype(fin_ref.dtype)


def build_call(BH: int, S: int, P: int, N: int, chunk: int,
               dtype=jnp.float32, interpret: bool = False):
    assert S % chunk == 0
    nc = S // chunk
    return pl.pallas_call(
        functools.partial(ssd_kernel, nc=nc, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )
