"""jit'd wrapper: SAME-padded stride-1 conv through the line-buffer kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_stream.kernel import build_call
from repro.kernels.conv2d_stream.ref import conv2d_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def conv2d_stream(x, w, b, *, interpret: bool = True, use_kernel: bool = True):
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,) — SAME, stride 1."""
    if not use_kernel:
        return conv2d_ref(x, w, b)
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    call = build_call(B, H, W, Cin, Cout, kh, kw, out_dtype=x.dtype,
                      interpret=interpret)
    return call(xp, w, b.reshape(1, -1))
