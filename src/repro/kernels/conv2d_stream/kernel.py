"""Pallas TPU kernel: streaming line-buffer convolution (paper Fig. 2).

TPU adaptation of the HLS CONV-actor template (DESIGN.md §2):

* *Line Buffer actor*  -> the padded input rows of one image live in VMEM and
  are re-read kh*kw times (data reuse without re-touching HBM);
* *Conv actor*         -> each (dy, dx) tap is an MXU matmul
  ``(H*W, Cin) @ (Cin, Cout)`` accumulated in f32;
* *Weight/Bias actors* -> the full filter bank + bias stay VMEM-resident
  across the whole grid (BlockSpec index_map pins them).

Grid = (B,) — one image per step, streamed HBM->VMEM once.  Suited to
edge-CNN images (the paper's scope); dims need no 128 alignment because the
matmul M dim is H*W (lane packing handled by Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int):
    """x: (1, H+kh-1, W+kw-1, Cin) padded; w: (kh, kw, Cin, Cout); b: (1, Cout);
    o: (1, H, W, Cout)."""
    _, Hp, Wp, Cin = x_ref.shape
    H = Hp - (kh - 1)
    W = Wp - (kw - 1)
    Cout = o_ref.shape[-1]
    x = x_ref[0]                                  # VMEM-resident line buffer
    acc = jnp.zeros((H * W, Cout), jnp.float32)
    for dy in range(kh):                          # kh*kw MXU taps, VMEM reuse
        for dx in range(kw):
            patch = jax.lax.slice(x, (dy, dx, 0), (dy + H, dx + W, Cin))
            acc += jax.lax.dot(
                patch.reshape(H * W, Cin).astype(jnp.float32),
                w_ref[dy, dx].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc += b_ref[0].astype(jnp.float32)
    o_ref[0] = acc.reshape(H, W, Cout).astype(o_ref.dtype)


def build_call(B: int, H: int, W: int, Cin: int, Cout: int, kh: int, kw: int,
               out_dtype=jnp.float32, interpret: bool = False):
    Hp, Wp = H + kh - 1, W + kw - 1
    return pl.pallas_call(
        functools.partial(conv_kernel, kh=kh, kw=kw),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cin), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, Cin, Cout), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((1, Cout), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, W, Cout), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cout), out_dtype),
        interpret=interpret,
    )
