"""Pure-jnp oracle for the streaming line-buffer convolution."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b, stride: int = 1):
    """x: (B, H, W, Cin) float; w: (kh, kw, Cin, Cout); b: (Cout,).

    SAME padding, NHWC/HWIO — matches repro.models.cnn.conv2d."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return (y + b.astype(jnp.float32)).astype(x.dtype)
