"""Persistent block-size autotune cache shared by the Pallas kernel families.

Timed tiling picks are two-level cached: each kernel family keeps its own
in-process L1 dict, and compiled-backend timings persist here to ONE JSON
file (``~/.cache/repro/autotune.json``, override with
``REPRO_AUTOTUNE_CACHE=<path>``, disable with ``REPRO_AUTOTUNE_CACHE=off``)
so tuning survives across processes.  Keys are family-prefixed strings
(``"512:384:..."`` for qmatmul, ``"dw:..."`` for the depthwise conv kernels)
and values are integer block tuples of *family-specific arity*.

The file carries an explicit schema version::

    {"schema": 2, "entries": {"<key>": [<blocks...>], ...}}

Any file whose schema does not match :data:`CACHE_SCHEMA` — including the
pre-versioned flat ``{key: blocks}`` format older releases wrote — is treated
as empty, so stale caches *retune* instead of silently returning block tuples
of the wrong arity to a newer kernel.  Bump :data:`CACHE_SCHEMA` whenever a
key format or tuple arity changes.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

# bump on any key-format or block-tuple-arity change; mismatched (or
# pre-versioned) files are discarded and retuned
CACHE_SCHEMA = 2

AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


class CacheFormatError(ValueError):
    """Typed validation failure for autotune-cache entries: block tuples
    must be non-empty sequences of positive integers (bool is not an int
    here, and floats/NaN/negatives are rejected) — a corrupted block pick
    would otherwise propagate straight into Pallas grid shapes."""


def _valid_blocks(v: object) -> Tuple[int, ...]:
    """Validate one cache value; raises :class:`CacheFormatError`."""
    if not isinstance(v, (list, tuple)) or len(v) < 1:
        raise CacheFormatError(
            f"cache entry must be a non-empty block list, got {v!r}")
    blocks = []
    for b in v:
        if isinstance(b, bool) or not isinstance(b, int) or b <= 0:
            raise CacheFormatError(
                f"block sizes must be positive integers, got {b!r} in {v!r}")
        blocks.append(int(b))
    return tuple(blocks)

# loaded disk state: {"path": resolved path or None, "data": {key: blocks}};
# re-resolved when the env var changes (tests point it at tmp dirs).  The
# dict OBJECT is shared by identity with the per-family ops modules.
_disk_state: Dict[str, object] = {"path": False, "data": {}}


def autotune_cache_path() -> Optional[str]:
    """Resolved disk-cache path, or None when persistence is disabled."""
    p = os.environ.get(AUTOTUNE_CACHE_ENV)
    if p is None:
        return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "autotune.json")
    p = p.strip()
    if p.lower() in ("", "0", "off", "none"):
        return None
    return os.path.expanduser(p)


def disk_cache() -> Dict[str, Tuple[int, ...]]:
    """The persisted ``{key: blocks}`` map (empty when disabled, corrupt, or
    written under a different :data:`CACHE_SCHEMA`)."""
    path = autotune_cache_path()
    if _disk_state["path"] != path:
        data: Dict[str, Tuple[int, ...]] = {}
        if path is not None and os.path.exists(path):
            try:
                with open(path) as f:
                    raw = json.load(f)
                # schema gate: flat pre-versioned files and future formats
                # both load as empty -> retune rather than mis-shape blocks
                if isinstance(raw, dict) and raw.get("schema") == CACHE_SCHEMA:
                    entries = raw.get("entries", {})
                    if not isinstance(entries, dict):
                        raise CacheFormatError(
                            f"'entries' must be a dict, got "
                            f"{type(entries).__name__}")
                    for k, v in entries.items():
                        # per-entry validation: one corrupted pick retunes
                        # that key; the rest of the cache stays usable
                        try:
                            data[str(k)] = _valid_blocks(v)
                        except CacheFormatError:
                            continue
            except (OSError, ValueError, TypeError):
                data = {}   # corrupt/unreadable cache: retune, then rewrite
        _disk_state["path"] = path
        _disk_state["data"] = data
    return _disk_state["data"]  # type: ignore[return-value]


def tuned_entries(prefix: str = "") -> Dict[str, Tuple[int, ...]]:
    """Snapshot of the persisted tuned tilings whose key starts with
    ``prefix`` (``""`` = all families; ``"dw:"`` = the depthwise kernels).

    The DSE reads this to report which candidate shapes already carry a
    *timed* block pick — a tuned tiling means the measured-latency term for
    that shape is grounded in a real kernel timing rather than the static
    heuristic."""
    return {k: tuple(v) for k, v in disk_cache().items()
            if k.startswith(prefix)}


def disk_put(key: str, blocks: Tuple[int, ...]) -> None:
    """Write-through one timed result (no-op when persistence is off)."""
    path = autotune_cache_path()
    if path is None:
        return
    data = disk_cache()
    # strict on the write side: persisting a garbage pick poisons every
    # later process, so it fails loudly (typed) instead of best-effort
    data[key] = _valid_blocks(blocks)
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"schema": CACHE_SCHEMA,
                       "entries": {k: list(v) for k, v in sorted(data.items())}},
                      f, indent=1)
        os.replace(tmp, path)   # atomic: concurrent tuners never see partials
    except OSError:
        pass                    # telemetry-grade persistence: never fail a call
