"""Pure-jnp oracle for the direct depthwise conv kernels.

THE bit-exactness contract of :mod:`repro.kernels.qconv_dw.kernel` lives
here: the references accumulate the ``kh * kw`` shifted-window products in
exactly the kernel's order (dy-major, then dx) over the *code domain* and
apply the per-channel scale once at the end — the same operation sequence the
Pallas kernel traces in-VMEM.  On the fully-integer path (int8 activation
codes, int32 MACs, power-of-two scale folds — see the argument in
``qmatmul.ref``) interpret-mode kernel outputs match these references
bit-for-bit; the float-activation path computes the same exact products but
XLA's fma contraction of the scale/bias epilogue can differ from the eager
reference by an ulp, so float-path comparisons use an ulp-of-max tolerance
(the same contract qmatmul's float path carries).

Also home to the canonical spatial padding math (:func:`pad_amounts` /
:func:`normalize_pads` — shared with the writers' im2col lowering) and
:func:`expand_dw_codes`, the block-diagonal dense expansion that lets the
legacy im2col + qgemm path run a depthwise conv as the differential baseline
(it materializes the ``kh*kw``-times-larger patch tensor the direct kernel
exists to kill).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.qmatmul.ref import (ActQt, epilogue_code_ref, epilogue_ref,
                                       exact_in_f32)
from repro.quant.ptq import derive_view

__all__ = ["pad_amounts", "normalize_pads", "out_spatial", "expand_dw_codes",
           "qconv_dw_ref", "qconv_dw_int8_act_ref", "ActQt"]


def pad_amounts(size: int, k: int, s: int, pads) -> Tuple[int, Tuple[int, int]]:
    """(out_dim, (lo, hi)) for one spatial dim — matches XLA's SAME/VALID."""
    if pads == "SAME":
        o = -(-size // s)
        pad = max((o - 1) * s + k - size, 0)
        return o, (pad // 2, pad - pad // 2)
    if pads == "VALID":
        return (size - k) // s + 1, (0, 0)
    lo, hi = pads
    return (size + lo + hi - k) // s + 1, (int(lo), int(hi))


def normalize_pads(pads):
    """Canonical *hashable* padding spec: ``"SAME"`` / ``"VALID"`` pass
    through; explicit pads normalize to ``((top, bottom), (left, right))``
    from either that pair-of-pairs form or the flat ONNX ``[t, l, b, r]``."""
    if isinstance(pads, str):
        return pads
    p = list(pads)
    if len(p) == 4 and not hasattr(p[0], "__len__"):
        t, l, b, r = (int(v) for v in p)
        return ((t, b), (l, r))
    return tuple((int(lo), int(hi)) for lo, hi in p)


def _split_pads(pads):
    """Per-axis pad spec for :func:`pad_amounts` from a normalized spec."""
    if isinstance(pads, str):
        return pads, pads
    return pads[0], pads[1]


def out_spatial(h: int, w: int, kh: int, kw: int, strides, pads
                ) -> Tuple[int, int, Tuple[int, int], Tuple[int, int]]:
    """(OH, OW, (ph_lo, ph_hi), (pw_lo, pw_hi)) for a conv window."""
    ph, pw = _split_pads(normalize_pads(pads))
    oh, hpad = pad_amounts(h, kh, strides[0], ph)
    ow, wpad = pad_amounts(w, kw, strides[1], pw)
    return oh, ow, hpad, wpad


def expand_dw_codes(codes):
    """Depthwise HWIO codes (kh, kw, 1, C) -> the block-diagonal dense
    (kh*kw*C, C) int8 matrix the im2col + qgemm path consumes.

    Row ``pos*C + cin`` holds the weight of patch position ``pos`` (dy-major,
    dx) and input channel ``cin`` for every output channel — zero except at
    ``cin == cout``, matching :func:`~repro.core.writers.qjax_writer.im2col`'s
    (dy, dx, channel) patch layout.  Nested truncation maps zeros to zeros,
    so the ``bits``-bit view of the expansion IS the expansion of the
    ``bits``-bit view — the baseline stays differential at every working
    point."""
    kh, kw, one, c = codes.shape
    assert one == 1, f"depthwise codes must be (kh, kw, 1, C), got {codes.shape}"
    eye = jnp.eye(c, dtype=codes.dtype)
    k2 = codes.reshape(kh * kw, c)
    return (k2[:, None, :] * eye[None, :, :]).reshape(kh * kw * c, c)


def _accumulate(xp, wmat, oh: int, ow: int, kh: int, kw: int, strides):
    """The kernel-ordered window accumulation: xp (B, Hp, Wp, C) f32 padded
    input, wmat (kh*kw, C) f32 per-tap weights -> (B, oh, ow, C) f32."""
    sh, sw = strides
    acc = jnp.zeros((xp.shape[0], oh, ow, xp.shape[3]), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            seg = xp[:, dy:dy + sh * (oh - 1) + 1:sh,
                     dx:dx + sw * (ow - 1) + 1:sw, :]
            acc = acc + seg * wmat[dy * kw + dx][None, None, None, :]
    return acc


def qconv_dw_ref(x, codes, scale, bias=None, *, kh: int, kw: int,
                 strides=(1, 1), pads="SAME", bits: int = 8,
                 relu: bool = False, act_qt: Optional[ActQt] = None,
                 out_dtype=jnp.float32):
    """Float-activation depthwise conv over the ``bits``-bit code view.

    x: (B, H, W, C) float; codes: (kh*kw, C) int8 master; scale: (C,) f32.
    Accumulates x * code products (scale applied ONCE after the window sum —
    the kernel's order, not dequant-first) then runs the shared epilogue."""
    B, H, W, C = x.shape
    oh, ow, hpad, wpad = out_spatial(H, W, kh, kw, strides, pads)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), hpad, wpad, (0, 0)))
    wmat = derive_view(codes, bits).astype(jnp.float32)
    acc = _accumulate(xp, wmat, oh, ow, kh, kw, strides)
    y = acc * scale.reshape(1, 1, 1, -1).astype(jnp.float32)
    if bias is not None:
        y = y + bias.reshape(1, 1, 1, -1).astype(jnp.float32)
    return epilogue_ref(y, relu, act_qt).astype(out_dtype)


def qconv_dw_int8_act_ref(x_codes, x_scale, codes, scale, bias=None, *,
                          kh: int, kw: int, strides=(1, 1), pads="SAME",
                          bits: int = 8, relu: bool = False,
                          act_qt: Optional[ActQt] = None,
                          out_code: bool = False, out_dtype=jnp.float32):
    """Fully-integer depthwise conv oracle: x_codes (B, H, W, C) int8, the
    scalar power-of-two producer scale folded into the per-channel weight
    scale (the kernel's fold — bit-identical), integer window accumulation
    (exact in f32 for any real window: ``kh*kw * 128 * 127 << 2^24``), and
    the shared requant epilogue.  ``out_code=True`` returns int8 codes."""
    B, H, W, C = x_codes.shape
    oh, ow, hpad, wpad = out_spatial(H, W, kh, kw, strides, pads)
    xp = jnp.pad(x_codes, ((0, 0), hpad, wpad, (0, 0)))
    wmat = derive_view(codes, bits)
    if exact_in_f32(kh * kw):
        acc = _accumulate(xp.astype(jnp.float32), wmat.astype(jnp.float32),
                          oh, ow, kh, kw, strides)
    else:
        sh, sw = strides
        iacc = jnp.zeros((B, oh, ow, C), jnp.int32)
        for dy in range(kh):
            for dx in range(kw):
                seg = xp[:, dy:dy + sh * (oh - 1) + 1:sh,
                         dx:dx + sw * (ow - 1) + 1:sw, :]
                iacc = iacc + seg.astype(jnp.int32) \
                    * wmat[dy * kw + dx].astype(jnp.int32)[None, None, None, :]
        acc = iacc.astype(jnp.float32)
    xs = jnp.asarray(x_scale, jnp.float32)
    assert xs.ndim == 0 or xs.size == 1, \
        "depthwise int8-act path takes a scalar (per-tensor) activation scale"
    y = acc * (scale.reshape(1, 1, 1, -1).astype(jnp.float32) * xs.reshape(()))
    if bias is not None:
        y = y + bias.reshape(1, 1, 1, -1).astype(jnp.float32)
    if out_code:
        assert act_qt is not None, "out_code needs the output act_qt"
        return epilogue_code_ref(y, relu, act_qt).astype(jnp.int8)
    return epilogue_ref(y, relu, act_qt).astype(out_dtype)
