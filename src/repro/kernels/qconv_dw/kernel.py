"""Pallas TPU kernel: direct depthwise conv in the integer code domain.

No im2col materialization: the ``(N*OH*OW, KH*KW*C)`` patch tensor the legacy
conv lowering builds is never formed.  Instead each grid invocation computes
ONE output row of one (batch, channel-block) slice straight from ``kh``
overlapping *input-row views* of the same padded activation array — block
height 1 makes the element row equal the block index, so the BlockSpec index
map ``(b*Hp + oh*sh + j, 0, c)`` expresses the sliding window without any
data duplication (the same multiple-views-of-one-array trick the qmatmul
kernel uses for its split-row packed activation chunks).

Depthwise structure makes the reduction tiny (``kh*kw`` taps per channel) and
purely channel-parallel, so the MAC loop is a VPU multiply-accumulate over
``(1, OW, bc)`` tiles — int32 on the fully-integer path — with the weight tap
matrix resident in VMEM: int8 master codes truncated to the active ``bits``
view in-VMEM, or the split-row sub-byte packed W4/W2 buffer
(:func:`repro.quant.pack.pack_rows` at the small depthwise alignment)
unpacked in-VMEM.  The fused bias + ReLU + (re)quant epilogue is shared with
qmatmul's oracle, so the exactness contract has ONE home
(:mod:`repro.kernels.qconv_dw.ref` accumulates in this kernel's exact order:
bit-exact on the fully-integer path, ulp-of-max on the float path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# shared contract homes: nested truncation + sub-byte unpack (qmatmul) and
# the epilogue bodies (pure jnp, trace fine inside a Pallas kernel)
from repro.kernels.qmatmul.kernel import _truncate, _unpack_fields
from repro.kernels.qmatmul.ref import ActQt, epilogue_code_ref, epilogue_ref

# channel-block default: one lane tile
DEFAULT_BC = 128


def _strided_taps(row, dx: int, sw: int, ow: int, bc: int):
    """Columns ``dx + sw*o`` for ``o in [0, ow)`` of a (1, Wpp, bc) row tile.
    Expressed as a contiguous slice + reshape (not a strided slice) so Mosaic
    lowers it on compiled backends."""
    if sw == 1:
        return jax.lax.slice_in_dim(row, dx, dx + ow, axis=1)
    seg = jax.lax.slice_in_dim(row, dx, dx + sw * ow, axis=1)
    return seg.reshape(1, ow, sw, bc)[:, :, 0, :]


def qconv_dw_kernel(*refs, kh: int, kw: int, sw: int, ow: int, bits: int,
                    has_bias: bool, relu: bool, act_qt: Optional[ActQt],
                    int8_act: bool, pack_ratio: int):
    """One grid invocation = one (batch, output-row, channel-block) tile.

    Ref layout (in order):

    ``row_0 .. row_{kh-1}`` — the kh input rows of this output row's window:
    (1, Wpp, bc) views of the SAME padded activation array (int8 codes on the
    integer path, f32 on the float path);
    ``w``  — weight taps: int8 codes (KRp, bc) or split-row sub-byte packed
    uint8 (Kp2/r, bc); rows beyond ``kh*kw`` are alignment padding;
    ``s``  — per-channel scale (1, bc), activation scale and sub-byte step
    pre-folded in;
    ``[b]`` — bias (1, bc), only ``has_bias``;
    ``o``  — output tile (1, OWp, bc); int8 codes when the epilogue emits
    codes, else the float dtype.
    """
    rows = refs[:kh]
    idx = kh
    w_ref, s_ref = refs[idx], refs[idx + 1]
    idx += 2
    b_ref = None
    if has_bias:
        b_ref = refs[idx]
        idx += 1
    o_ref = refs[idx]
    bc = o_ref.shape[-1]

    if pack_ratio > 1:
        fields = _unpack_fields(w_ref[...].astype(jnp.int32), bits, pack_ratio)
        wmat = jnp.concatenate(fields, axis=0)          # (Kp2, bc) q fields
        if not int8_act:
            wmat = wmat.astype(jnp.float32)
    else:
        wmat = _truncate(w_ref[...].astype(jnp.float32), bits)
        if int8_act:
            wmat = wmat.astype(jnp.int32)

    acc_dtype = jnp.int32 if int8_act else jnp.float32
    acc = jnp.zeros((1, ow, bc), acc_dtype)
    for j in range(kh):
        row = rows[j][...].astype(acc_dtype)            # (1, Wpp, bc)
        for dx in range(kw):
            taps = _strided_taps(row, dx, sw, ow, bc)
            acc = acc + taps * wmat[j * kw + dx][None, None, :]

    y = acc.astype(jnp.float32) * s_ref[...][:, None, :].astype(jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...][:, None, :].astype(jnp.float32)
    if jnp.issubdtype(o_ref.dtype, jnp.integer):
        o_ref[...] = epilogue_code_ref(y, relu, act_qt).astype(o_ref.dtype)
    else:
        o_ref[...] = epilogue_ref(y, relu, act_qt).astype(o_ref.dtype)


def build_dw_call(B: int, Hp: int, Wpp: int, Cp: int, *, kh: int, kw: int,
                  sh: int, sw: int, oh: int, ow: int, w_rows: int, bits: int,
                  int8_act: bool, bc: int = DEFAULT_BC,
                  out_dtype=jnp.float32, interpret: bool = False,
                  has_bias: bool = False, relu: bool = False,
                  act_qt: Optional[ActQt] = None, packed: bool = False,
                  emit_code: bool = False):
    """A ``pallas_call`` over a padded depthwise problem.

    Operands: activations reshaped to (B*Hp, Wpp, Cp) with
    ``Wpp >= (kw-1) + sw*ow`` (so every strided tap slice is in bounds),
    weights (w_rows, Cp) — codes padded to ``w_rows >= kh*kw`` rows, or the
    packed buffer with ``w_rows = Kp2 / (8//bits)`` byte rows — scale (1, Cp)
    and optional bias (1, Cp).  Output: (B*oh, ow, Cp)."""
    if emit_code:
        assert act_qt is not None, "emit_code needs the output act_qt"
        assert act_qt[1] >= -128 and act_qt[2] <= 127, \
            f"act_qt {act_qt} does not fit int8 codes"
    if packed:
        assert bits in (4, 2), f"sub-byte packing needs bits in (4, 2): {bits}"
    assert Cp % bc == 0, (Cp, bc)
    assert Wpp >= (kw - 1) + sw * ow, (Wpp, kw, sw, ow)
    grid = (B, oh, Cp // bc)

    kern = functools.partial(
        qconv_dw_kernel, kh=kh, kw=kw, sw=sw, ow=ow, bits=bits,
        has_bias=has_bias, relu=relu, act_qt=act_qt, int8_act=int8_act,
        pack_ratio=(8 // bits) if packed else 1)
    # kh views of the one padded activation array: view j's block row is the
    # input row feeding tap row j of output row oh (block height 1 => block
    # index == element row)
    in_specs = [
        pl.BlockSpec((1, Wpp, bc),
                     functools.partial(
                         lambda b, o, c, j: (b * Hp + o * sh + j, 0, c), j=j))
        for j in range(kh)
    ]
    in_specs.append(pl.BlockSpec((w_rows, bc), lambda b, o, c: (0, c)))
    in_specs.append(pl.BlockSpec((1, bc), lambda b, o, c: (0, c)))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bc), lambda b, o, c: (0, c)))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ow, bc), lambda b, o, c: (b * oh + o, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B * oh, ow, Cp),
                                       jnp.int8 if emit_code else out_dtype),
        interpret=interpret,
    )
