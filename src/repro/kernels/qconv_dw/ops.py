"""jit'd public wrappers for the direct depthwise conv kernel.

``qconv_dw(x, codes, scale, …)`` is the float-activation entry point and
``qconv_dw_int8_act`` the fully-integer one: int8 activation codes in, int32
window MACs, and ``out_code=True`` re-quantizes straight to the consumer's
int8 code in the fused epilogue — the depthwise stage of a separable block
never leaves the code domain.  Both accept ``packed=True`` to stream the
split-row sub-byte W4/W2 weight buffer (:func:`repro.quant.pack.pack_rows`
at ``align=DW_PACK_ALIGN`` — a 3x3 window packs its 9 tap rows into 16, not
the matmul tile's 128) unpacked in-VMEM.

Host-side prep pads the spatial window so every strided tap slice stays in
bounds and the W lane dim tiles cleanly, then hands the kernel ``kh``
row-shifted *views* of one padded activation array — the patch tensor of the
legacy im2col + qgemm lowering is never materialized.  Autotuning picks the
channel block the same way qmatmul picks (bm, bn, bk): in-process L1 dict,
then the shared versioned disk cache (``repro.kernels.autotune``) under
``"dw:"``-prefixed keys, then a timing sweep on compiled backends.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.qconv_dw.kernel import DEFAULT_BC, build_dw_call
from repro.kernels.qconv_dw.ref import (ActQt, normalize_pads, out_spatial,
                                        qconv_dw_int8_act_ref, qconv_dw_ref)
from repro.kernels.qmatmul.ops import _pad_to, _time_call, resolve_interpret
from repro.quant.pack import unpack_rows

# split-row packing alignment for depthwise tap rows: the reduction is kh*kw
# (9 for a 3x3 window), so aligning to the matmul tile's 128 would store 93%
# padding — 8 keeps the sub-byte byte counts honest and still divides by
# every pack ratio
DW_PACK_ALIGN = 8

_LANE = 128

__all__ = ["qconv_dw", "qconv_dw_int8_act", "pick_blocks_dw",
           "DW_PACK_ALIGN", "ActQt"]


def _round_up(n: int, m: int) -> int:
    return n + (-n) % m


# -- channel-block autotune -------------------------------------------------
# same two-level scheme as qmatmul.ops.pick_blocks, tuning the single free
# tiling knob of the depthwise grid (the channel block bc); entries share
# qmatmul's disk file under family-prefixed "dw:" keys, stored as 1-tuples
_BC_CACHE: Dict[tuple, int] = {}

_CANDIDATE_BC = (128, 256, 512)


def _disk_key_dw(B: int, oh: int, Wpp: int, Cp: int, kh: int, kw: int,
                 sh: int, sw: int, bits: int, int8_act: bool,
                 packed: bool) -> str:
    return (f"dw:{B}:{oh}:{Wpp}:{Cp}:{kh}x{kw}:{sh}{sw}:{bits}:"
            f"{int(int8_act)}:{int(packed)}")


def _synth_dw_args(B: int, Hp: int, Wpp: int, Cp: int, kh: int, w_rows: int,
                   int8_act: bool, packed: bool):
    """Concrete operands for the timing pass (shapes match the real call)."""
    if int8_act:
        x = jax.random.randint(jax.random.PRNGKey(0), (B * Hp, Wpp, Cp),
                               -127, 128, jnp.int8)
    else:
        x = jax.random.normal(jax.random.PRNGKey(0), (B * Hp, Wpp, Cp),
                              jnp.float32)
    if packed:
        w = jax.random.randint(jax.random.PRNGKey(1), (w_rows, Cp),
                               0, 256, jnp.int32).astype(jnp.uint8)
    else:
        w = jax.random.randint(jax.random.PRNGKey(1), (w_rows, Cp),
                               -127, 128, jnp.int8)
    return [x] * kh + [w, jnp.ones((1, Cp), jnp.float32)]


def pick_blocks_dw(B: int, Hp: int, Wpp: int, Cp: int, *, kh: int, kw: int,
                   sh: int, sw: int, oh: int, ow: int, w_rows: int, bits: int,
                   interpret: bool, int8_act: bool = False,
                   packed: bool = False) -> int:
    """Channel block ``bc`` for a padded depthwise problem at a working point.

    Interpret mode takes the static default without timing (timing the
    emulator would tune for the wrong machine); compiled backends sweep the
    divisor candidates once per shape and write the winner through to the
    shared disk cache."""
    key = ("dw", B, oh, Wpp, Cp, kh, kw, sh, sw, bits, int8_act, packed,
           interpret)
    hit = _BC_CACHE.get(key)
    if hit is not None:
        return hit
    default = min(DEFAULT_BC, Cp)
    if interpret:
        _BC_CACHE[key] = default
        return default
    dk = _disk_key_dw(B, oh, Wpp, Cp, kh, kw, sh, sw, bits, int8_act, packed)
    disk = autotune.disk_cache().get(dk)
    if disk is not None and len(disk) == 1:
        _BC_CACHE[key] = disk[0]
        return disk[0]
    cands = {default} | {c for c in _CANDIDATE_BC if Cp % c == 0}
    if len(cands) == 1:
        _BC_CACHE[key] = default
        return default
    args = _synth_dw_args(B, Hp, Wpp, Cp, kh, w_rows, int8_act, packed)
    best, best_t = default, float("inf")
    for bc in sorted(cands):
        call = build_dw_call(B, Hp, Wpp, Cp, kh=kh, kw=kw, sh=sh, sw=sw,
                             oh=oh, ow=ow, w_rows=w_rows, bits=bits,
                             int8_act=int8_act, bc=bc, interpret=False,
                             packed=packed)
        t = _time_call(call, args)
        if t < best_t:
            best, best_t = bc, t
    _BC_CACHE[key] = best
    autotune.disk_put(dk, (best,))
    return best


def _prep_spatial(xp, kw: int, sw: int, ow: int):
    """Pad a spatially-padded (B, Hp, Wp, C) activation so the kernel's tap
    slices and lane tiling line up; returns (x2, Hp, Wpp, Cp, owp) with x2
    reshaped to the (B*Hp, Wpp, Cp) row-view layout."""
    B, Hp, Wp, C = xp.shape
    owp = _round_up(ow, 8)
    wpp = _round_up(max(Wp, (kw - 1) + sw * owp), 8)
    cp = _round_up(C, _LANE)
    xp = jnp.pad(xp, ((0, 0), (0, 0), (0, wpp - Wp), (0, cp - C)))
    return xp.reshape(B * Hp, wpp, cp), Hp, wpp, cp, owp


def _prep_weights(codes, scale, bias, k2: int, cp: int, bits: int,
                  packed: bool):
    """(w, sp, bp, w_rows) padded to the channel tile; sub-byte step folded
    into the scale on the packed path (exact: the step is a power of two)."""
    if packed:
        r = 8 // bits
        assert codes.shape[0] * r == _round_up(k2, DW_PACK_ALIGN), (
            f"packed tap rows {codes.shape[0]} (x{r}) do not cover the "
            f"aligned window {_round_up(k2, DW_PACK_ALIGN)}")
        w = _pad_to(codes, cp, 1)
        s_eff = scale.reshape(1, -1).astype(jnp.float32) * float(1 << (8 - bits))
    else:
        assert codes.shape[0] == k2, (
            f"weight tap rows {codes.shape[0]} != window size {k2}")
        w = _pad_to(_pad_to(codes, 8, 0), cp, 1)
        s_eff = scale.reshape(1, -1).astype(jnp.float32)
    sp = _pad_to(s_eff, cp, 1)
    bp = None
    if bias is not None:
        bp = _pad_to(bias.reshape(1, -1).astype(jnp.float32), cp, 1)
    return w, sp, bp, w.shape[0]


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "strides", "pads", "bits", "relu", "act_qt", "interpret",
    "use_kernel", "packed", "bc"))
def qconv_dw(x, codes, scale, bias=None, *, kh: int, kw: int,
             strides: Tuple[int, int] = (1, 1), pads="SAME", bits: int = 8,
             relu: bool = False, act_qt: Optional[ActQt] = None,
             interpret: Optional[bool] = None,
             use_kernel: Optional[bool] = None, packed: bool = False,
             bc: Optional[int] = None):
    """Float-activation direct depthwise conv with the fused epilogue.

    x: (B, H, W, C) float NHWC; codes: (kh*kw, C) int8 master tap rows — or,
    with ``packed=True``, the split-row sub-byte buffer
    (align(kh*kw, 8)/r, C) uint8; scale: (C,) f32; bias: (C,) or None.
    ``pads`` must be hashable: "SAME" / "VALID" or the normalized
    ((top, bottom), (left, right)) from :func:`normalize_pads`."""
    B, H, W, C = x.shape
    k2 = kh * kw
    interp = resolve_interpret(interpret)
    if use_kernel is None:
        use_kernel = not interp
    if not use_kernel:
        c = unpack_rows(codes, bits)[:k2] if packed else codes
        return qconv_dw_ref(x, c, scale, bias, kh=kh, kw=kw, strides=strides,
                            pads=pads, bits=bits, relu=relu, act_qt=act_qt,
                            out_dtype=x.dtype)
    sh, sw = strides
    oh, ow, hpad, wpad = out_spatial(H, W, kh, kw, strides, pads)
    # f32 in the window MACs (not bf16): fixed-point activations make every
    # tap product exact, leaving only epilogue fma-contraction ulps vs the
    # oracle (qmatmul's float path loses bf16 mantissa bits in the MXU)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), hpad, wpad, (0, 0)))
    x2, Hp, wpp, cp, owp = _prep_spatial(xp, kw, sw, ow)
    w, sp, bp, w_rows = _prep_weights(codes, scale, bias, k2, cp, bits, packed)
    if bc is None:
        bc = pick_blocks_dw(B, Hp, wpp, cp, kh=kh, kw=kw, sh=sh, sw=sw,
                            oh=oh, ow=owp, w_rows=w_rows, bits=bits,
                            interpret=interp, packed=packed)
    call = build_dw_call(B, Hp, wpp, cp, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh,
                         ow=owp, w_rows=w_rows, bits=bits, int8_act=False,
                         bc=bc, out_dtype=x.dtype, interpret=interp,
                         has_bias=bias is not None, relu=relu, act_qt=act_qt,
                         packed=packed)
    args = [x2] * kh + [w, sp] + ([bp] if bp is not None else [])
    y = call(*args)
    return y.reshape(B, oh, owp, cp)[:, :, :ow, :C]


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "strides", "pads", "bits", "relu", "act_qt", "out_code",
    "packed", "interpret", "use_kernel", "out_dtype", "bc"))
def qconv_dw_int8_act(x_codes, x_scale, codes, scale, bias=None, *, kh: int,
                      kw: int, strides: Tuple[int, int] = (1, 1),
                      pads="SAME", bits: int = 8, relu: bool = False,
                      act_qt: Optional[ActQt] = None, out_code: bool = False,
                      packed: bool = False, interpret: Optional[bool] = None,
                      use_kernel: Optional[bool] = None,
                      out_dtype=jnp.float32, bc: Optional[int] = None):
    """Fully-integer direct depthwise conv: x_codes (B, H, W, C) int8
    activation codes, int32 window MACs, the producer's scalar power-of-two
    ``x_scale`` folded into the per-channel weight scale, and ``out_code=True``
    emitting the consumer's int8 codes from the fused epilogue.

    Zero-padding the code plane IS zero-padding the activation: fixed-point
    activation quant has no zero point, so code 0 decodes to 0.0 exactly."""
    B, H, W, C = x_codes.shape
    k2 = kh * kw
    xs = jnp.asarray(x_scale, jnp.float32)
    assert xs.ndim == 0 or xs.size == 1, \
        "depthwise int8-act path takes a scalar (per-tensor) activation scale"
    interp = resolve_interpret(interpret)
    if use_kernel is None:
        use_kernel = not interp
    if not use_kernel:
        c = unpack_rows(codes, bits)[:k2] if packed else codes
        return qconv_dw_int8_act_ref(x_codes, xs, c, scale, bias, kh=kh,
                                     kw=kw, strides=strides, pads=pads,
                                     bits=bits, relu=relu, act_qt=act_qt,
                                     out_code=out_code, out_dtype=out_dtype)
    sh, sw = strides
    oh, ow, hpad, wpad = out_spatial(H, W, kh, kw, strides, pads)
    xp = jnp.pad(x_codes, ((0, 0), hpad, wpad, (0, 0)))
    x2, Hp, wpp, cp, owp = _prep_spatial(xp, kw, sw, ow)
    w, sp, bp, w_rows = _prep_weights(codes, scale, bias, k2, cp, bits, packed)
    # scalar activation scale folds into the channel scale — a power of two,
    # so the fold is bit-exact vs the oracle's grouping
    sp = sp * xs.reshape(())
    if bc is None:
        bc = pick_blocks_dw(B, Hp, wpp, cp, kh=kh, kw=kw, sh=sh, sw=sw,
                            oh=oh, ow=owp, w_rows=w_rows, bits=bits,
                            interpret=interp, int8_act=True, packed=packed)
    call = build_dw_call(B, Hp, wpp, cp, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh,
                         ow=owp, w_rows=w_rows, bits=bits, int8_act=True,
                         bc=bc, out_dtype=out_dtype, interpret=interp,
                         has_bias=bias is not None, relu=relu, act_qt=act_qt,
                         packed=packed, emit_code=out_code)
    args = [x2] * kh + [w, sp] + ([bp] if bp is not None else [])
    y = call(*args)
    return y.reshape(B, oh, owp, cp)[:, :, :ow, :C]
