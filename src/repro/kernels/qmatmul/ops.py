"""jit'd public wrappers for the quantized matmul kernel.

``qmatmul(x, codes, scale, bits=…)`` handles arbitrary leading batch dims,
pads M/K/N up to MXU-aligned tiles, and falls back to the jnp oracle for
shapes too small to tile (CPU smoke paths).  ``qgemm`` is the writer-facing
entry point: bias + ReLU + activation fake-quant fused into the kernel
epilogue, backend-aware ``interpret`` selection (compiled on TPU, jnp-ref
fallback off-TPU) and a small block-size autotune cache keyed on
``(M, K, N, bits)``.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.kernel import (ActQt, build_call, DEFAULT_BM,
                                          DEFAULT_BN, DEFAULT_BK)
from repro.kernels.qmatmul.ref import qgemm_ref, qmatmul_ref

_MIN_TILE = 128


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Backend-aware ``interpret`` default: compiled Pallas on TPU, interpret
    mode everywhere else.  An explicit True/False always wins (writer kwargs
    pass it through for tests and forced modes)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


# -- block-size autotune ----------------------------------------------------
# keyed on the padded problem (M, K, N, bits) plus the interpret flag (an
# interpret-mode entry must not pin the untuned default for later compiled
# calls of the same shape); populated by timing candidate tilings on
# synthetic data the first time a shape is seen on a compiled backend, by
# the static default in interpret mode (timing interpret-mode Pallas would
# measure the emulator, not the hardware)
_BLOCK_CACHE: Dict[Tuple[int, int, int, int, bool],
                   Tuple[int, int, int]] = {}

_CANDIDATE_BLOCKS = ((128, 128, 512), (128, 256, 512), (256, 128, 512),
                     (128, 128, 256), (256, 256, 512))


def _default_blocks(M: int, K: int, N: int) -> Tuple[int, int, int]:
    return min(DEFAULT_BM, M), min(DEFAULT_BN, N), min(DEFAULT_BK, K)


def _time_call(call, args, iters: int = 3) -> float:
    jax.block_until_ready(call(*args))          # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def pick_blocks(M: int, K: int, N: int, bits: int,
                interpret: bool) -> Tuple[int, int, int]:
    """(bm, bn, bk) for an M×K×N problem at a working point.

    All dims are already padded to multiples of ``_MIN_TILE``.  Results are
    cached per (M, K, N, bits, interpret); the timing pass runs on synthetic
    concrete data, so it is safe to call at trace time inside an outer jit."""
    key = (M, K, N, bits, interpret)
    hit = _BLOCK_CACHE.get(key)
    if hit is not None:
        return hit
    default = _default_blocks(M, K, N)
    if interpret:
        _BLOCK_CACHE[key] = default
        return default
    cands = {default}
    for bm, bn, bk in _CANDIDATE_BLOCKS:
        bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
        if M % bm == 0 and N % bn == 0 and K % bk == 0:
            cands.add((bm, bn, bk))
    if len(cands) == 1:
        _BLOCK_CACHE[key] = default
        return default
    kx = jax.random.PRNGKey(0)
    x = jax.random.normal(kx, (M, K), jnp.bfloat16)
    w = jax.random.randint(jax.random.PRNGKey(1), (K, N), -127, 128,
                           jnp.int8)
    s = jnp.ones((1, N), jnp.float32)
    best, best_t = default, float("inf")
    for bm, bn, bk in sorted(cands):
        call = build_call(M, K, N, bits=bits, int8_act=False,
                          bm=bm, bn=bn, bk=bk, interpret=False)
        t = _time_call(call, (x, w, s))
        if t < best_t:
            best, best_t = (bm, bn, bk), t
    _BLOCK_CACHE[key] = best
    return best


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "use_kernel",
                                             "bm", "bn", "bk"))
def qmatmul(x, codes, scale, *, bits: int = 8,
            interpret: Optional[bool] = None,
            use_kernel: bool = True, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
            bk: int = DEFAULT_BK):
    """x: (..., K) float; codes: (K, N) int8; scale: (N,) f32 -> (..., N)."""
    lead = x.shape[:-1]
    K, N = codes.shape
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    if not use_kernel or min(M, K, N) < 8:
        y = qmatmul_ref(x2, codes, scale, bits, out_dtype=x.dtype)
        return y.reshape(*lead, N)
    interp = resolve_interpret(interpret)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    call = build_call(xp.shape[0], xp.shape[1], cp.shape[1], bits=bits,
                      int8_act=False, bm=min(bm, xp.shape[0]),
                      bn=min(bn, cp.shape[1]), bk=min(bk, xp.shape[1]),
                      out_dtype=x.dtype, interpret=interp)
    y = call(xp.astype(jnp.bfloat16), cp, sp)[:M, :N]
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bits", "relu", "act_qt",
                                             "interpret", "use_kernel",
                                             "bm", "bn", "bk"))
def qgemm(x, codes, scale, bias=None, *, bits: int = 8, relu: bool = False,
          act_qt: Optional[ActQt] = None, interpret: Optional[bool] = None,
          use_kernel: Optional[bool] = None,
          bm: Optional[int] = None, bn: Optional[int] = None,
          bk: Optional[int] = None):
    """Packed-weight Gemm with the fused epilogue — the execution engine's
    hot-path op.

    x: (..., K) float; codes: (K, N) int8 master; scale: (N,) f32; bias:
    (N,) or None.  ``use_kernel=None`` auto-selects: the compiled Pallas
    kernel on TPU, the jnp reference (which XLA constant-folds into a plain
    matmul when codes are trace constants) elsewhere.  ``act_qt`` is the
    consumer-side fixed-point activation quant ``(frac, qmin, qmax)``,
    applied inside the kernel epilogue instead of as a separate round/clip
    op per FIFO."""
    lead = x.shape[:-1]
    K, N = codes.shape
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    interp = resolve_interpret(interpret)
    if use_kernel is None:
        use_kernel = not interp
    if not use_kernel or min(M, K, N) < 8:
        y = qgemm_ref(x2, codes, scale, bias, bits=bits, relu=relu,
                      act_qt=act_qt, out_dtype=x.dtype)
        return y.reshape(*lead, N)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    Mp, Kp, Np = xp.shape[0], xp.shape[1], cp.shape[1]
    if bm is None or bn is None or bk is None:
        abm, abn, abk = pick_blocks(Mp, Kp, Np, bits, interp)
        bm, bn, bk = bm or abm, bn or abn, bk or abk
    args = [xp.astype(jnp.bfloat16), cp, sp]
    if bias is not None:
        args.append(_pad_to(bias.reshape(1, -1).astype(jnp.float32),
                            _MIN_TILE, 1))
    call = build_call(Mp, Kp, Np, bits=bits, int8_act=False,
                      bm=min(bm, Mp), bn=min(bn, Np), bk=min(bk, Kp),
                      out_dtype=x.dtype, interpret=interp,
                      has_bias=bias is not None, relu=relu, act_qt=act_qt)
    y = call(*args)[:M, :N]
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qmatmul_int8_act(x_codes, x_scale, codes, scale, *, bits: int = 8,
                     interpret: Optional[bool] = None, out_dtype=jnp.bfloat16):
    """Full-integer path: x_codes (M, K) int8 + per-row scale (M,)."""
    M, K = x_codes.shape
    N = codes.shape[1]
    xp = _pad_to(_pad_to(x_codes, _MIN_TILE, 0), _MIN_TILE, 1)
    xsp = _pad_to(x_scale.reshape(-1, 1).astype(jnp.float32), _MIN_TILE, 0)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    call = build_call(xp.shape[0], xp.shape[1], cp.shape[1], bits=bits,
                      int8_act=True, out_dtype=out_dtype,
                      interpret=resolve_interpret(interpret))
    return call(xp, xsp, cp, sp)[:M, :N]
