"""jit'd public wrappers for the quantized matmul kernel.

``qmatmul(x, codes, scale, bits=…)`` handles arbitrary leading batch dims,
pads M/K/N up to MXU-aligned tiles, and falls back to the jnp oracle for
shapes too small to tile (CPU smoke paths).  ``qgemm`` is the float-activation
writer entry point: bias + ReLU + activation fake-quant fused into the kernel
epilogue.  ``qmatmul_int8_act`` is the *fully-integer* entry point: the
activation operand is the producer FIFO's int8 codes + a power-of-two scale,
MACs run in int32, and ``out_code=True`` re-quantizes the output to the
consumer's int8 code in the same epilogue — codes, not floats, flow between
layers.  Both accept ``packed=True`` to stream split-row sub-byte W4/W2
weight buffers (:func:`repro.quant.pack.pack_rows`) unpacked in-VMEM.

All entry points share backend-aware ``interpret`` selection (compiled on
TPU, jnp-ref fallback off-TPU) and a block-size autotune cache keyed on the
padded problem.  The autotune cache is two-level: the in-process dict is L1,
and timed results persist to a JSON file (``~/.cache/repro/autotune.json``,
override with ``REPRO_AUTOTUNE_CACHE=<path>``, disable with
``REPRO_AUTOTUNE_CACHE=off``) so compiled-backend tuning survives across
processes.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.qmatmul.kernel import (ActQt, build_call, DEFAULT_BM,
                                          DEFAULT_BN, DEFAULT_BK)
from repro.kernels.qmatmul.ref import (qgemm_ref, qmatmul_int8_act_ref,
                                       qmatmul_ref)
from repro.quant.pack import unpack_rows

_MIN_TILE = 128

__all__ = ["qmatmul", "qgemm", "qmatmul_int8_act", "pick_blocks",
           "resolve_interpret", "ActQt"]


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Backend-aware ``interpret`` default: compiled Pallas on TPU, interpret
    mode everywhere else.  An explicit True/False always wins (writer kwargs
    pass it through for tests and forced modes)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


# -- block-size autotune ----------------------------------------------------
# keyed on the padded problem (M, K, N, bits, int8_act, packed) plus the
# interpret flag (an interpret-mode entry must not pin the untuned default
# for later compiled calls of the same shape); populated by timing candidate
# tilings on synthetic data the first time a shape is seen on a compiled
# backend, by the static default in interpret mode (timing interpret-mode
# Pallas would measure the emulator, not the hardware).  Timed entries are
# write-through persisted to the disk cache (see module docstring) and
# reloaded by later processes — the in-process dict stays the L1.
_BLOCK_CACHE: Dict[Tuple[int, int, int, int, bool, bool, bool],
                   Tuple[int, int, int]] = {}

_CANDIDATE_BLOCKS = ((128, 128, 512), (128, 256, 512), (256, 128, 512),
                     (128, 128, 256), (256, 256, 512))

# the disk half lives in repro.kernels.autotune (one versioned file shared
# by every kernel family); these aliases keep the historical module-level API
AUTOTUNE_CACHE_ENV = autotune.AUTOTUNE_CACHE_ENV
_disk_state = autotune._disk_state          # shared BY IDENTITY with autotune
autotune_cache_path = autotune.autotune_cache_path


def _disk_key(key) -> str:
    M, K, N, bits, int8_act, packed, _interp = key
    return f"{M}:{K}:{N}:{bits}:{int(int8_act)}:{int(packed)}"


def _disk_cache() -> Dict[str, Tuple[int, ...]]:
    return autotune.disk_cache()


def _disk_put(key, blocks: Tuple[int, int, int]) -> None:
    autotune.disk_put(_disk_key(key), blocks)


def _default_blocks(M: int, K: int, N: int) -> Tuple[int, int, int]:
    return min(DEFAULT_BM, M), min(DEFAULT_BN, N), min(DEFAULT_BK, K)


def _time_call(call, args, iters: int = 3) -> float:
    jax.block_until_ready(call(*args))          # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _synth_args(M: int, K: int, N: int, int8_act: bool, packed: bool,
                pack_ratio: int):
    """Concrete operands for the timing pass (shapes match the real call)."""
    if int8_act:
        x = jax.random.randint(jax.random.PRNGKey(0), (M, K), -127, 128,
                               jnp.int8)
    else:
        x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.bfloat16)
    if packed:
        w = jax.random.randint(jax.random.PRNGKey(1), (K // pack_ratio, N),
                               0, 256, jnp.int32).astype(jnp.uint8)
    else:
        w = jax.random.randint(jax.random.PRNGKey(1), (K, N), -127, 128,
                               jnp.int8)
    s = jnp.ones((1, N), jnp.float32)
    return [x] * pack_ratio + [w, s]


def pick_blocks(M: int, K: int, N: int, bits: int, interpret: bool,
                int8_act: bool = False,
                packed: bool = False) -> Tuple[int, int, int]:
    """(bm, bn, bk) for an M×K×N problem at a working point.

    All dims are already padded to multiples of ``_MIN_TILE``.  Results are
    cached per (M, K, N, bits, int8_act, packed, interpret); the timing pass
    runs on synthetic concrete data, so it is safe to call at trace time
    inside an outer jit.  Lookup order: in-process dict, then the on-disk
    cache (compiled-backend entries only), then a timing sweep whose result
    is written through to both."""
    key = (M, K, N, bits, int8_act, packed, interpret)
    hit = _BLOCK_CACHE.get(key)
    if hit is not None:
        return hit
    default = _default_blocks(M, K, N)
    if interpret:
        _BLOCK_CACHE[key] = default
        return default
    disk = _disk_cache().get(_disk_key(key))
    if disk is not None and len(disk) == 3:
        _BLOCK_CACHE[key] = disk
        return disk
    r = (8 // bits) if packed else 1
    cands = {default}
    for bm, bn, bk in _CANDIDATE_BLOCKS:
        bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
        if M % bm == 0 and N % bn == 0 and K % bk == 0 and bk % r == 0:
            cands.add((bm, bn, bk))
    if len(cands) == 1:
        _BLOCK_CACHE[key] = default
        return default
    args = _synth_args(M, K, N, int8_act, packed, r)
    best, best_t = default, float("inf")
    for bm, bn, bk in sorted(cands):
        call = build_call(M, K, N, bits=bits, int8_act=int8_act,
                          bm=bm, bn=bn, bk=bk, interpret=False, packed=packed)
        t = _time_call(call, args)
        if t < best_t:
            best, best_t = (bm, bn, bk), t
    _BLOCK_CACHE[key] = best
    _disk_put(key, best)
    return best


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "use_kernel",
                                             "bm", "bn", "bk"))
def qmatmul(x, codes, scale, *, bits: int = 8,
            interpret: Optional[bool] = None,
            use_kernel: bool = True, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
            bk: int = DEFAULT_BK):
    """x: (..., K) float; codes: (K, N) int8; scale: (N,) f32 -> (..., N)."""
    lead = x.shape[:-1]
    K, N = codes.shape
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    if not use_kernel or min(M, K, N) < 8:
        y = qmatmul_ref(x2, codes, scale, bits, out_dtype=x.dtype)
        return y.reshape(*lead, N)
    interp = resolve_interpret(interpret)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    call = build_call(xp.shape[0], xp.shape[1], cp.shape[1], bits=bits,
                      int8_act=False, bm=min(bm, xp.shape[0]),
                      bn=min(bn, cp.shape[1]), bk=min(bk, xp.shape[1]),
                      out_dtype=x.dtype, interpret=interp)
    y = call(xp.astype(jnp.bfloat16), cp, sp)[:M, :N]
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bits", "relu", "act_qt",
                                             "interpret", "use_kernel",
                                             "packed", "bm", "bn", "bk"))
def qgemm(x, codes, scale, bias=None, *, bits: int = 8, relu: bool = False,
          act_qt: Optional[ActQt] = None, interpret: Optional[bool] = None,
          use_kernel: Optional[bool] = None, packed: bool = False,
          bm: Optional[int] = None, bn: Optional[int] = None,
          bk: Optional[int] = None):
    """Packed-weight Gemm with the fused epilogue — the float-activation
    hot-path op.

    x: (..., K) float; codes: (K, N) int8 master — or, with ``packed=True``,
    the split-row sub-byte buffer (K'/r, N) uint8 where K' is K padded to the
    tile size (:func:`repro.quant.pack.pack_rows`); scale: (N,) f32; bias:
    (N,) or None.  ``use_kernel=None`` auto-selects: the compiled Pallas
    kernel on TPU, the jnp reference (which XLA constant-folds into a plain
    matmul when codes are trace constants) elsewhere.  ``act_qt`` is the
    consumer-side fixed-point activation quant ``(frac, qmin, qmax)``,
    applied inside the kernel epilogue instead of as a separate round/clip
    op per FIFO."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = codes.shape[-1]
    r = (8 // bits) if packed else 1
    if not packed:
        assert codes.shape[0] == K, (
            f"weight rows {codes.shape[0]} != reduction dim {K}")
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    interp = resolve_interpret(interpret)
    if use_kernel is None:
        use_kernel = not interp
    if not use_kernel or min(M, K, N) < 8:
        c = unpack_rows(codes, bits)[:K] if packed else codes
        y = qgemm_ref(x2, c, scale, bias, bits=bits, relu=relu,
                      act_qt=act_qt, out_dtype=x.dtype)
        return y.reshape(*lead, N)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    Mp, Kp = xp.shape
    if packed:
        assert codes.shape[0] * r == Kp, (
            f"packed weight rows {codes.shape[0]} (x{r}) do not cover the "
            f"padded reduction dim {Kp}")
        cp = _pad_to(codes, _MIN_TILE, 1)
        # the packed fields are q = view / step: fold the power-of-two step
        # into the channel scale (exact in f32)
        s_eff = scale.reshape(1, -1).astype(jnp.float32) * float(1 << (8 - bits))
    else:
        cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
        s_eff = scale.reshape(1, -1).astype(jnp.float32)
    Np = cp.shape[1]
    sp = _pad_to(s_eff, _MIN_TILE, 1)
    if bm is None or bn is None or bk is None:
        abm, abn, abk = pick_blocks(Mp, Kp, Np, bits, interp, packed=packed)
        bm, bn, bk = bm or abm, bn or abn, bk or abk
    args = [xp.astype(jnp.bfloat16)] * r + [cp, sp]
    if bias is not None:
        args.append(_pad_to(bias.reshape(1, -1).astype(jnp.float32),
                            _MIN_TILE, 1))
    call = build_call(Mp, Kp, Np, bits=bits, int8_act=False,
                      bm=min(bm, Mp), bn=min(bn, Np), bk=min(bk, Kp),
                      out_dtype=x.dtype, interpret=interp,
                      has_bias=bias is not None, relu=relu, act_qt=act_qt,
                      packed=packed)
    y = call(*args)[:M, :N]
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bits", "relu", "act_qt",
                                             "out_code", "packed", "interpret",
                                             "use_kernel", "out_dtype",
                                             "bm", "bn", "bk"))
def qmatmul_int8_act(x_codes, x_scale, codes, scale, bias=None, *,
                     bits: int = 8, relu: bool = False,
                     act_qt: Optional[ActQt] = None, out_code: bool = False,
                     packed: bool = False, interpret: Optional[bool] = None,
                     use_kernel: Optional[bool] = None,
                     out_dtype=jnp.bfloat16,
                     bm: Optional[int] = None, bn: Optional[int] = None,
                     bk: Optional[int] = None):
    """Fully-integer Gemm: x_codes (..., K) int8 activation codes, MACs in
    int32, the fused epilogue re-quantizing straight to the consumer's code.

    ``x_scale`` is the producer FIFO's activation scale — a scalar (the hot
    path: a power of two from calibration, folded into the per-channel weight
    scale with zero extra work) or per-row ``(M,)`` (the legacy dynamic-range
    path, applied in the epilogue).  ``codes`` is (K, N) int8 or the
    split-row packed (K'/r, N) uint8 buffer with ``packed=True``;
    ``out_code=True`` returns int8 codes (``act_qt`` required), else the
    dequantized float in ``out_dtype``."""
    lead = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    N = codes.shape[-1]
    r = (8 // bits) if packed else 1
    if not packed:
        assert codes.shape[0] == K, (
            f"weight rows {codes.shape[0]} != reduction dim {K}")
    x2 = x_codes.reshape(-1, K)
    M = x2.shape[0]
    xs = jnp.asarray(x_scale, jnp.float32)
    per_row = xs.ndim >= 1 and xs.size > 1
    interp = resolve_interpret(interpret)
    if use_kernel is None:
        use_kernel = not interp
    if not use_kernel or min(M, K, N) < 8:
        c = unpack_rows(codes, bits)[:K] if packed else codes
        y = qmatmul_int8_act_ref(x2, xs, c, scale, bits, bias=bias, relu=relu,
                                 act_qt=act_qt, out_code=out_code,
                                 out_dtype=out_dtype)
        return y.reshape(*lead, N)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    Mp, Kp = xp.shape
    if packed:
        assert codes.shape[0] * r == Kp, (
            f"packed weight rows {codes.shape[0]} (x{r}) do not cover the "
            f"padded reduction dim {Kp}")
        cp = _pad_to(codes, _MIN_TILE, 1)
        s_eff = scale.reshape(1, -1).astype(jnp.float32) * float(1 << (8 - bits))
    else:
        cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
        s_eff = scale.reshape(1, -1).astype(jnp.float32)
    Np = cp.shape[1]
    if not per_row:
        # scalar activation scale: fold into the channel scale (bit-exact
        # with the oracle's fold — both scales are powers of two)
        s_eff = s_eff * xs.reshape(())
    sp = _pad_to(s_eff, _MIN_TILE, 1)
    if bm is None or bn is None or bk is None:
        abm, abn, abk = pick_blocks(Mp, Kp, Np, bits, interp, int8_act=True,
                                    packed=packed)
        bm, bn, bk = bm or abm, bn or abn, bk or abk
    args = [xp] * r
    if per_row:
        args.append(_pad_to(xs.reshape(-1, 1), _MIN_TILE, 0))
    args += [cp, sp]
    if bias is not None:
        args.append(_pad_to(bias.reshape(1, -1).astype(jnp.float32),
                            _MIN_TILE, 1))
    call = build_call(Mp, Kp, Np, bits=bits, int8_act=True,
                      bm=min(bm, Mp), bn=min(bn, Np), bk=min(bk, Kp),
                      out_dtype=out_dtype, interpret=interp,
                      has_bias=bias is not None, relu=relu, act_qt=act_qt,
                      packed=packed, emit_code=out_code, has_xscale=per_row)
    y = call(*args)[:M, :N]
    return y.reshape(*lead, N)
