"""jit'd public wrapper for the quantized matmul kernel.

``qmatmul(x, codes, scale, bits=…)`` handles arbitrary leading batch dims,
pads M/K/N up to MXU-aligned tiles, and falls back to the jnp oracle for
shapes too small to tile (CPU smoke paths).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.kernel import build_call, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK
from repro.kernels.qmatmul.ref import qmatmul_ref

_MIN_TILE = 128


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, r)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "use_kernel",
                                             "bm", "bn", "bk"))
def qmatmul(x, codes, scale, *, bits: int = 8, interpret: bool = True,
            use_kernel: bool = True, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
            bk: int = DEFAULT_BK):
    """x: (..., K) float; codes: (K, N) int8; scale: (N,) f32 -> (..., N)."""
    lead = x.shape[:-1]
    K, N = codes.shape
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    if not use_kernel or min(M, K, N) < 8:
        y = qmatmul_ref(x2, codes, scale, bits, out_dtype=x.dtype)
        return y.reshape(*lead, N)
    xp = _pad_to(_pad_to(x2, _MIN_TILE, 0), _MIN_TILE, 1)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    call = build_call(xp.shape[0], xp.shape[1], cp.shape[1], bits=bits,
                      int8_act=False, bm=min(bm, xp.shape[0]),
                      bn=min(bn, cp.shape[1]), bk=min(bk, xp.shape[1]),
                      out_dtype=x.dtype, interpret=interpret)
    y = call(xp.astype(jnp.bfloat16), cp, sp)[:M, :N]
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qmatmul_int8_act(x_codes, x_scale, codes, scale, *, bits: int = 8,
                     interpret: bool = True, out_dtype=jnp.bfloat16):
    """Full-integer path: x_codes (M, K) int8 + per-row scale (M,)."""
    M, K = x_codes.shape
    N = codes.shape[1]
    xp = _pad_to(_pad_to(x_codes, _MIN_TILE, 0), _MIN_TILE, 1)
    xsp = _pad_to(x_scale.reshape(-1, 1).astype(jnp.float32), _MIN_TILE, 0)
    cp = _pad_to(_pad_to(codes, _MIN_TILE, 0), _MIN_TILE, 1)
    sp = _pad_to(scale.reshape(1, -1).astype(jnp.float32), _MIN_TILE, 1)
    call = build_call(xp.shape[0], xp.shape[1], cp.shape[1], bits=bits,
                      int8_act=True, out_dtype=out_dtype, interpret=interpret)
    return call(xp, xsp, cp, sp)[:M, :N]
