"""Pure-jnp oracle for the dequant-fused quantized matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.ptq import derive_view


def qmatmul_ref(x, codes, scale, bits: int = 8, out_dtype=jnp.bfloat16):
    """x: (M, K) float; codes: (K, N) int8 master; scale: (N,) or (1, N) f32.

    Dequantizes the ``bits``-bit derived view of the master codes and matmuls.
    """
    w = derive_view(codes, bits).astype(jnp.float32) * scale.reshape(1, -1)
    y = jnp.dot(x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def qmatmul_int8_act_ref(x_codes, x_scale, codes, scale, bits: int = 8,
                         out_dtype=jnp.bfloat16):
    """Integer-domain path: x_codes (M, K) int8, per-row scale (M,) or scalar.

    Accumulates in int32 (the MXU int8 path) then rescales."""
    w = derive_view(codes, bits)
    acc = jnp.dot(x_codes.astype(jnp.int32), w.astype(jnp.int32))
    y = acc.astype(jnp.float32) * x_scale.reshape(-1, 1) * scale.reshape(1, -1)
    return y.astype(out_dtype)
