"""Pure-jnp oracle for the dequant-fused quantized matmul."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.quant.ptq import derive_view

# static spec of the fused activation quant: (frac, qmin, qmax)
ActQt = Tuple[int, int, int]


def epilogue_ref(y, relu: bool = False, act_qt: Optional[ActQt] = None):
    """ReLU + fixed-point activation fake-quant, bit-identical to
    ``fixedpoint.fake_quant`` (round-half-even, saturate; powers of two are
    exact in f32).  The Pallas kernels trace this same function in-VMEM, so
    the kernel/oracle bit-exactness contract has one home."""
    if relu:
        y = jnp.maximum(y, 0.0)
    if act_qt is not None:
        frac, qmin, qmax = act_qt
        code = jnp.clip(jnp.round(y * (2.0 ** frac)), qmin, qmax)
        y = code * (2.0 ** -frac)
    return y


def qmatmul_ref(x, codes, scale, bits: int = 8, out_dtype=jnp.bfloat16):
    """x: (M, K) float; codes: (K, N) int8 master; scale: (N,) or (1, N) f32.

    Dequantizes the ``bits``-bit derived view of the master codes and matmuls.
    """
    w = derive_view(codes, bits).astype(jnp.float32) * scale.reshape(1, -1)
    y = jnp.dot(x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def qgemm_ref(x, codes, scale, bias=None, *, bits: int = 8,
              relu: bool = False, act_qt: Optional[ActQt] = None,
              out_dtype=jnp.float32):
    """Gemm over the ``bits``-bit view with the fused epilogue applied.

    Under jit with constant ``codes``/``scale`` XLA folds the dequant into a
    constant f32 weight, so this path costs exactly one matmul at runtime —
    the honest CPU fallback for the packed execution engine."""
    w = derive_view(codes, bits).astype(jnp.float32) * scale.reshape(1, -1)
    y = jnp.dot(x.astype(jnp.float32), w)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    return epilogue_ref(y, relu, act_qt).astype(out_dtype)


def qmatmul_int8_act_ref(x_codes, x_scale, codes, scale, bits: int = 8,
                         out_dtype=jnp.bfloat16):
    """Integer-domain path: x_codes (M, K) int8, per-row scale (M,) or scalar.

    Accumulates in int32 (the MXU int8 path) then rescales."""
    w = derive_view(codes, bits)
    acc = jnp.dot(x_codes.astype(jnp.int32), w.astype(jnp.int32))
    y = acc.astype(jnp.float32) * x_scale.reshape(-1, 1) * scale.reshape(1, -1)
    return y.astype(out_dtype)
