"""Pure-jnp oracle for the dequant-fused quantized matmul."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.quant.ptq import derive_view

# static spec of the fused activation quant: (frac, qmin, qmax)
ActQt = Tuple[int, int, int]


def epilogue_code_ref(y, relu: bool, act_qt: ActQt):
    """ReLU + fixed-point quantization, returning the *integer code* (still
    f32 domain: ``clip(round(y * 2^frac))``) — what the fully-integer path
    stores to the output FIFO as int8.  Round-half-even + saturate, identical
    to ``fixedpoint.quantize``."""
    if relu:
        y = jnp.maximum(y, 0.0)
    frac, qmin, qmax = act_qt
    return jnp.clip(jnp.round(y * (2.0 ** frac)), qmin, qmax)


def epilogue_ref(y, relu: bool = False, act_qt: Optional[ActQt] = None):
    """ReLU + fixed-point activation fake-quant, bit-identical to
    ``fixedpoint.fake_quant`` (round-half-even, saturate; powers of two are
    exact in f32).  The Pallas kernels trace this same function in-VMEM, so
    the kernel/oracle bit-exactness contract has one home."""
    if act_qt is None:
        return jnp.maximum(y, 0.0) if relu else y
    frac = act_qt[0]
    return epilogue_code_ref(y, relu, act_qt) * (2.0 ** -frac)


def exact_in_f32(k_dim: int) -> bool:
    """True when an integer dot over ``k_dim`` int8 codes is exact in f32
    arithmetic: every product and partial sum stays below 2^24 (the f32
    mantissa), so an f32 matmul — much faster than int32 on CPU backends —
    returns bit-identical results to the int32 MXU path.  Activation codes
    reach -128 (a signed 8-bit grid) while weight codes are clipped to
    [-127, 127], so the per-step product bound is 128*127."""
    return k_dim * 128 * 127 <= 2 ** 24


def int_dot(x_codes, w_codes):
    """Exact integer matmul of code matrices: f32 when provably exact (the
    fast path XLA vectorizes everywhere), int32 otherwise.  Returns f32."""
    if exact_in_f32(x_codes.shape[-1]):
        return jnp.dot(x_codes.astype(jnp.float32), w_codes.astype(jnp.float32))
    return jnp.dot(x_codes.astype(jnp.int32),
                   w_codes.astype(jnp.int32)).astype(jnp.float32)


def qmatmul_ref(x, codes, scale, bits: int = 8, out_dtype=jnp.bfloat16):
    """x: (M, K) float; codes: (K, N) int8 master; scale: (N,) or (1, N) f32.

    Dequantizes the ``bits``-bit derived view of the master codes and matmuls.
    """
    w = derive_view(codes, bits).astype(jnp.float32) * scale.reshape(1, -1)
    y = jnp.dot(x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def qgemm_ref(x, codes, scale, bias=None, *, bits: int = 8,
              relu: bool = False, act_qt: Optional[ActQt] = None,
              out_dtype=jnp.float32):
    """Gemm over the ``bits``-bit view with the fused epilogue applied.

    Under jit with constant ``codes``/``scale`` XLA folds the dequant into a
    constant f32 weight, so this path costs exactly one matmul at runtime —
    the honest CPU fallback for the packed execution engine."""
    w = derive_view(codes, bits).astype(jnp.float32) * scale.reshape(1, -1)
    y = jnp.dot(x.astype(jnp.float32), w)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    return epilogue_ref(y, relu, act_qt).astype(out_dtype)


def qmatmul_int8_act_ref(x_codes, x_scale, codes, scale, bits: int = 8,
                         bias=None, relu: bool = False,
                         act_qt: Optional[ActQt] = None,
                         out_code: bool = False, out_dtype=jnp.bfloat16):
    """Fully-integer path oracle: x_codes (M, K) int8, x_scale a scalar or
    per-row (M,) f32, with the fused epilogue.

    Accumulates exactly in the integer domain (:func:`int_dot`) then
    rescales.  A *scalar* ``x_scale`` (the writer hot path: a power-of-two
    activation-code scale) is folded into the per-channel weight scale before
    the accumulator multiply — the same order the Pallas kernel uses, so the
    two are bit-identical (power-of-two products are exact in f32).
    ``out_code=True`` returns the int8 *code* of the quantized output
    (``act_qt`` required) instead of its float value — codes, not floats,
    flow to the consumer."""
    w = derive_view(codes, bits)
    acc = int_dot(x_codes, w)
    xs = jnp.asarray(x_scale, jnp.float32)
    if xs.ndim == 0 or xs.size == 1:
        y = acc * (xs.reshape(()) * scale.reshape(1, -1))
    else:
        y = acc * xs.reshape(-1, 1) * scale.reshape(1, -1)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(jnp.float32)
    if out_code:
        assert act_qt is not None, "out_code needs the output act_qt"
        return epilogue_code_ref(y, relu, act_qt).astype(jnp.int8)
    return epilogue_ref(y, relu, act_qt).astype(out_dtype)
