"""Pallas TPU kernel: dequant-fused quantized matmul with a fused epilogue.

Weights live in HBM as int8 master codes (one copy serves every working point,
DESIGN.md §2 MDC row); each (bk, bn) tile is streamed into VMEM, truncated to
the active ``bits`` view, dequantized with the per-channel scale and fed to the
MXU against a (bm, bk) activation tile.  f32 accumulation in a VMEM scratch
tile across the k grid dim (TPU grid is sequential => scratch carries).

Three orthogonal extensions make this the *fully-integer* engine:

* ``int8_act`` — activations arrive as int8 codes (the producer FIFO's
  fixed-point integers); MACs run on the MXU int8 path with
  ``preferred_element_type=int32`` and the per-tensor activation scale is
  pre-folded into the per-channel weight scale (a power of two — exact).
* ``pack_ratio`` — the weight tile is *sub-byte packed* (split-row layout,
  :func:`repro.quant.pack.pack_rows`): a (bk/r, bn) uint8 tile is DMA'd from
  HBM and unpacked in-VMEM into ``r`` code tiles, each MAC'd against its own
  (bm, bk/r) activation tile (the r activation views index disjoint K chunks
  of the SAME array — no data duplication, just r BlockSpecs).  HBM traffic
  for the weight stream drops to bits/8 of the W8 view.
* ``emit_code`` — the epilogue (per-channel rescale, optional bias, ReLU and
  fixed-point activation quant, bit-identical to ``fixedpoint.fake_quant``)
  stores the int8 *code* instead of the dequantized value, so codes — not
  floats — flow through the inter-layer FIFO to the next kernel.

Block shapes are MXU-aligned (multiples of 128 on M/N; 128 lanes on K).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the epilogue body is shared with the jnp oracle (pure jnp, traces fine
# inside a Pallas kernel) so the bit-exactness contract has ONE home
from repro.kernels.qmatmul.ref import ActQt, epilogue_code_ref, epilogue_ref

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _truncate(codes_f32, bits: int):
    """Nested ``bits``-bit view of int8 codes (matches quant.ptq.derive_view)."""
    if bits >= 8:
        return codes_f32
    step = float(1 << (8 - bits))
    q = jnp.clip(jnp.round(codes_f32 / step), -(2 ** (bits - 1)),
                 2 ** (bits - 1) - 1)
    return q * step


def _unpack_fields(packed_i32, bits: int, pack_ratio: int):
    """Split-row packed uint8 tile -> ``pack_ratio`` integer code tiles
    (the ``q`` fields; the 2^(8-bits) step is pre-folded into the scale)."""
    half, mask = 1 << (bits - 1), (1 << bits) - 1
    outs = []
    for j in range(pack_ratio):
        f = (packed_i32 >> (j * bits)) & mask
        outs.append(jnp.where(f >= half, f - (1 << bits), f))
    return outs


def qgemm_kernel(*refs, bits: int, nk: int, has_bias: bool, relu: bool,
                 act_qt: Optional[ActQt], int8_act: bool = False,
                 pack_ratio: int = 1, has_xscale: bool = False):
    """Grid (m, n, k).  Ref layout (in order):

    ``x_0 .. x_{r-1}`` — activation tiles (bm, bk/r); bf16 float path or int8
    code path; r = ``pack_ratio`` views of the SAME array over disjoint K
    chunks (r == 1 when the weight tile is unpacked);
    ``[xs]``          — per-row activation scale (bm, 1), only ``has_xscale``
    (the legacy per-row integer path; the writer path folds its per-tensor
    power-of-two scale into ``s`` instead);
    ``w``             — weight tile: int8 codes (bk, bn) or split-row packed
    uint8 (bk/r, bn);
    ``s``             — per-channel scale (1, bn) with the activation scale
    and the sub-byte step pre-folded in;
    ``[b]``           — bias (1, bn), only ``has_bias``;
    ``o``             — output tile (bm, bn); int8 codes when the epilogue
    emits codes, else the float dtype;
    ``acc``           — VMEM scratch (bm, bn), int32 on the integer path.
    """
    r = pack_ratio
    xs = list(refs[:r])
    idx = r
    xs_ref = None
    if has_xscale:
        xs_ref = refs[idx]
        idx += 1
    w_ref, s_ref = refs[idx], refs[idx + 1]
    idx += 2
    b_ref = None
    if has_bias:
        b_ref = refs[idx]
        idx += 1
    o_ref, acc_ref = refs[idx], refs[idx + 1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if r == 1:
        if int8_act:
            w = w_ref[...].astype(jnp.int32)
            if bits < 8:
                # same round-half-even rule as ptq.derive_view (bit-exact)
                w = _truncate(w.astype(jnp.float32), bits).astype(jnp.int32)
            acc_ref[...] += jax.lax.dot(xs[0][...].astype(jnp.int32), w,
                                        preferred_element_type=jnp.int32)
        else:
            w = _truncate(w_ref[...].astype(jnp.float32), bits)
            acc_ref[...] += jax.lax.dot(xs[0][...].astype(jnp.float32), w,
                                        preferred_element_type=jnp.float32)
    else:
        fields = _unpack_fields(w_ref[...].astype(jnp.int32), bits, r)
        if int8_act:
            for x_ref, q in zip(xs, fields):
                acc_ref[...] += jax.lax.dot(
                    x_ref[...].astype(jnp.int32), q,
                    preferred_element_type=jnp.int32)
        else:
            for x_ref, q in zip(xs, fields):
                acc_ref[...] += jax.lax.dot(
                    x_ref[...].astype(jnp.float32), q.astype(jnp.float32),
                    preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        y = acc_ref[...].astype(jnp.float32)
        if xs_ref is not None:
            y = y * xs_ref[...].astype(jnp.float32)
        y = y * s_ref[...].astype(jnp.float32)
        if b_ref is not None:
            y = y + b_ref[...].astype(jnp.float32)
        if jnp.issubdtype(o_ref.dtype, jnp.integer):
            o_ref[...] = epilogue_code_ref(y, relu, act_qt).astype(o_ref.dtype)
        else:
            o_ref[...] = epilogue_ref(y, relu, act_qt).astype(o_ref.dtype)


def build_call(M: int, K: int, N: int, *, bits: int, int8_act: bool,
               bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
               out_dtype=jnp.bfloat16, interpret: bool = False,
               has_bias: bool = False, relu: bool = False,
               act_qt: Optional[ActQt] = None, packed: bool = False,
               emit_code: bool = False, has_xscale: bool = False):
    """A ``pallas_call`` for a (padded) M×K×N problem.

    ``K`` is the *logical* reduction dim; with ``packed=True`` the weight
    operand is the split-row packed uint8 buffer of shape (K/r, N) with
    ``r = 8 // bits`` (see :func:`repro.quant.pack.pack_rows`) and the
    activation operand is passed ``r`` times with BlockSpecs covering its r
    contiguous K chunks.  ``emit_code=True`` stores int8 codes (``act_qt``
    required)."""
    r = (8 // bits) if packed else 1
    if packed:
        assert bits in (4, 2), f"sub-byte packing needs bits in (4, 2): {bits}"
    if emit_code:
        assert act_qt is not None, "emit_code needs the output act_qt"
        assert act_qt[1] >= -128 and act_qt[2] <= 127, \
            f"act_qt {act_qt} does not fit int8 codes"
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if packed and bk % r:
        bk = max(r, bk - bk % r)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, K, N, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    kern = functools.partial(qgemm_kernel, bits=bits, nk=nk, has_bias=has_bias,
                             relu=relu, act_qt=act_qt, int8_act=int8_act,
                             pack_ratio=r, has_xscale=has_xscale)
    # r activation views over disjoint K chunks of the same array: view j's
    # block-column c covers x columns [(j*nk + c) * bk/r, ...) — chunk j of
    # the split-row layout
    in_specs = [
        pl.BlockSpec((bm, bk // r),
                     functools.partial(lambda m, n, k, j: (m, j * nk + k), j=j))
        for j in range(r)
    ]
    if has_xscale:
        in_specs.append(pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)))
    in_specs.append(pl.BlockSpec((bk // r, bn), lambda m, n, k: (k, n)))
    in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
    acc_dtype = jnp.int32 if int8_act else jnp.float32

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N),
                                       jnp.int8 if emit_code else out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )
