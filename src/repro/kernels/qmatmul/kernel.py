"""Pallas TPU kernel: dequant-fused quantized matmul with a fused epilogue.

Weights live in HBM as int8 master codes (one copy serves every working point,
DESIGN.md §2 MDC row); each (bk, bn) tile is streamed into VMEM, truncated to
the active ``bits`` view, dequantized with the per-channel scale and fed to the
MXU against a (bm, bk) activation tile.  f32 accumulation in a VMEM scratch
tile across the k grid dim (TPU grid is sequential => scratch carries).

The epilogue runs in-VMEM on the final k step: per-channel rescale, optional
bias add, optional ReLU and optional fixed-point activation quantization
(``act_qt = (frac, qmin, qmax)``, bit-identical to
``quant.fixedpoint.fake_quant``) — so the consumer-side round/clip the writers
used to emit as a separate op per FIFO happens inside the matmul kernel.

Block shapes are MXU-aligned (multiples of 128 on M/N; 128 lanes on K).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the epilogue body is shared with the jnp oracle (pure jnp, traces fine
# inside a Pallas kernel) so the bit-exactness contract has ONE home
from repro.kernels.qmatmul.ref import ActQt, epilogue_ref

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _truncate(codes_f32, bits: int):
    """Nested ``bits``-bit view of int8 codes (matches quant.ptq.derive_view)."""
    if bits >= 8:
        return codes_f32
    step = float(1 << (8 - bits))
    q = jnp.clip(jnp.round(codes_f32 / step), -(2 ** (bits - 1)),
                 2 ** (bits - 1) - 1)
    return q * step


def qgemm_kernel(*refs, bits: int, nk: int, has_bias: bool, relu: bool,
                 act_qt: Optional[ActQt]):
    """Grid (m, n, k). x: (bm, bk) bf16; w: (bk, bn) int8; s: (1, bn) f32;
    optional b: (1, bn) f32."""
    if has_bias:
        x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, s_ref, o_ref, acc_ref = refs
        b_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _truncate(w_ref[...].astype(jnp.float32), bits)
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        y = acc_ref[...] * s_ref[...].astype(jnp.float32)
        if b_ref is not None:
            y = y + b_ref[...].astype(jnp.float32)
        o_ref[...] = epilogue_ref(y, relu, act_qt).astype(o_ref.dtype)


# backward-compatible alias: the original no-epilogue float-activation kernel
qmatmul_kernel = functools.partial(qgemm_kernel, has_bias=False, relu=False,
                                   act_qt=None)


def qmatmul_int8_kernel(x_ref, xs_ref, w_ref, s_ref, o_ref, acc_ref, *,
                        bits: int, nk: int, relu: bool = False,
                        act_qt: Optional[ActQt] = None):
    """Integer-domain path: x int8 codes (bm, bk) + per-row scale (bm, 1);
    int32 accumulation (MXU int8 rate)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.int32)
    if bits < 8:
        # same round-half-even rule as quant.ptq.derive_view (bit-exact)
        w = _truncate(w.astype(jnp.float32), bits).astype(jnp.int32)
    acc_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.int32), w,
                                preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        y = (acc_ref[...].astype(jnp.float32)
             * xs_ref[...].astype(jnp.float32)
             * s_ref[...].astype(jnp.float32))
        o_ref[...] = epilogue_ref(y, relu, act_qt).astype(o_ref.dtype)


def build_call(M: int, K: int, N: int, *, bits: int, int8_act: bool,
               bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
               out_dtype=jnp.bfloat16, interpret: bool = False,
               has_bias: bool = False, relu: bool = False,
               act_qt: Optional[ActQt] = None):
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, K, N, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    if int8_act:
        assert not has_bias, "bias epilogue is float-activation only"
        kern = functools.partial(qmatmul_int8_kernel, bits=bits, nk=nk,
                                 relu=relu, act_qt=act_qt)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bm, 1), lambda m, n, k: (m, 0)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ]
        acc_dtype = jnp.int32
    else:
        kern = functools.partial(qgemm_kernel, bits=bits, nk=nk,
                                 has_bias=has_bias, relu=relu, act_qt=act_qt)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
        acc_dtype = jnp.float32

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )
