"""Per-architecture smoke tests (assignment requirement): reduced same-family
config, one forward + one train step + one decode step on CPU; asserts output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.params import count_params_analytic, init_params, param_shapes
from repro.optim.adamw import OptConfig
from repro.runtime import model_api
from repro.runtime.train import init_train_state, make_train_step

B, S = 2, 64


def _batch(cfg, key, with_labels=True):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model),
                                             jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, max_seq=S)
    batch = _batch(cfg, key, with_labels=False)
    logits, aux = model_api.forward_logits(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux["lb_loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, max_seq=S)
    state = init_train_state(params)
    step = make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                          total_steps=10))
    batch = _batch(cfg, key)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must change
    deltas = [float(jnp.max(jnp.abs(new_state.params[k].astype(jnp.float32)
                                    - params[k].astype(jnp.float32))))
              for k in params]
    assert max(deltas) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key, max_seq=S)
    batch = _batch(cfg, key, with_labels=False)
    st = model_api.init_decode_state(params, batch, cfg, B, 32)
    tok = batch["tokens"][:, :1]
    logits, st2 = model_api.decode_step(params, tok, st, cfg)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(st2.index) == 1


def test_full_param_counts_match_published():
    """Analytic N for the full configs lands near the published sizes."""
    expected = {
        "granite-moe-3b-a800m": 3.3e9, "mixtral-8x7b": 46.7e9,
        "phi3-mini-3.8b": 3.8e9, "h2o-danube-3-4b": 4.0e9,
        "codeqwen1.5-7b": 8.2e9, "qwen1.5-0.5b": 0.46e9,
        "mamba2-1.3b": 1.34e9, "hymba-1.5b": 1.64e9,
    }
    for arch, exp in expected.items():
        n = count_params_analytic(get_config(arch))
        assert abs(n - exp) / exp < 0.05, (arch, n, exp)


def test_moe_active_params():
    g = get_config("granite-moe-3b-a800m")
    assert count_params_analytic(g, active_only=True) < 1.0e9  # ~800M active
    m = get_config("mixtral-8x7b")
    assert 12e9 < count_params_analytic(m, active_only=True) < 14e9


def test_param_shapes_cover_init_exactly():
    for arch in ARCH_IDS:
        cfg = get_config(arch).smoke()
        shapes = param_shapes(cfg, max_seq=32)
        params = init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
        assert set(shapes) == set(params)
        for k in shapes:
            assert tuple(shapes[k]) == tuple(params[k].shape), k


def test_sliding_window_masks_distant_tokens():
    """SWA must differ from full attention beyond the window."""
    import dataclasses
    cfg = get_config("h2o-danube-3-4b").smoke()
    assert cfg.sliding_window == 32
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, max_seq=S)
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab)
    l_swa, _ = model_api.forward_logits(params, {"tokens": toks}, cfg)
    l_full, _ = model_api.forward_logits(params, {"tokens": toks}, cfg_full)
    # positions < window agree; beyond the window they must diverge
    early = float(jnp.max(jnp.abs(l_swa[:, :31] - l_full[:, :31])))
    late = float(jnp.max(jnp.abs(l_swa[:, 40:] - l_full[:, 40:])))
    assert early < 1e-2 and late > 1e-3


def test_vlm_patches_change_output():
    cfg = get_config("phi-3-vision-4.2b").smoke()
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key, max_seq=S)
    b1 = _batch(cfg, key, with_labels=False)
    b2 = dict(b1, patches=b1["patches"] + 1.0)
    l1, _ = model_api.forward_logits(params, b1, cfg)
    l2, _ = model_api.forward_logits(params, b2, cfg)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
