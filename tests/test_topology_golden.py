"""Golden-file + structural tests for ``StreamWriter.topology()``.

The canonical MNIST-CNN topology JSON is checked in under
``tests/golden/``; any change to actor composition, FIFO ids, derived FIFO
depths, or datatype labels shows up as a reviewable diff.  Regenerate after
an *intentional* model change with:

    GOLDEN_REGEN=1 PYTHONPATH=src python -m pytest tests/test_topology_golden.py
"""
import json
import math
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.configs.separable_cnn import CONFIG as SEP
from repro.core.flow import DesignFlow
from repro.core.ir import Graph, Node, TensorInfo
from repro.core.reader import cnn_to_ir, separable_cnn_to_ir
from repro.core.writers.stream_writer import StreamWriter
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig

GOLDEN = pathlib.Path(__file__).parent / "golden" / "mnist_cnn_topology.json"
SEP_GOLDEN = (pathlib.Path(__file__).parent / "golden"
              / "separable_cnn_topology.json")


def canonical_topology(fifo_slack: float = 1.0):
    """The check-in reference: seed-pinned MNIST CNN, symbolic batch,
    uniform D16-W8, default compile pipeline."""
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    res = DesignFlow(g).run(targets=("stream",),
                            dtconfig=DatatypeConfig(16, 8),
                            fifo_slack=fifo_slack)
    return res.writers["stream"].topology()


def canonical_separable_topology():
    """The depthwise-separable reference: seed-pinned separable CNN at the
    fully-integer D8-W8 point, default compile pipeline (DW+BN+Relu fusion
    and the stem's Relu->MaxPool reorder both fire)."""
    params = cnn.init_separable_params(SEP, jax.random.PRNGKey(0))
    g = separable_cnn_to_ir(
        SEP, {k: np.asarray(v) for k, v in params.items()})
    res = DesignFlow(g).run(targets=("stream",),
                            dtconfig=DatatypeConfig(8, 8))
    return res.writers["stream"].topology()


def _check_golden(topo, path):
    topo = json.loads(json.dumps(topo))            # normalize tuples
    if os.environ.get("GOLDEN_REGEN"):
        path.parent.mkdir(exist_ok=True)
        path.write_text(json.dumps(topo, indent=1) + "\n")
    assert path.exists(), "golden file missing — run with GOLDEN_REGEN=1"
    want = json.loads(path.read_text())
    assert topo == want, (
        f"topology drifted from {path.name}; if the change is intentional, "
        f"regenerate with GOLDEN_REGEN=1")


def test_topology_matches_golden_file():
    _check_golden(canonical_topology(), GOLDEN)


def test_separable_topology_matches_golden_file():
    _check_golden(canonical_separable_topology(), SEP_GOLDEN)


def test_every_fifo_has_positive_integer_depth():
    topo = canonical_topology()
    assert topo["connections"], "topology has no FIFOs"
    for c in topo["connections"]:
        assert isinstance(c["depth"], int) and c["depth"] > 0, c
        assert isinstance(c["depth_bytes"], int) and c["depth_bytes"] > 0, c
    assert topo["total_fifo_bytes"] == sum(c["depth_bytes"]
                                           for c in topo["connections"])


def test_fifo_depths_follow_value_info_models():
    """Line-buffer model for windowed consumers, per-item volume for Gemm."""
    topo = canonical_topology()
    by_dst = {c["dst"]: c for c in topo["connections"]}
    # conv0 reads the (N, 28, 28, 1) input with a 3x3 window:
    # (3-1)*28*1 + 3*1 line-buffer elements
    assert by_dst["conv0"]["depth"] == 2 * 28 * 1 + 3 * 1
    # pool0 reads conv0's (N, 28, 28, 16) stream with a 2x2 window
    assert by_dst["pool0"]["depth"] == 1 * 28 * 16 + 2 * 16
    # the classifier needs the whole flattened per-item vector resident
    assert by_dst["fc"]["depth"] == CNN.fc_in


def test_grouped_fifo_depths_follow_line_buffer_model():
    """Depthwise consumers share the Conv line-buffer firing rule — the NHWC
    stream buffers every channel of a pixel regardless of grouping."""
    topo = canonical_separable_topology()
    by_dst = {c["dst"]: c for c in topo["connections"]}
    # dw0 reads the pooled (N, 14, 14, 8) stream with a 3x3 window
    assert by_dst["dw0"]["depth"] == 2 * 14 * 8 + 3 * 8
    # dw1 reads pw0's (N, 14, 14, 16) stream (its own stride-2 does not
    # change what must be buffered before the first firing)
    assert by_dst["dw1"]["depth"] == 2 * 14 * 16 + 3 * 16
    # the reorder pass moved the stem pool onto the conv stream: the pool
    # buffers a window of the full-rate tensor, the relu one pixel of the
    # pooled one
    assert by_dst["stem_pool"]["tensor"] == "stem_out"
    assert by_dst["stem_pool"]["depth"] == 1 * 28 * 8 + 2 * 8
    assert by_dst["stem_relu"]["depth"] == 8
    actors = {a["name"]: a for a in topo["actors"]}
    for dw in ("dw0", "dw1"):
        assert actors[dw]["class"] == "FusedDepthwiseConv"
        assert actors[dw]["target"] == "pallas/qconv_dw"
        assert actors[dw]["sub_actors"] == [
            "LineBuffer", "DepthwiseActor", "WeightActor", "BiasActor",
            "ReluActor"]
        assert actors[dw]["weight_shape"][2] == 1      # HWIO depthwise


def test_fifo_slack_scales_depths():
    base = canonical_topology(fifo_slack=1.0)
    slacked = canonical_topology(fifo_slack=2.5)
    assert slacked["fifo_slack"] == 2.5
    for b, s in zip(base["connections"], slacked["connections"]):
        assert s["depth"] == math.ceil(b["depth"] * 2.5)
    assert slacked["total_fifo_bytes"] > base["total_fifo_bytes"]


def test_fifo_ids_globally_unique_under_fanout():
    """Regression: ids used to restart per node, so one tensor fanning out to
    two consumers produced colliding FIFO labels in the XDF analogue."""
    rng = np.random.default_rng(0)
    inits = {
        "w1": rng.normal(size=(6, 4)).astype(np.float32),
        "w2": rng.normal(size=(6, 4)).astype(np.float32),
    }
    g = Graph("fanout", [
        Node("Gemm", "g1", ["input", "w1"], ["a"]),
        Node("Gemm", "g2", ["input", "w2"], ["b"]),
        Node("Add", "sum", ["a", "b"], ["out"]),
    ], [TensorInfo("input", ("N", 6))], ["out"], inits)
    topo = StreamWriter(g).topology()
    conns = topo["connections"]
    assert len(conns) == 4                       # input x2 + a + b
    ids = [c["fifo"] for c in conns]
    assert len(set(ids)) == len(ids), f"colliding FIFO ids: {ids}"
    # the two edges carrying the same tensor are distinct FIFOs
    input_edges = [c for c in conns if c["tensor"] == "input"]
    assert len(input_edges) == 2
    assert input_edges[0]["fifo"] != input_edges[1]["fifo"]
    for c in conns:
        assert c["depth"] > 0


def test_save_topology_roundtrip_includes_aggregate_bytes(tmp_path):
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    res = DesignFlow(g).run(targets=("stream",),
                            dtconfig=DatatypeConfig(16, 8))
    path = tmp_path / "net.xdf.json"
    res.writers["stream"].save_topology(str(path))
    with open(path) as f:
        loaded = json.load(f)
    assert loaded["total_fifo_bytes"] > 0
    assert loaded["fifo_slack"] == 1.0
    assert loaded == json.loads(json.dumps(res.writers["stream"].topology()))


def test_stream_writer_rejects_nonpositive_slack():
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    with pytest.raises(ValueError):
        StreamWriter(g, fifo_slack=0.0)


def test_fifo_depths_are_batch_independent():
    """A pinned-batch graph must size FIFOs per item, identical to the
    symbolic-batch graph — streaming buffers never scale with batch."""
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    np_params = {k: np.asarray(v) for k, v in params.items()}
    sym = DesignFlow(cnn_to_ir(CNN, np_params)).run(targets=("stream",))
    pin = DesignFlow(cnn_to_ir(CNN, np_params, batch=8)).run(
        targets=("stream",))
    t_sym = sym.writers["stream"].topology()
    t_pin = pin.writers["stream"].topology()
    assert [c["depth"] for c in t_pin["connections"]] == \
        [c["depth"] for c in t_sym["connections"]]
    assert t_pin["total_fifo_bytes"] == t_sym["total_fifo_bytes"]
    by_dst = {c["dst"]: c for c in t_pin["connections"]}
    assert by_dst["fc"]["depth"] == CNN.fc_in          # not 8 * fc_in


def test_fifo_depth_falls_back_to_weight_window_without_kernel_shape():
    """Conv nodes may omit kernel_shape (shape inference reads the weight's
    HW dims); topology() must size the line buffer the same way."""
    rng = np.random.default_rng(0)
    inits = {"w": rng.normal(size=(3, 3, 2, 4)).astype(np.float32),
             "b": rng.normal(size=(4,)).astype(np.float32)}
    g = Graph("nok", [
        Node("Conv", "c", ["input", "w", "b"], ["out"],
             {"pads": "SAME", "strides": [1, 1]}),
    ], [TensorInfo("input", ("N", 8, 8, 2))], ["out"], inits)
    topo = StreamWriter(g).topology()
    (conn,) = topo["connections"]
    assert conn["depth"] == (3 - 1) * 8 * 2 + 3 * 2
