"""End-to-end design-flow tests (paper Fig. 1): Reader -> Writers -> adaptive."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig


@pytest.fixture(scope="module")
def flow_setup():
    key = jax.random.PRNGKey(0)
    params = cnn.init_params(CNN, key)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28, 1))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()}, batch=4)
    return params, x, DesignFlow(g)


def test_float_writer_bit_exact_vs_model(flow_setup):
    """With the pass pipeline disabled the interpretation is bit-exact; the
    default (fused) pipeline reassociates the BN affine into the conv weights
    and must agree within fp32 tolerance."""
    params, x, flow = flow_setup
    ref, _ = cnn.forward(params, x, CNN)
    raw = flow.run(targets=("jax",), dtconfig=DatatypeConfig(32, 32), passes=())
    np.testing.assert_array_equal(np.asarray(raw.executables["jax"](x)),
                                  np.asarray(ref))
    fused = flow.run(targets=("jax",), dtconfig=DatatypeConfig(32, 32))
    assert any(n.op == "FusedConv" for n in fused.graph.nodes)
    np.testing.assert_allclose(np.asarray(fused.executables["jax"](x)),
                               np.asarray(ref), atol=1e-5)


def test_stream_writer_equals_jax_writer(flow_setup):
    _, x, flow = flow_setup
    res = flow.run(targets=("jax", "stream"), dtconfig=DatatypeConfig(16, 8),
                   calib_inputs=(x,))
    np.testing.assert_allclose(np.asarray(res.executables["jax"](x)),
                               np.asarray(res.executables["stream"](x)),
                               atol=1e-4)


def test_quantized_flow_reports_zero_weights(flow_setup):
    _, x, flow = flow_setup
    fracs = {}
    for wb in (16, 8, 4, 2):
        res = flow.run(targets=("jax",), dtconfig=DatatypeConfig(16, wb))
        fracs[wb] = res.stats["zero_weight_frac"]
    # paper claim C3: zero weights increase as precision drops
    assert fracs[2] > fracs[4] > fracs[8] >= fracs[16]


def test_calibration_captures_every_fifo(flow_setup):
    _, x, flow = flow_setup
    ranges = flow.calibrate(x)
    # one range per tensor in the dataflow (inputs + all node outputs)
    names = {n.outputs[0] for n in flow.graph.nodes}
    assert names <= set(ranges)


def test_adaptive_accelerator_points_and_sharing(flow_setup):
    _, x, flow = flow_setup
    pts = [WorkingPoint("hi", 8), WorkingPoint("lo", 2)]
    acc = flow.compose_adaptive(pts)
    y_hi = acc("hi", x)
    y_lo = acc("lo", x)
    assert y_hi.shape == y_lo.shape == (4, 10)
    # lower precision must actually change the computation
    assert float(jnp.max(jnp.abs(y_hi - y_lo))) > 1e-6
    rep = acc.sharing_report()
    assert rep["sharing_ratio"] > 1.0          # merged < sum of separates
    assert rep["extra_bytes_per_config"] == 0  # derived views are free


def test_dynamic_switch_matches_static(flow_setup):
    _, x, flow = flow_setup
    pts = [WorkingPoint("hi", 8), WorkingPoint("lo", 4)]
    acc = flow.compose_adaptive(pts)
    dyn = acc.build_dynamic()
    for i, pt in enumerate(pts):
        y_static = acc(pt.name, x).astype(jnp.float32)
        y_dyn = dyn(jnp.int32(i), acc.qparams.tree(), x)
        np.testing.assert_allclose(np.asarray(y_dyn), np.asarray(y_static),
                                   atol=1e-5)


def test_stream_topology_is_mdc_consumable(flow_setup, tmp_path):
    _, x, flow = flow_setup
    res = flow.run(targets=("stream",), dtconfig=DatatypeConfig(16, 8))
    w = res.writers["stream"]
    topo = w.topology()
    conv_actors = [a for a in topo["actors"] if a["class"] == "FusedConv"]
    assert len(conv_actors) == 2
    for a in conv_actors:
        assert a["sub_actors"] == ["LineBuffer", "ConvActor", "WeightActor",
                                   "BiasActor", "ReluActor"]
        assert a["target"] == "pallas/conv2d_stream"
        assert a["fused"]  # records the folded BN/Relu node names
    assert all(c["datatype"] == "D16-W8" for c in topo["connections"])
    w.save_topology(str(tmp_path / "net.xdf.json"))
    import json
    with open(tmp_path / "net.xdf.json") as f:
        assert json.load(f)["network"] == "mnist-cnn"


def test_per_layer_precision_map_changes_output(flow_setup):
    """A heterogeneous PrecisionMap must differ from its uniform default and
    report per-layer zero-weight stats."""
    from repro.quant.qtypes import PrecisionMap
    _, x, flow = flow_setup
    uni = flow.run(targets=("jax",), dtconfig=DatatypeConfig(16, 8),
                   calib_inputs=(x,))
    pm = PrecisionMap(DatatypeConfig(16, 8), {"conv1": DatatypeConfig(16, 2)})
    het = flow.run(targets=("jax",), dtconfig=pm, calib_inputs=(x,))
    y_uni = np.asarray(uni.executables["jax"](x))
    y_het = np.asarray(het.executables["jax"](x))
    assert np.max(np.abs(y_uni - y_het)) > 1e-6
    assert het.stats["zero_weight_frac"] > uni.stats["zero_weight_frac"]
    # the annotation landed on the fused node
    names = {n.name: n for n in het.graph.nodes}
    assert names["conv1"].dtconfig == DatatypeConfig(16, 2)
    assert names["fc"].dtconfig == DatatypeConfig(16, 8)
