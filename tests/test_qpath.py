"""Packed-weight execution engine: bit-exactness of the packed-kernel path
against the fake-quant reference, nested-view truncation, fused epilogue
semantics, backend-aware interpret selection, shared weight buffers across
working points, and the AccelServer bits telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint, shared_point_executables
from repro.core.flow import DesignFlow
from repro.core.ir import Graph
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.qjax_writer import QJaxContext, QJaxWriter, im2col
from repro.kernels.qmatmul import ops as qops
from repro.kernels.qmatmul.ops import pick_blocks, qgemm, resolve_interpret
from repro.kernels.qmatmul.ref import epilogue_ref, qgemm_ref
from repro.models import cnn
from repro.quant.fixedpoint import fake_quant
from repro.quant.pack import PackedWeights
from repro.quant.ptq import derive_view
from repro.quant.qtypes import DatatypeConfig, QType

POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


def _quantize(w):
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / 127.0
    codes = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return codes, s


def _cnn_graph(seed=0):
    params = cnn.init_params(CNN, jax.random.PRNGKey(seed))
    return cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})


def _float_copy_reference(qwriter, bits, act_ranges=None):
    """The fake-quant baseline over the SAME quantizer: a plain JaxWriter
    whose initializers are the packed weights dequantized at ``bits``."""
    g = qwriter.graph
    deq = {k: np.asarray(v) for k, v in qwriter.packed.dequantized(bits).items()}
    g2 = Graph(g.name, g.nodes, g.inputs, g.outputs, deq)
    return JaxWriter(g2, DatatypeConfig(qwriter.dt.act_bits, 32),
                     act_ranges or qwriter.act_ranges).build()


# ---------------------------------------------------------------------------
# ops / kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("relu,with_bias,with_aqt", [
    (False, False, False), (True, True, True), (False, True, True),
    (True, False, True)])
def test_qgemm_kernel_epilogue_matches_ref(bits, relu, with_bias, with_aqt):
    """Forced interpret-mode kernel vs the jnp oracle, epilogue included."""
    x = jax.random.normal(jax.random.PRNGKey(bits), (128, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    codes, s = _quantize(w)
    bias = (jax.random.normal(jax.random.PRNGKey(2), (128,)) * 0.1
            if with_bias else None)
    aqt = (10, -(2 ** 15), 2 ** 15 - 1) if with_aqt else None
    y_k = qgemm(x, codes, s, bias, bits=bits, relu=relu, act_qt=aqt,
                interpret=True, use_kernel=True)
    y_r = qgemm_ref(x, codes, s, bias, bits=bits, relu=relu, act_qt=aqt)
    # kernel casts activations to bf16: 1-ulp-of-max bf16 tolerance
    tol = float(jnp.max(jnp.abs(y_r))) * 2 ** -7 + 1e-6
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), atol=tol)


def test_epilogue_matches_fixedpoint_fake_quant():
    """The fused activation quant must be bit-identical to fake_quant."""
    qt = QType(16, 10)
    y = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 40.0
    fused = epilogue_ref(y, relu=True, act_qt=(qt.frac, qt.qmin, qt.qmax))
    manual = fake_quant(jnp.maximum(y, 0.0), qt)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(manual))


def test_resolve_interpret_is_backend_aware():
    # CPU/GPU test envs must auto-select interpret; explicit values win
    auto = resolve_interpret(None)
    assert auto == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_pick_blocks_caches_and_divides():
    qops._BLOCK_CACHE.clear()
    bm, bn, bk = pick_blocks(256, 512, 384, 8, interpret=True)
    assert 256 % bm == 0 and 384 % bn == 0 and 512 % bk == 0
    # the interpret flag is part of the key: an interpret-mode default must
    # not pin the untuned blocks for later compiled calls of the same shape
    assert (256, 512, 384, 8, True) in qops._BLOCK_CACHE
    assert (256, 512, 384, 8, False) not in qops._BLOCK_CACHE
    assert pick_blocks(256, 512, 384, 8, interpret=True) == (bm, bn, bk)


def test_qgemm_small_shapes_fall_back_to_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (6, 4), jnp.float32)
    codes, s = _quantize(w)
    y = qgemm(x, codes, s, bits=8, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(qgemm_ref(x, codes, s, bits=8)))


def test_im2col_matches_xla_conv():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 9, 9, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.2
    patches, oh, ow = im2col(x, 3, 3, (1, 1), "SAME")
    y = patches.reshape(-1, 27) @ w.reshape(27, 5)
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y.reshape(2, oh, ow, 5)),
                               np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# PackedWeights: nested views, one buffer
# ---------------------------------------------------------------------------

def test_nested_view_truncation_property():
    """W4 codes must be the truncation of the W8 master (and W2 of it)."""
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    assert packed.tensors, "CNN graph must have packed weights"
    for name, t in packed.tensors.items():
        np.testing.assert_array_equal(np.asarray(t.view(8)),
                                      np.asarray(t.codes))
        for bits in (4, 2):
            np.testing.assert_array_equal(
                np.asarray(t.view(bits)),
                np.asarray(derive_view(t.codes, bits)), err_msg=name)
            # nested: every low-bit code lies on the 2^(8-bits) grid
            step = 1 << (8 - bits)
            assert int(jnp.max(jnp.abs(t.view(bits)).astype(jnp.int32)
                               % step)) == 0


def test_biases_and_norm_stats_pass_through():
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    assert "conv0/b" in packed.passthrough
    assert "bn0/mean" in packed.passthrough
    assert "conv0/w" in packed.tensors and "fc/w" in packed.tensors


# ---------------------------------------------------------------------------
# writer-level differential: packed path == fake-quant reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
def test_qjax_ref_path_bitexact_vs_fake_quant_reference(bits):
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (3, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    got = np.asarray(w.build(bits=bits)(x))
    ref = np.asarray(_float_copy_reference(w, bits)(x))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("bits", [8, 4])
def test_qjax_kernel_path_matches_fake_quant_reference(bits):
    """Forced interpret-mode Pallas kernels end to end (bf16 activations in
    the MXU tiles -> ulp-of-max tolerance)."""
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(2), (1, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=True, interpret=True)
    got = np.asarray(w.build(bits=bits)(x))
    ref = np.asarray(_float_copy_reference(w, bits)(x))
    tol = np.max(np.abs(ref)) * 2 ** -7 + 1e-6
    np.testing.assert_allclose(got, ref, atol=tol)


def test_qjax_mlp_gemm_chain_bitexact():
    rng = np.random.default_rng(0)
    sizes = [12, 16, 8, 4]
    params = {}
    for i in range(len(sizes) - 1):
        params[f"fc{i}/w"] = rng.normal(
            size=(sizes[i], sizes[i + 1])).astype(np.float32)
        params[f"fc{i}/b"] = rng.normal(size=(sizes[i + 1],)).astype(np.float32)
    g = mlp_to_ir(sizes, params)
    x = rng.random((5, 12), np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    for bits in (8, 4, 2):
        got = np.asarray(w.build(bits=bits)(x))
        ref = np.asarray(_float_copy_reference(w, bits)(x))
        np.testing.assert_array_equal(got, ref)


def test_act_quant_fused_into_epilogue_not_reapplied():
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(3), (2, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    y = w.build()(x)
    # every FusedConv/Gemm output was claimed by a kernel epilogue
    fused_ops = {n.outputs[0] for n in w.graph.topo_order()
                 if n.op in ("Conv", "FusedConv", "Gemm", "MatMul")}
    assert fused_ops <= w._fused_act
    # and the fused quant is idempotent: re-applying _act_q changes nothing
    w._fused_act.clear()
    node = next(n for n in w.graph.topo_order() if n.op == "Gemm")
    np.testing.assert_array_equal(
        np.asarray(w._act_q(node.outputs[0], y, node)), np.asarray(y))


def test_default_bits_follows_dtconfig():
    g = _cnn_graph()
    assert QJaxWriter(g).default_bits == 8
    assert QJaxWriter(g, DatatypeConfig(16, 4)).default_bits == 4
    assert QJaxWriter(g, DatatypeConfig(16, 16)).default_bits == 8
    w = QJaxWriter(g, DatatypeConfig(16, 4))
    # per-layer cap composes with the runtime point: min(point, layer)
    assert QJaxContext(w, 8).weight_bits(None) == 4
    assert QJaxContext(w, 2).weight_bits(None) == 2


def test_reference_writers_reject_bits_parameter():
    g = _cnn_graph()
    with pytest.raises(ValueError, match="packed-weight"):
        JaxWriter(g).build(bits=8)


# ---------------------------------------------------------------------------
# shared weight buffer across working points (the MDC merge, acceptance)
# ---------------------------------------------------------------------------

def test_point_executables_share_one_packed_buffer():
    res = DesignFlow(_cnn_graph()).run(targets=("qjax",),
                                       dtconfig=DatatypeConfig(16, 8))
    writer = res.writers["qjax"]
    pts = shared_point_executables(writer, POINTS)
    # buffer identity: every point reads the SAME master code arrays
    for name, t in writer.packed.tensors.items():
        ids = {id(pts[p.name].packed.tensors[name].codes) for p in POINTS}
        assert len(ids) == 1, f"{name} duplicated across points"
    assert [pts[p.name].bits for p in POINTS] == [8, 4, 2]
    # size accounting: a 3-point server holds ~1/3 of per-point copies
    rep = writer.packed.sharing_report(len(POINTS))
    assert rep["shared_bytes"] * 3 == rep["per_point_copy_bytes"]
    assert rep["shared_bytes"] / rep["per_point_copy_bytes"] <= 0.34
    # and far less than the legacy per-point fake-quant f32 copies the
    # writers used to bake into each executable (the empirical ratio)
    assert rep["sharing_ratio"] * rep["shared_bytes"] == rep["per_point_f32_bytes"]
    assert rep["sharing_ratio"] > 3.0


def test_shared_points_require_packed_writer():
    res = DesignFlow(_cnn_graph()).run(targets=("jax",))
    with pytest.raises(TypeError, match="packed"):
        shared_point_executables(res.writers["jax"], POINTS)
    with pytest.raises(KeyError, match="qjax"):
        res.serve_adaptive(POINTS)


def test_serve_adaptive_switches_bits_with_zero_weight_copies():
    from repro.core.adaptive import RuntimePolicy
    res = DesignFlow(_cnn_graph()).run(targets=("qjax",),
                                       dtconfig=DatatypeConfig(16, 8))
    srv = res.serve_adaptive(
        POINTS, policy=RuntimePolicy(POINTS, thresholds=[0.66, 0.33]),
        max_batch=4, max_wait=0.0)
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(4), (2, 28, 28, 1)),
                   np.float32)
    outs = {}
    for budget, point in ((1.0, "w8"), (0.5, "w4"), (0.1, "w2")):
        t = srv.submit(x, budget=budget)
        srv.pump(flush=True)
        outs[point] = np.asarray(srv.result(t))
    stats = srv.stats()
    assert stats["points"] == {"w8": 1, "w4": 1, "w2": 1}
    assert stats["bits_views"] == {8: 1, 4: 1, 2: 1}
    assert [r.bits for r in srv.reports] == [8, 4, 2]
    # each batch executed the right working point: outputs match the
    # per-bits builds of the same writer (no weight movement in between)
    writer = res.writers["qjax"]
    for point, bits in (("w8", 8), ("w4", 4), ("w2", 2)):
        np.testing.assert_allclose(
            outs[point], np.asarray(writer.build(bits=bits)(x)), atol=1e-6)


def test_qjax_flow_agrees_with_float_reference():
    """End-to-end sanity: the packed engine at W8/D32 stays close to the
    float pipeline (quantization error only, no structural drift)."""
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(5), (4, 28, 28, 1)),
                   np.float32)
    res = DesignFlow(g).run(targets=("jax", "qjax"))
    y_f = np.asarray(res.batched["jax"](x))
    y_q = np.asarray(res.batched["qjax"](x))
    scale = np.max(np.abs(y_f)) + 1e-9
    assert np.max(np.abs(y_f - y_q)) / scale < 0.05
    assert np.mean(np.argmax(y_f, -1) == np.argmax(y_q, -1)) == 1.0
