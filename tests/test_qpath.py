"""Packed-weight execution engine: bit-exactness of the packed-kernel path
against the fake-quant reference, nested-view truncation, fused epilogue
semantics, backend-aware interpret selection, shared weight buffers across
working points, the fully-integer (int8 activation code) hot path, sub-byte
packed weight residency, and the AccelServer bits telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.adaptive import WorkingPoint, shared_point_executables
from repro.core.flow import DesignFlow
from repro.core.ir import Graph
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.qjax_writer import (ActCode, QJaxContext, QJaxWriter,
                                            im2col)
from repro.kernels.qmatmul import ops as qops
from repro.kernels.qmatmul.ops import (pick_blocks, qgemm, qmatmul_int8_act,
                                       resolve_interpret)
from repro.kernels.qmatmul.ref import (epilogue_ref, qgemm_ref,
                                       qmatmul_int8_act_ref)
from repro.models import cnn
from repro.quant.fixedpoint import fake_quant
from repro.quant.pack import PackedWeights, pack_rows, unpack_rows
from repro.quant.ptq import derive_view
from repro.quant.qtypes import DatatypeConfig, QType

POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


def _quantize(w):
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / 127.0
    codes = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return codes, s


def _cnn_graph(seed=0):
    params = cnn.init_params(CNN, jax.random.PRNGKey(seed))
    return cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})


def _float_copy_reference(qwriter, bits, act_ranges=None):
    """The fake-quant baseline over the SAME quantizer: a plain JaxWriter
    whose initializers are the packed weights dequantized at ``bits``."""
    g = qwriter.graph
    deq = {k: np.asarray(v) for k, v in qwriter.packed.dequantized(bits).items()}
    g2 = Graph(g.name, g.nodes, g.inputs, g.outputs, deq)
    return JaxWriter(g2, DatatypeConfig(qwriter.dt.act_bits, 32),
                     act_ranges or qwriter.act_ranges).build()


# ---------------------------------------------------------------------------
# ops / kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("relu,with_bias,with_aqt", [
    (False, False, False), (True, True, True), (False, True, True),
    (True, False, True)])
def test_qgemm_kernel_epilogue_matches_ref(bits, relu, with_bias, with_aqt):
    """Forced interpret-mode kernel vs the jnp oracle, epilogue included."""
    x = jax.random.normal(jax.random.PRNGKey(bits), (128, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    codes, s = _quantize(w)
    bias = (jax.random.normal(jax.random.PRNGKey(2), (128,)) * 0.1
            if with_bias else None)
    aqt = (10, -(2 ** 15), 2 ** 15 - 1) if with_aqt else None
    y_k = qgemm(x, codes, s, bias, bits=bits, relu=relu, act_qt=aqt,
                interpret=True, use_kernel=True)
    y_r = qgemm_ref(x, codes, s, bias, bits=bits, relu=relu, act_qt=aqt)
    # kernel casts activations to bf16: 1-ulp-of-max bf16 tolerance
    tol = float(jnp.max(jnp.abs(y_r))) * 2 ** -7 + 1e-6
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), atol=tol)


def test_epilogue_matches_fixedpoint_fake_quant():
    """The fused activation quant must be bit-identical to fake_quant."""
    qt = QType(16, 10)
    y = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 40.0
    fused = epilogue_ref(y, relu=True, act_qt=(qt.frac, qt.qmin, qt.qmax))
    manual = fake_quant(jnp.maximum(y, 0.0), qt)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(manual))


def test_resolve_interpret_is_backend_aware():
    # CPU/GPU test envs must auto-select interpret; explicit values win
    auto = resolve_interpret(None)
    assert auto == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_pick_blocks_caches_and_divides():
    qops._BLOCK_CACHE.clear()
    bm, bn, bk = pick_blocks(256, 512, 384, 8, interpret=True)
    assert 256 % bm == 0 and 384 % bn == 0 and 512 % bk == 0
    # the interpret flag is part of the key: an interpret-mode default must
    # not pin the untuned blocks for later compiled calls of the same shape
    assert (256, 512, 384, 8, False, False, True) in qops._BLOCK_CACHE
    assert (256, 512, 384, 8, False, False, False) not in qops._BLOCK_CACHE
    assert pick_blocks(256, 512, 384, 8, interpret=True) == (bm, bn, bk)


def test_qgemm_small_shapes_fall_back_to_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (6, 4), jnp.float32)
    codes, s = _quantize(w)
    y = qgemm(x, codes, s, bits=8, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(qgemm_ref(x, codes, s, bits=8)))


def test_im2col_matches_xla_conv():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 9, 9, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.2
    patches, oh, ow = im2col(x, 3, 3, (1, 1), "SAME")
    y = patches.reshape(-1, 27) @ w.reshape(27, 5)
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y.reshape(2, oh, ow, 5)),
                               np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# PackedWeights: nested views, one buffer
# ---------------------------------------------------------------------------

def test_nested_view_truncation_property():
    """W4 codes must be the truncation of the W8 master (and W2 of it)."""
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    assert packed.tensors, "CNN graph must have packed weights"
    for name, t in packed.tensors.items():
        np.testing.assert_array_equal(np.asarray(t.view(8)),
                                      np.asarray(t.codes))
        for bits in (4, 2):
            np.testing.assert_array_equal(
                np.asarray(t.view(bits)),
                np.asarray(derive_view(t.codes, bits)), err_msg=name)
            # nested: every low-bit code lies on the 2^(8-bits) grid
            step = 1 << (8 - bits)
            assert int(jnp.max(jnp.abs(t.view(bits)).astype(jnp.int32)
                               % step)) == 0


def test_biases_and_norm_stats_pass_through():
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    assert "conv0/b" in packed.passthrough
    assert "bn0/mean" in packed.passthrough
    assert "conv0/w" in packed.tensors and "fc/w" in packed.tensors


# ---------------------------------------------------------------------------
# writer-level differential: packed path == fake-quant reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
def test_qjax_ref_path_bitexact_vs_fake_quant_reference(bits):
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (3, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    got = np.asarray(w.build(bits=bits)(x))
    ref = np.asarray(_float_copy_reference(w, bits)(x))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("bits", [8, 4])
def test_qjax_kernel_path_matches_fake_quant_reference(bits):
    """Forced interpret-mode Pallas kernels end to end (bf16 activations in
    the MXU tiles -> ulp-of-max tolerance)."""
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(2), (1, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=True, interpret=True)
    got = np.asarray(w.build(bits=bits)(x))
    ref = np.asarray(_float_copy_reference(w, bits)(x))
    tol = np.max(np.abs(ref)) * 2 ** -7 + 1e-6
    np.testing.assert_allclose(got, ref, atol=tol)


def test_qjax_mlp_gemm_chain_bitexact():
    rng = np.random.default_rng(0)
    sizes = [12, 16, 8, 4]
    params = {}
    for i in range(len(sizes) - 1):
        params[f"fc{i}/w"] = rng.normal(
            size=(sizes[i], sizes[i + 1])).astype(np.float32)
        params[f"fc{i}/b"] = rng.normal(size=(sizes[i + 1],)).astype(np.float32)
    g = mlp_to_ir(sizes, params)
    x = rng.random((5, 12), np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    for bits in (8, 4, 2):
        got = np.asarray(w.build(bits=bits)(x))
        ref = np.asarray(_float_copy_reference(w, bits)(x))
        np.testing.assert_array_equal(got, ref)


def test_act_quant_fused_into_epilogue_not_reapplied():
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(3), (2, 28, 28, 1)),
                   np.float32)
    w = QJaxWriter(g, DatatypeConfig(16, 8), use_kernel=False)
    y = w.build()(x)
    # every FusedConv/Gemm output was claimed by a kernel epilogue
    fused_ops = {n.outputs[0] for n in w.graph.topo_order()
                 if n.op in ("Conv", "FusedConv", "Gemm", "MatMul")}
    assert fused_ops <= w._fused_act
    # and the fused quant is idempotent: re-applying _act_q changes nothing
    w._fused_act.clear()
    node = next(n for n in w.graph.topo_order() if n.op == "Gemm")
    np.testing.assert_array_equal(
        np.asarray(w._act_q(node.outputs[0], y, node)), np.asarray(y))


def test_default_bits_follows_dtconfig():
    g = _cnn_graph()
    assert QJaxWriter(g).default_bits == 8
    assert QJaxWriter(g, DatatypeConfig(16, 4)).default_bits == 4
    assert QJaxWriter(g, DatatypeConfig(16, 16)).default_bits == 8
    w = QJaxWriter(g, DatatypeConfig(16, 4))
    # per-layer cap composes with the runtime point: min(point, layer)
    assert QJaxContext(w, 8).weight_bits(None) == 4
    assert QJaxContext(w, 2).weight_bits(None) == 2


def test_reference_writers_reject_bits_parameter():
    g = _cnn_graph()
    with pytest.raises(ValueError, match="packed-weight"):
        JaxWriter(g).build(bits=8)


# ---------------------------------------------------------------------------
# shared weight buffer across working points (the MDC merge, acceptance)
# ---------------------------------------------------------------------------

def test_point_executables_share_one_packed_buffer():
    res = DesignFlow(_cnn_graph()).run(targets=("qjax",),
                                       dtconfig=DatatypeConfig(16, 8))
    writer = res.writers["qjax"]
    pts = shared_point_executables(writer, POINTS)
    # buffer identity: every point reads the SAME master code arrays
    for name, t in writer.packed.tensors.items():
        ids = {id(pts[p.name].packed.tensors[name].codes) for p in POINTS}
        assert len(ids) == 1, f"{name} duplicated across points"
    assert [pts[p.name].bits for p in POINTS] == [8, 4, 2]
    # size accounting: a 3-point server holds ~1/3 of per-point copies
    rep = writer.packed.sharing_report(len(POINTS))
    assert rep["shared_bytes"] * 3 == rep["per_point_copy_bytes"]
    assert rep["shared_bytes"] / rep["per_point_copy_bytes"] <= 0.34
    # and far less than the legacy per-point fake-quant f32 copies the
    # writers used to bake into each executable (the empirical ratio)
    assert rep["sharing_ratio"] * rep["shared_bytes"] == rep["per_point_f32_bytes"]
    assert rep["sharing_ratio"] > 3.0


def test_shared_points_require_packed_writer():
    res = DesignFlow(_cnn_graph()).run(targets=("jax",))
    with pytest.raises(TypeError, match="packed"):
        shared_point_executables(res.writers["jax"], POINTS)
    with pytest.raises(KeyError, match="qjax"):
        res.serve_adaptive(POINTS)


def test_serve_adaptive_switches_bits_with_zero_weight_copies():
    from repro.core.adaptive import RuntimePolicy
    res = DesignFlow(_cnn_graph()).run(targets=("qjax",),
                                       dtconfig=DatatypeConfig(16, 8))
    srv = res.serve_adaptive(
        POINTS, policy=RuntimePolicy(POINTS, thresholds=[0.66, 0.33]),
        max_batch=4, max_wait=0.0)
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(4), (2, 28, 28, 1)),
                   np.float32)
    outs = {}
    for budget, point in ((1.0, "w8"), (0.5, "w4"), (0.1, "w2")):
        t = srv.submit(x, budget=budget)
        srv.pump(flush=True)
        outs[point] = np.asarray(srv.result(t))
    stats = srv.stats()
    assert stats["points"] == {"w8": 1, "w4": 1, "w2": 1}
    assert stats["bits_views"] == {8: 1, 4: 1, 2: 1}
    assert [r.bits for r in srv.reports] == [8, 4, 2]
    # each batch executed the right working point: outputs match the
    # per-bits builds of the same writer (no weight movement in between)
    writer = res.writers["qjax"]
    for point, bits in (("w8", 8), ("w4", 4), ("w2", 2)):
        np.testing.assert_allclose(
            outs[point], np.asarray(writer.build(bits=bits)(x)), atol=1e-6)


# ---------------------------------------------------------------------------
# fully-integer hot path: int8 activation codes end-to-end
# ---------------------------------------------------------------------------

def _mk_int8_inputs(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((M, K)).astype(np.float32)
    xs = 2.0 ** -4
    xc = np.clip(np.round(x / xs), -128, 127).astype(np.int8)
    w = rng.standard_normal((K, N)).astype(np.float32) * 0.3
    s = (np.maximum(np.abs(w).max(0), 1e-8) / 127.0).astype(np.float32)
    wc = np.clip(np.round(w / s), -127, 127).astype(np.int8)
    b = (rng.standard_normal(N) * 0.1).astype(np.float32)
    return jnp.asarray(xc), xs, jnp.asarray(wc), jnp.asarray(s), jnp.asarray(b)


@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (64, 200, 48),
                                   (130, 130, 130)])
@pytest.mark.parametrize("bits", [8, 4, 2])
def test_int8_act_kernel_bitexact_vs_ref(M, K, N, bits):
    """The fully-integer kernel (forced interpret mode) must be BIT-exact vs
    the oracle across shapes and working points: int32 accumulation plus
    power-of-two scale folds leave no room for float drift."""
    xc, xs, wc, s, b = _mk_int8_inputs(M, K, N, seed=bits)
    aqt = (10, -128, 127)
    for out_code in (False, True):
        y_k = qmatmul_int8_act(xc, xs, wc, s, b, bits=bits, relu=True,
                               act_qt=aqt, out_code=out_code,
                               interpret=True, use_kernel=True,
                               out_dtype=jnp.float32)
        y_r = qmatmul_int8_act_ref(xc, xs, wc, s, bits, bias=b, relu=True,
                                   act_qt=aqt, out_code=out_code,
                                   out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))
        if out_code:
            assert y_k.dtype == jnp.int8


@pytest.mark.parametrize("bits", [4, 2])
def test_int8_act_kernel_packed_weights_bitexact(bits):
    """Sub-byte packed weight streaming (in-VMEM unpack) is bit-exact vs the
    unpacked oracle: the packed field is the true low-bit integer and its
    2^(8-bits) step folds into the scale exactly."""
    xc, xs, wc, s, b = _mk_int8_inputs(64, 200, 48, seed=bits + 10)
    packed = pack_rows(wc, bits)
    assert packed.dtype == jnp.uint8
    y_k = qmatmul_int8_act(xc, xs, packed, s, b, bits=bits, relu=True,
                           act_qt=(9, -128, 127), out_code=True, packed=True,
                           interpret=True, use_kernel=True)
    y_r = qmatmul_int8_act_ref(xc, xs, wc, s, bits, bias=b, relu=True,
                               act_qt=(9, -128, 127), out_code=True)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_int8_act_per_row_scale_legacy_path():
    """The per-row dynamic-range form survives the rework (epilogue applies
    the row scale before the channel scale, same order as the oracle)."""
    xc, _, wc, s, _ = _mk_int8_inputs(128, 256, 128, seed=3)
    xs = jnp.asarray(
        np.random.default_rng(3).uniform(0.001, 0.1, 128).astype(np.float32))
    y_k = qmatmul_int8_act(xc, xs, wc, s, bits=8, interpret=True,
                           use_kernel=True)
    y_r = qmatmul_int8_act_ref(xc, xs, wc, s, 8)
    np.testing.assert_array_equal(np.asarray(y_k, np.float32),
                                  np.asarray(y_r, np.float32))


@pytest.mark.parametrize("bits", [4, 2])
def test_pack_rows_roundtrip_and_padding(bits):
    """Round trip: unpack(pack(codes)) == derive_view(codes) with zero-padded
    tail rows (zero fields are the zero code — MAC-neutral)."""
    rng = np.random.default_rng(bits)
    codes = rng.integers(-127, 128, (200, 40)).astype(np.int8)
    up = np.asarray(unpack_rows(pack_rows(codes, bits), bits))
    assert up.shape == (256, 40)     # K padded to PACK_ALIGN
    np.testing.assert_array_equal(
        up[:200], np.asarray(derive_view(jnp.asarray(codes), bits)))
    assert (up[200:] == 0).all()


def test_packed_view_byte_accounting():
    """Sub-byte residency: the W4 buffer is <= 0.55x and W2 <= 0.30x of the
    W8 view, per tensor and graph-wide (scales included)."""
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    for t in packed.tensors.values():
        w8 = t.view_nbytes(8)
        assert t.view_nbytes(4) <= 0.55 * w8
        assert t.view_nbytes(2) <= 0.30 * w8
        # the packed buffer itself really is the advertised uint8 size
        for bits in (4, 2):
            pv = t.packed_view(bits)
            assert pv.dtype == jnp.uint8
            assert int(pv.size) + 4 * int(t.scale.size) == t.view_nbytes(bits)
    rep = packed.sharing_report(3)
    vb = rep["view_bytes"]
    assert vb[4] <= 0.55 * vb[8] and vb[2] <= 0.30 * vb[8]


def test_packed_view_is_cached_one_buffer():
    packed = PackedWeights.from_initializers(_cnn_graph().initializers)
    t = next(iter(packed.tensors.values()))
    assert t.packed_view(4) is t.packed_view(4)   # one resident buffer


@pytest.mark.parametrize("use_kernel", [False, True])
def test_int8_act_codes_flow_between_layers(use_kernel):
    """The acceptance property: with D8 activations every inter-layer tensor
    on the hot path is an int8 ActCode — floats materialize ONLY at graph
    outputs (and at ops with no integer impl, of which the CNN has none)."""
    g = _cnn_graph()
    rng = np.random.default_rng(0)
    flow = DesignFlow(g)
    res = flow.run(targets=("qjax",), dtconfig=DatatypeConfig(8, 8),
                   calib_inputs=(rng.random((2, 28, 28, 1), np.float32),),
                   writer_kwargs={"qjax": {"use_kernel": use_kernel,
                                           "interpret": True}})
    w = res.writers["qjax"]
    assert w.int8_act_on
    x = rng.random((2, 28, 28, 1), np.float32)
    out, env = w.build(capture=True)(x)
    outputs = set(w.graph.outputs)
    for node in w.graph.topo_order():
        for o in node.outputs:
            if o in outputs:
                continue
            assert isinstance(env[o], ActCode), \
                f"{node.op} output {o} materialized {type(env[o]).__name__}"
            assert env[o].codes.dtype == jnp.int8
    # the graph INPUT is also encoded once at the boundary
    assert isinstance(env["input"], ActCode)
    # and the caller-facing output is float
    assert jnp.issubdtype(out.dtype, jnp.floating)


def test_int8_act_e2e_within_quantized_tolerance():
    """End to end on CNN + MLP: the fully-integer executable agrees with the
    float-calibrated fake-quant reference to quantization tolerance, and the
    forced-kernel build is bit-exact with the integer ref build (both are
    exact integer arithmetic)."""
    rng = np.random.default_rng(1)
    mlp_sizes = [64, 32, 16, 8]
    mlp_params = {}
    for i in range(len(mlp_sizes) - 1):
        mlp_params[f"fc{i}/w"] = rng.standard_normal(
            (mlp_sizes[i], mlp_sizes[i + 1])).astype(np.float32) * 0.3
        mlp_params[f"fc{i}/b"] = rng.standard_normal(
            mlp_sizes[i + 1]).astype(np.float32) * 0.1
    cases = [
        (_cnn_graph(), rng.random((3, 28, 28, 1), np.float32)),
        (mlp_to_ir(mlp_sizes, mlp_params), rng.random((5, 64), np.float32)),
    ]
    for g, x in cases:
        res = DesignFlow(g).run(targets=("jax", "qjax"),
                                dtconfig=DatatypeConfig(8, 8),
                                calib_inputs=(x[:2],))
        y_ref = np.asarray(res.batched["jax"](x))          # f32 fake-quant
        y_int = np.asarray(res.batched["qjax"](x))         # integer codes
        scale = np.max(np.abs(y_ref)) + 1e-9
        assert np.max(np.abs(y_ref - y_int)) / scale < 0.06
        # top-1 may only flip where the reference's top-2 margin is inside
        # the quantization tolerance (untrained logits have near-ties)
        for row in np.where(np.argmax(y_ref, -1) != np.argmax(y_int, -1))[0]:
            top2 = np.sort(y_ref[row])[-2:]
            assert top2[1] - top2[0] < 0.12 * scale
        # forced interpret-mode kernels == integer ref path, bit for bit
        wk = QJaxWriter(res.graph, DatatypeConfig(8, 8), res.act_ranges,
                        use_kernel=True, interpret=True)
        wr = QJaxWriter(res.graph, DatatypeConfig(8, 8), res.act_ranges,
                        use_kernel=False)
        for bits in (8, 4, 2):
            np.testing.assert_array_equal(
                np.asarray(wk.build(bits=bits)(x)),
                np.asarray(wr.build(bits=bits)(x)))


def test_int8_act_disabled_above_8_bit_activations():
    g = _cnn_graph()
    assert not QJaxWriter(g, DatatypeConfig(16, 8)).int8_act_on
    assert not QJaxWriter(g).int8_act_on              # float default
    assert QJaxWriter(g, DatatypeConfig(8, 8)).int8_act_on
    assert not QJaxWriter(g, DatatypeConfig(8, 8), int8_act=False).int8_act_on
    assert QJaxWriter(g, DatatypeConfig(16, 8), int8_act=True).int8_act_on


def test_serve_adaptive_reports_packed_bits_bytes():
    """AccelServer telemetry accounts the sub-byte resident bytes per view."""
    g = _cnn_graph()
    rng = np.random.default_rng(2)
    res = DesignFlow(g).run(targets=("qjax",), dtconfig=DatatypeConfig(8, 8),
                            calib_inputs=(rng.random((2, 28, 28, 1),
                                                     np.float32),))
    srv = res.serve_adaptive(POINTS, max_batch=4, max_wait=0.0)
    x = rng.random((1, 28, 28, 1), np.float32)
    t = srv.submit(x)
    srv.pump(flush=True)
    srv.result(t)
    bb = srv.stats()["bits_bytes"]
    packed = res.writers["qjax"].packed
    assert bb == {b: packed.view_bytes(b) for b in (8, 4, 2)}
    assert bb[4] <= 0.55 * bb[8] and bb[2] <= 0.30 * bb[8]


def test_autotune_cache_persists_across_processes(tmp_path, monkeypatch):
    """Timed block picks survive the process: a second (simulated) process
    with a cold in-memory cache reloads them from disk instead of retuning."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(qops.AUTOTUNE_CACHE_ENV, str(path))
    qops._disk_state["path"] = False     # force re-resolve of the env var
    qops._BLOCK_CACHE.clear()
    key = (256, 512, 384, 8, False, False, False)
    qops._BLOCK_CACHE[key] = (128, 128, 256)
    qops._disk_put(key, (128, 128, 256))
    assert path.exists()
    # simulate a fresh process: cold L1, cold disk-state
    qops._BLOCK_CACHE.clear()
    qops._disk_state["path"] = False
    assert pick_blocks(256, 512, 384, 8, interpret=False) == (128, 128, 256)
    assert qops._BLOCK_CACHE[key] == (128, 128, 256)   # write-through to L1
    # interpret-mode entries stay process-local (static default, not timed)
    import json
    qops._BLOCK_CACHE.clear()
    pick_blocks(512, 512, 512, 8, interpret=True)
    doc = json.loads(path.read_text())
    from repro.kernels.autotune import CACHE_SCHEMA
    assert doc["schema"] == CACHE_SCHEMA
    assert len(doc["entries"]) == 1


def test_autotune_cache_disable_and_corrupt(tmp_path, monkeypatch):
    monkeypatch.setenv(qops.AUTOTUNE_CACHE_ENV, "off")
    qops._disk_state["path"] = False
    assert qops.autotune_cache_path() is None
    qops._disk_put((1, 2, 3, 8, False, False, False), (1, 2, 3))  # no-op
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    monkeypatch.setenv(qops.AUTOTUNE_CACHE_ENV, str(path))
    qops._disk_state["path"] = False
    assert qops._disk_cache() == {}      # corrupt cache: retune, don't crash


def test_qjax_flow_agrees_with_float_reference():
    """End-to-end sanity: the packed engine at W8/D32 stays close to the
    float pipeline (quantization error only, no structural drift)."""
    g = _cnn_graph()
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(5), (4, 28, 28, 1)),
                   np.float32)
    res = DesignFlow(g).run(targets=("jax", "qjax"))
    y_f = np.asarray(res.batched["jax"](x))
    y_q = np.asarray(res.batched["qjax"](x))
    scale = np.max(np.abs(y_f)) + 1e-9
    assert np.max(np.abs(y_f - y_q)) / scale < 0.05
    assert np.mean(np.argmax(y_f, -1) == np.argmax(y_q, -1)) == 1.0
