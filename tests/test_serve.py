"""Serving correctness: decode == teacher-forced forward, adaptive switching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.models.params import init_params
from repro.runtime import model_api, serve
from repro.runtime.serve import AdaptiveLMServer


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-1.3b", "hymba-1.5b",
                                  "granite-moe-3b-a800m", "whisper-base"])
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through the KV/SSM cache must reproduce the
    teacher-forced logits (f32 smoke config for tight tolerance)."""
    cfg = dataclasses.replace(get_config(arch).smoke(), dtype="float32")
    if cfg.moe is not None:
        # capacity-based MoE drops differently at batch 1 vs batch S tokens;
        # a high capacity factor removes drops so the comparison is exact
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    params = init_params(cfg, key, max_seq=S)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    fwd_logits, _ = model_api.forward_logits(params, batch, cfg)

    st = model_api.init_decode_state(params, batch, cfg, B, S,
                                     dtype=jnp.float32)
    step = jax.jit(lambda p, t, s: model_api.decode_step(p, t, s, cfg))
    errs = []
    for t in range(S):
        logits, st = step(params, toks[:, t:t + 1], st)
        errs.append(float(jnp.max(jnp.abs(
            logits[:, 0] - fwd_logits[:, t]))))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-6
    assert max(errs) / scale < 5e-3, f"{arch}: decode/forward mismatch {max(errs)}"


def test_adaptive_server_switches_points():
    cfg = get_config("qwen1.5-0.5b").smoke()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, max_seq=32)
    points = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    srv = AdaptiveLMServer(params, cfg, points,
                           RuntimePolicy(points, thresholds=[0.66, 0.33]))
    st = model_api.init_decode_state(params, {"tokens": None}, cfg, 2, 32)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab)
    seen = []
    for budget in (1.0, 0.5, 0.1):
        logits, st, m = srv.decode(tok, st, energy_budget_frac=budget)
        seen.append(m.point)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert seen == ["w8", "w4", "w2"]
    # lower precision reads fewer weight bytes (the paper's energy story)
    b = [srv.decode(tok, st, budget)[2].weight_bytes_read
         for budget in (1.0, 0.5, 0.1)]
    assert b[0] > b[1] > b[2]


def test_working_points_share_master_weights():
    """All working points must read the SAME master codes (MDC sharing)."""
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(2), max_seq=32)
    srv = AdaptiveLMServer(params, cfg)
    tree = srv.qparams.tree()
    assert len(tree["codes"]) > 0
    # switching points does not touch qparams
    st = model_api.init_decode_state(params, {"tokens": None}, cfg, 1, 32)
    tok = jnp.zeros((1, 1), jnp.int32)
    srv.decode(tok, st, 1.0)
    srv.decode(tok, st, 0.1)
    tree2 = srv.qparams.tree()
    for k in tree["codes"]:
        np.testing.assert_array_equal(np.asarray(tree["codes"][k]),
                                      np.asarray(tree2["codes"][k]))


def test_greedy_generate_empty_prompt():
    # regression: S0 == 0 skipped the warmup loop and hit a NameError on
    # `logits`; the empty-prompt path now seeds generation with token 0
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), max_seq=16)
    prompt = jnp.zeros((2, 0), jnp.int32)
    out = serve.greedy_generate(params, cfg, prompt, max_new=4, seq_len=16)
    assert out.shape == (2, 4)
    assert int(out[0, 0]) == 0          # BOS seed counts as the first token


def test_greedy_generate_prompt_prefix_consistency():
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(1), max_seq=16)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 3), 0, cfg.vocab)
    out = serve.greedy_generate(params, cfg, prompt, max_new=5, seq_len=16)
    assert out.shape == (1, 3 + 5)
    np.testing.assert_array_equal(np.asarray(out[:, :3]), np.asarray(prompt))
