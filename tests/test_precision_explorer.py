"""Mixed-precision explorer guarantees: determinism and the accuracy floor.

The greedy search (``passes/precision.py``) drives Table II's ``Wauto`` row;
these tests pin that (a) the search is a pure function of its inputs — two
runs from the same seed agree exactly — and (b) no returned configuration
ever falls below the ``1 - tol`` top-1-agreement floor it promised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.passes import strip_precision
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.core.writers.jax_writer import JaxWriter
from repro.quant.qtypes import PrecisionMap

TOL = 0.1
SEED = 1234


@pytest.fixture(scope="module")
def mlp_setup():
    sizes = [16, 12, 8, 5]
    rng = np.random.default_rng(SEED)
    params = {}
    for i in range(len(sizes) - 1):
        params[f"fc{i}/w"] = (0.5 * rng.normal(size=(sizes[i], sizes[i + 1]))
                              ).astype(np.float32)
        params[f"fc{i}/b"] = (0.2 * rng.normal(size=(sizes[i + 1],))
                              ).astype(np.float32)
    g = mlp_to_ir(sizes, params)
    x = jax.random.normal(jax.random.PRNGKey(SEED), (32, 16))
    return DesignFlow(g), x


def _agreement(flow, pm, x) -> float:
    """Top-1 agreement of the quantized executable vs. the float reference
    on the calibration batch."""
    res = flow.run(targets=("jax",), dtconfig=pm, calib_inputs=(x,))
    ref = JaxWriter(strip_precision(res.graph)).build()(x)
    got = res.executables["jax"](x)
    return float(jnp.mean((jnp.argmax(got, -1) == jnp.argmax(ref, -1))
                          .astype(jnp.float32)))


def test_explorer_is_deterministic(mlp_setup):
    flow, x = mlp_setup
    pm1, hist1 = flow.explore_mixed_precision((x,), ladder=(16, 8, 4, 2),
                                              tol=TOL)
    pm2, hist2 = flow.explore_mixed_precision((x,), ladder=(16, 8, 4, 2),
                                              tol=TOL)
    assert pm1 == pm2
    assert hist1 == hist2


def test_explorer_never_breaches_accuracy_floor(mlp_setup):
    flow, x = mlp_setup
    pm, history = flow.explore_mixed_precision((x,), ladder=(16, 8, 4, 2),
                                               tol=TOL)
    # every accepted move recorded an agreement at or above the floor
    assert all(h["agreement"] >= 1.0 - TOL for h in history)
    # and the returned config, re-evaluated end to end, honours it too
    assert _agreement(flow, pm, x) >= 1.0 - TOL


def test_explorer_accepts_moves_and_monotonic_ladder(mlp_setup):
    flow, x = mlp_setup
    pm, history = flow.explore_mixed_precision((x,), ladder=(16, 8, 4),
                                               tol=0.5)
    assert history, "with tol=0.5 the greedy search must accept moves"
    assert isinstance(pm, PrecisionMap)
    ladder = (16, 8, 4)
    for cfg in pm.per_node.values():
        assert cfg.weight_bits in ladder
    # history replays onto the final bit assignment
    final = {n: 16 for n in pm.per_node}
    for h in history:
        final[h["layer"]] = h["weight_bits"]
    assert final == {n: c.weight_bits for n, c in pm.per_node.items()}


def test_explorer_deterministic_on_cnn_graph():
    """Seed-pinned CNN: the search that feeds Table II's Wauto row is stable
    run-to-run on the fused graph."""
    from repro.models import cnn as cnn_model
    params = cnn_model.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    flow = DesignFlow(g)
    x = jax.random.uniform(jax.random.PRNGKey(1), (16, 28, 28, 1))
    pm1, h1 = flow.explore_mixed_precision((x,), ladder=(16, 8), tol=0.5)
    pm2, h2 = flow.explore_mixed_precision((x,), ladder=(16, 8), tol=0.5)
    assert pm1 == pm2 and h1 == h2
    assert set(pm1.per_node) == {"conv0", "conv1", "fc"}
