"""Resource-constrained design-space exploration: Pareto dominance and
determinism properties, front serialization, budget screening (including
the infeasible error path), the explorer end-to-end on ``separable-cnn``
under a tightened byte ceiling, the typed ``WriterOptions`` surface, and
the unified ``PointSelector`` protocol with its deprecation shims.

Property tests draw from hypothesis when installed; otherwise the same
properties run over a pinned seed sweep (mirrors ``test_conformance``).
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.separable_cnn import CONFIG as SEP
from repro.core.adaptive import (BudgetSelector, FixedSelector, PointSelector,
                                 RuntimePolicy, ServiceObjective,
                                 SLOController, WorkingPoint)
from repro.core.flow import DesignFlow, WriterOptions
from repro.core.reader import separable_cnn_to_ir
from repro.dse import (BudgetInfeasibleError, DesignSpaceExplorer, ParetoFront,
                       ParetoPoint, ResourceBudget, prune_dominated,
                       scratch_bytes_for)
from repro.models import cnn

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 15


def seeded_property(fn):
    """Run ``fn(seed)`` under hypothesis when available, else over a pinned
    seed sweep (same property, deterministic examples)."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=N_EXAMPLES, deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", [1000003 * i + 29
                                            for i in range(N_EXAMPLES)])(fn)


def pt(name="p", bits=8, *, wb=100, fb=10, sb=0, lat=1.0, agree=1.0,
       measured=None):
    return ParetoPoint(WorkingPoint(name, bits), weight_bytes=wb,
                       fifo_bytes=fb, scratch_bytes=sb,
                       predicted_latency_s=lat, agreement=agree,
                       measured_latency_s=measured)


def random_points(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    return [pt(f"p{i}", 8,
               wb=int(rng.integers(1, 5)) * 100,
               fb=int(rng.integers(0, 3)) * 10,
               lat=float(rng.integers(1, 4)),
               agree=float(rng.integers(0, 4)) / 4.0)
            for i in range(n)]


# ---------------------------------------------------------------------------
# dominance + prune properties
# ---------------------------------------------------------------------------


def test_dominates_is_strict():
    a, b = pt("a", wb=100), pt("b", wb=200)
    assert a.dominates(b) and not b.dominates(a)
    # equal objective vectors: neither dominates (strictness)
    c = pt("c", wb=100)
    assert not a.dominates(c) and not c.dominates(a)
    # trade-off: fewer bytes but worse agreement -> incomparable
    d = pt("d", wb=50, agree=0.5)
    assert not a.dominates(d) and not d.dominates(a)


def test_measured_latency_overrides_predicted_in_objectives():
    slow = pt("s", lat=9.0, measured=0.5)
    fast = pt("f", lat=1.0)
    assert slow.latency_s == 0.5
    assert slow.objectives()[1] == 0.5
    # with the measured term the "slow" prediction no longer loses
    assert not fast.dominates(slow)


@seeded_property
def test_prune_dominated_properties(seed):
    """For ANY point set: survivors are mutually non-dominated, every
    removed point is dominated by a survivor, order is preserved, and the
    function is idempotent + deterministic."""
    pts = random_points(seed)
    front = prune_dominated(pts)
    assert front  # a finite set always has at least one non-dominated point
    for p in front:
        assert not any(q.dominates(p) for q in front)
    removed = [p for p in pts if p not in front]
    for p in removed:
        assert any(q.dominates(p) for q in front)
    # order-preserving subsequence of the input
    it = iter(pts)
    assert all(any(p is q for q in it) for p in front)
    assert prune_dominated(front) == front
    assert prune_dominated(pts) == front


def test_prune_keeps_objective_identical_duplicates():
    a, b = pt("a", wb=100), pt("b", wb=100)
    assert prune_dominated([a, b]) == [a, b]


# ---------------------------------------------------------------------------
# front serialization
# ---------------------------------------------------------------------------


def make_front():
    pts = [pt("w8", 8, wb=300, lat=3.0, agree=1.0),
           pt("w4", 4, wb=150, lat=2.0, agree=0.9),
           pt("w2", 2, wb=80, lat=1.0, agree=0.6, measured=0.8)]
    return ParetoFront("toy", pts, act_bits=8, fifo_slack=2.0,
                       per_layer_bits={"conv1": 4}, buckets=(1, 2, 4, 8),
                       budget=ResourceBudget(weight_bytes=400),
                       tuned_tilings=3)


def test_front_json_roundtrip_exact(tmp_path):
    front = make_front()
    again = ParetoFront.from_json(front.to_json())
    assert again.to_json() == front.to_json()
    assert [p.point.name for p in again.points] == ["w8", "w4", "w2"]
    assert again.per_layer_bits == {"conv1": 4}
    assert again.budget.weight_bytes == 400
    assert again.points[2].measured_latency_s == 0.8
    # file round-trip (what CI artifacts and serving deployments load)
    path = tmp_path / "front.json"
    front.save(str(path))
    assert ParetoFront.load(str(path)).to_json() == front.to_json()


def test_front_schema_mismatch_refused():
    d = make_front().to_dict()
    d["schema"] = 999
    with pytest.raises(ValueError, match="schema mismatch"):
        ParetoFront.from_dict(d)


def test_front_orders_points_highest_precision_first():
    pts = [pt("w2", 2, wb=80), pt("w8", 8, wb=300), pt("w4", 4, wb=150)]
    front = ParetoFront("toy", pts)
    assert [p.point.weight_bits for p in front.points] == [8, 4, 2]
    assert [w.name for w in front.working_points()] == ["w8", "w4", "w2"]


def test_front_precision_map_and_run_kwargs():
    front = make_front()
    pm = front.precision_map()
    assert pm.default.act_bits == 8 and pm.default.weight_bits == 8
    assert pm.per_node["conv1"].weight_bits == 4
    kw = front.run_kwargs()
    assert kw["fifo_slack"] == 2.0 and kw["dtconfig"] is not pm


def test_front_selector_kinds():
    front = make_front()
    open_loop = front.selector()
    assert isinstance(open_loop, BudgetSelector)
    assert open_loop.select(1.0).name == "w8"
    assert open_loop.select(0.0).name == "w2"
    closed = front.selector(ServiceObjective(p95_latency_s=1.0))
    assert isinstance(closed, SLOController)
    assert [p.name for p in closed.points] == ["w8", "w4", "w2"]


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------


def test_budget_check_reports_each_violated_term():
    b = ResourceBudget(weight_bytes=100, latency_s=1.0)
    bad = b.check({"weight_bytes": 150, "fifo_bytes": 10,
                   "scratch_bytes": 0, "total_bytes": 160,
                   "predicted_latency_s": 2.0})
    assert bad == {"weight_bytes": (150, 100), "latency_s": (2.0, 1.0)}
    assert not b.check({"weight_bytes": 90, "predicted_latency_s": 0.5})
    assert "weight_bytes=150 > ceiling 100" in b.violations_str(bad)


def test_budget_validation_and_roundtrip():
    with pytest.raises(ValueError, match="must be positive"):
        ResourceBudget(weight_bytes=0)
    with pytest.raises(ValueError, match="max_batch"):
        ResourceBudget(max_batch=0)
    with pytest.raises(ValueError, match="unknown budget terms"):
        ResourceBudget.from_dict({"bram_bytes": 1})
    b = ResourceBudget(total_bytes=1000, max_batch=4)
    assert ResourceBudget.from_dict(b.to_dict()) == b
    assert b.constrained and not ResourceBudget(max_batch=4).constrained


# ---------------------------------------------------------------------------
# explorer end-to-end on separable-cnn (acceptance path)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sep_graph_calib():
    params = cnn.init_separable_params(SEP, jax.random.PRNGKey(1))
    g = separable_cnn_to_ir(SEP, {k: np.asarray(v) for k, v in params.items()})
    shape = (SEP.image_hw[0], SEP.image_hw[1], SEP.in_channels)
    calib = np.random.default_rng(0).random((32, *shape), np.float32)
    return g, calib


@pytest.fixture(scope="module")
def free_front(sep_graph_calib):
    g, calib = sep_graph_calib
    return DesignFlow(g).explore((calib,))


def test_explore_unconstrained_front(free_front):
    names = [p.point.name for p in free_front.points]
    assert len(free_front) >= 3 and names[0] == "w8"
    # mutually non-dominated by construction
    for p in free_front.points:
        assert not any(q.dominates(p) for q in free_front.points)
    # unconstrained search records no budget; slack headroom is free
    assert free_front.budget is None
    assert free_front.fifo_slack == 2.0 and free_front.act_bits == 8
    assert free_front.buckets == (1, 2, 4, 8)


def test_explore_deterministic(sep_graph_calib, free_front):
    g, calib = sep_graph_calib
    again = DesignFlow(g).explore((calib,))
    assert again.to_json() == free_front.to_json()


def test_tightened_byte_ceiling_drops_w8(sep_graph_calib, free_front):
    """The acceptance trajectory: a weight-byte ceiling strictly below the
    free front's top point forces W8 off the front."""
    g, calib = sep_graph_calib
    ceiling = max(p.weight_bytes for p in free_front.points) - 1
    tight = DesignFlow(g).explore(
        (calib,), budget=ResourceBudget(weight_bytes=ceiling))
    names = [p.point.name for p in tight.points]
    assert "w8" not in names and len(tight) >= 1
    assert max(p.weight_bytes for p in tight.points) <= ceiling
    assert (max(p.weight_bytes for p in tight.points)
            < max(p.weight_bytes for p in free_front.points))
    # the binding budget is recorded on the front
    assert tight.budget is not None
    assert tight.budget.weight_bytes == ceiling


def test_infeasible_budget_raises_with_violations(sep_graph_calib):
    g, calib = sep_graph_calib
    with pytest.raises(BudgetInfeasibleError,
                       match="closest candidate") as ei:
        DesignFlow(g).explore((calib,),
                              budget=ResourceBudget(weight_bytes=1))
    assert "weight_bytes" in ei.value.violations
    value, ceiling = ei.value.violations["weight_bytes"]
    assert value > ceiling == 1


def test_front_bytes_match_packed_and_stream_accounting(sep_graph_calib,
                                                        free_front):
    """Every predicted byte term on the front ties back to the measured
    substrate: PackedWeights.view_bytes, StreamWriter.topology, im2col
    scratch at the largest bucket."""
    from repro.core.writers.stream_writer import StreamWriter
    g, calib = sep_graph_calib
    flow = DesignFlow(g)
    res = flow.run(("qjax", "stream"), calib_inputs=(calib,),
                   **free_front.run_kwargs())
    packed = res.writers["qjax"].packed
    caps = free_front.per_layer_bits
    for p in free_front.points:
        assert p.weight_bytes == packed.view_bytes(p.point.weight_bits,
                                                   caps=caps)
    fifo = int(res.writers["stream"].topology()["total_fifo_bytes"])
    assert all(p.fifo_bytes == fifo for p in free_front.points)
    scratch = scratch_bytes_for(res.graph, batch=max(free_front.buckets),
                                act_bytes=1, dw_mode="direct")
    assert all(p.scratch_bytes == scratch for p in free_front.points)


def test_serve_adaptive_consumes_front(sep_graph_calib, free_front):
    g, calib = sep_graph_calib
    res = DesignFlow(g).run(("qjax",), calib_inputs=(calib,),
                            **free_front.run_kwargs())
    srv = res.serve_adaptive(points=free_front, max_batch=4, max_wait=0.0,
                             selector=free_front.selector(
                                 ServiceObjective(p95_latency_s=60.0)))
    tk = srv.submit(calib[:1])
    srv.pump(flush=True)
    assert srv.result(tk).shape[0] == 1
    assert srv.reports[-1].bits == 8          # SLO satisfied: top point
    assert srv.stats()["slo"]["point"] == "w8"


def test_explorer_requires_a_ladder(sep_graph_calib):
    g, calib = sep_graph_calib
    with pytest.raises(ValueError, match="ladder"):
        DesignSpaceExplorer(g, (calib,), ladder=())


def test_front_json_from_explorer_is_loadable(free_front, tmp_path):
    path = tmp_path / "sep_front.json"
    free_front.save(str(path))
    loaded = ParetoFront.load(str(path))
    assert loaded.to_json() == free_front.to_json()
    assert json.loads(free_front.to_json())["graph"] == loaded.graph_name


# ---------------------------------------------------------------------------
# WriterOptions: the typed writer-configuration surface
# ---------------------------------------------------------------------------


def test_writer_options_validate_eagerly():
    with pytest.raises(ValueError, match="dw_mode"):
        WriterOptions(dw_mode="winograd")
    with pytest.raises(ValueError, match="fifo_slack"):
        WriterOptions(fifo_slack=0.0)
    assert WriterOptions(dw_mode="im2col", fifo_slack=1.5).set_fields() == {
        "dw_mode": "im2col", "fifo_slack": 1.5}
    assert WriterOptions().set_fields() == {}


def test_unknown_writer_kwarg_names_the_writer(sep_graph_calib):
    g, calib = sep_graph_calib
    with pytest.raises(ValueError, match=r"'jax'.*JaxWriter"):
        DesignFlow(g).run(("jax",), writer_kwargs={"jax": {"bogus": 1}})


def test_writer_kwargs_for_unknown_target_rejected(sep_graph_calib):
    g, _ = sep_graph_calib
    with pytest.raises(KeyError, match="not in targets"):
        DesignFlow(g).run(("jax",), writer_kwargs={"qjax": {}})


def test_options_reach_accepting_writers_only(sep_graph_calib):
    """One WriterOptions configures a multi-target run: fifo_slack reaches
    the stream writer, dw_mode the qjax writer, and neither leaks into a
    writer that does not accept it."""
    g, calib = sep_graph_calib
    opts = WriterOptions(fifo_slack=3.0, dw_mode="im2col")
    res = DesignFlow(g).run(("jax", "stream", "qjax"), calib_inputs=(calib,),
                            options=opts)
    assert res.writers["stream"].fifo_slack == 3.0
    assert res.writers["qjax"].dw_mode == "im2col"


def test_explicit_writer_kwargs_override_options(sep_graph_calib):
    g, calib = sep_graph_calib
    res = DesignFlow(g).run(
        ("stream",), calib_inputs=(calib,),
        options=WriterOptions(fifo_slack=3.0),
        writer_kwargs={"stream": {"fifo_slack": 1.0}})
    assert res.writers["stream"].fifo_slack == 1.0


# ---------------------------------------------------------------------------
# PointSelector protocol + deprecation shims
# ---------------------------------------------------------------------------

POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


def test_selector_protocol_instances():
    sel = BudgetSelector(list(POINTS))
    ctl = SLOController(POINTS, ServiceObjective(p95_latency_s=1.0))
    fix = FixedSelector(POINTS[1])
    pol = RuntimePolicy(list(POINTS))
    for s in (sel, ctl, fix, pol):
        assert isinstance(s, PointSelector)


def test_runtime_policy_shim_matches_budget_selector():
    """The deprecation shim: RuntimePolicy.select(energy_budget_frac) is
    exactly BudgetSelector.select(budget) for every budget."""
    pol = RuntimePolicy(list(POINTS))
    sel = BudgetSelector(list(POINTS))
    for frac in np.linspace(0.0, 1.0, 21):
        assert pol.select(float(frac)) is not None
        assert (pol.select(energy_budget_frac=float(frac)).name
                == sel.select(budget=float(frac)).name)
    # explicit thresholds behave identically through both surfaces
    pol = RuntimePolicy(list(POINTS), thresholds=[0.8, 0.3])
    sel = BudgetSelector(list(POINTS), thresholds=[0.8, 0.3])
    for frac in (0.0, 0.2, 0.3, 0.5, 0.8, 0.9, 1.0):
        assert pol.select(frac).name == sel.select(frac).name
    assert pol.select(0.9).name == "w8"
    assert pol.select(0.5).name == "w4"
    assert pol.select(0.1).name == "w2"


def test_fixed_selector_pins_one_point():
    fix = FixedSelector(POINTS[2])
    assert fix.points == [POINTS[2]]
    for frac in (0.0, 0.5, 1.0):
        assert fix.select(frac).name == "w2"
    fix.observe(1.0)                           # protocol no-op, must not raise


def test_slo_controller_select_accepts_protocol_budget_arg():
    ctl = SLOController(POINTS, ServiceObjective(p95_latency_s=1.0))
    # closed-loop: the budget argument is accepted (protocol) and ignored
    assert ctl.select().name == ctl.select(0.0).name == "w8"
