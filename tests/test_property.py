"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.tokens import DataConfig, batch_at
from repro.models.common import cross_entropy
from repro.quant.fixedpoint import dequantize, quantize
from repro.quant.pack import (pack_int2, pack_int4, pack_rows, unpack_int2,
                              unpack_int4, unpack_rows)
from repro.quant.ptq import derive_view
from repro.quant.qtypes import fixed_for_range

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.lists(st.integers(-8, 7), min_size=2, max_size=64).filter(
    lambda v: len(v) % 2 == 0))
@settings(**SETTINGS)
def test_pack4_roundtrip(codes):
    c = jnp.array(codes, jnp.int8).reshape(1, -1)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(c))),
                                  np.asarray(c))


@given(st.lists(st.integers(-2, 1), min_size=4, max_size=64).filter(
    lambda v: len(v) % 4 == 0))
@settings(**SETTINGS)
def test_pack2_roundtrip(codes):
    c = jnp.array(codes, jnp.int8).reshape(1, -1)
    np.testing.assert_array_equal(np.asarray(unpack_int2(pack_int2(c))),
                                  np.asarray(c))


@given(st.integers(1, 300), st.integers(1, 8), st.sampled_from([4, 2]),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pack_rows_roundtrip_property(k, n, bits, seed):
    """Split-row sub-byte packing: for ANY int8 code matrix,
    ``unpack(pack(c))`` equals the nested ``bits``-bit view on the original
    rows and is exactly zero on the alignment-padding rows."""
    rng = np.random.default_rng(seed)
    c = rng.integers(-127, 128, (k, n)).astype(np.int8)
    up = np.asarray(unpack_rows(pack_rows(c, bits), bits))
    assert up.shape[0] % 128 == 0 and up.shape[0] >= k
    np.testing.assert_array_equal(up[:k],
                                  np.asarray(derive_view(jnp.asarray(c), bits)))
    assert not up[k:].any()


@given(st.floats(0.01, 100.0), st.sampled_from([4, 8, 16]))
@settings(**SETTINGS)
def test_fixed_for_range_quantization_error_bound(max_abs, bits):
    """|dequant(quant(x)) - x| <= scale/2 + saturation-free inside the range."""
    qt = fixed_for_range(bits, max_abs)
    xs = jnp.linspace(-max_abs, max_abs, 33)
    deq = dequantize(quantize(xs, qt), qt)
    assert float(jnp.max(jnp.abs(deq - xs))) <= qt.scale * 1.001


@given(st.integers(-127, 127), st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_derive_view_idempotent_and_bounded(code, bits):
    c = jnp.array([code], jnp.int8)
    v = derive_view(c, bits)
    np.testing.assert_array_equal(np.asarray(derive_view(v, bits)),
                                  np.asarray(v))  # idempotent
    assert abs(int(v[0]) - code) <= (1 << (8 - bits))  # truncation bound


@given(st.integers(0, 10_000), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_data_stream_deterministic_and_step_unique(s1, s2):
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=2, seed=1)
    b1 = batch_at(cfg, s1)
    b1b = batch_at(cfg, s1)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b1b["tokens"]))
    if s1 != s2:
        b2 = batch_at(cfg, s2)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


@given(st.integers(2, 64))
@settings(**SETTINGS)
def test_cross_entropy_ignores_padded_vocab(vocab):
    """Logits in the padded region must not affect the loss."""
    pad = 16
    key = jax.random.PRNGKey(vocab)
    logits = jax.random.normal(key, (2, 3, vocab + pad))
    labels = jax.random.randint(key, (2, 3), 0, vocab)
    l1 = cross_entropy(logits, labels, vocab)
    noised = logits.at[..., vocab:].add(100.0)
    l2 = cross_entropy(noised, labels, vocab)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@given(st.integers(1, 6), st.integers(1, 6))
@settings(**SETTINGS)
def test_ir_random_dag_topo_valid(n_gemm, n_relu):
    """Random chain DAGs always topo-sort with deps satisfied."""
    from repro.core.ir import Graph, Node, TensorInfo
    nodes, prev = [], "input"
    inits = {}
    for i in range(n_gemm):
        w = f"w{i}"
        inits[w] = np.zeros((4, 4), np.float32)
        nodes.append(Node("MatMul", f"g{i}", [prev, w], [f"t{i}"]))
        prev = f"t{i}"
        for j in range(min(n_relu, 2)):
            nodes.append(Node("Relu", f"r{i}_{j}", [prev], [f"t{i}_{j}"]))
            prev = f"t{i}_{j}"
    g = Graph("rand", nodes[::-1], [TensorInfo("input", (1, 4))], [prev], inits)
    seen = {"input"} | set(inits)
    for n in g.topo_order():
        assert all(i in seen for i in n.inputs)
        seen.update(n.outputs)


@given(st.sampled_from([2, 4, 8, 16]), st.floats(0.05, 4.0))
@settings(**SETTINGS)
def test_quantize_monotone(bits, scale):
    """Quantization preserves ordering (monotone non-decreasing)."""
    qt = fixed_for_range(bits, scale)
    xs = jnp.sort(jax.random.normal(jax.random.PRNGKey(bits), (32,)) * scale)
    q = quantize(xs, qt)
    assert bool(jnp.all(jnp.diff(q) >= 0))
