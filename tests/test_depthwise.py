"""Direct depthwise conv path: kernel-vs-ref bit-exactness in the integer
code domain, W8/W4/W2 nested views with sub-byte packed tap rows, grouped
Conv ingest (reader normalization + shape inference), DW+BN+Relu fusion and
the Relu->MaxPool reordering pass, the qjax writer's direct-vs-im2col
differential, and the versioned autotune disk cache for ``dw:`` keys."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.separable_cnn import CONFIG as SEP
from repro.core.flow import DesignFlow
from repro.core.ir import BATCH, Graph, Node, TensorInfo
from repro.core.passes import PassManager, structural_pipeline
from repro.core.passes.fusion import reorder_relu_maxpool
from repro.core.passes.shape_infer import infer_shapes
from repro.core.reader import normalize_groups, separable_cnn_to_ir
from repro.core.writers.jax_writer import JaxWriter
from repro.core.writers.qjax_writer import QJaxWriter
from repro.kernels import autotune
from repro.kernels.qconv_dw import ops as dwops
from repro.kernels.qconv_dw.ops import (DW_PACK_ALIGN, pick_blocks_dw,
                                        qconv_dw, qconv_dw_int8_act)
from repro.kernels.qconv_dw.ref import (expand_dw_codes, out_spatial,
                                        qconv_dw_int8_act_ref, qconv_dw_ref)
from repro.models import cnn
from repro.quant.pack import pack_rows, unpack_rows
from repro.quant.ptq import derive_view
from repro.quant.qtypes import DatatypeConfig


def _dw_problem(seed=0, B=2, H=9, W=9, C=8, k=3):
    key = jax.random.PRNGKey(seed)
    kx, kw_, ks, kb = jax.random.split(key, 4)
    x_codes = jax.random.randint(kx, (B, H, W, C), -127, 128, jnp.int8)
    codes = jax.random.randint(kw_, (k * k, C), -127, 128, jnp.int8)
    scale = (jax.random.uniform(ks, (C,)) * 0.05 + 0.01).astype(jnp.float32)
    bias = (jax.random.normal(kb, (C,)) * 0.1).astype(jnp.float32)
    x_scale = 2.0 ** -6          # the calibrated pow2 activation-code scale
    return x_codes, x_scale, codes, scale, bias


def _sep_graph(seed=0):
    params = cnn.init_separable_params(SEP, jax.random.PRNGKey(seed))
    return separable_cnn_to_ir(
        SEP, {k: np.asarray(v) for k, v in params.items()})


# ---------------------------------------------------------------------------
# kernel vs ref: the integer code domain is bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,packed", [
    (8, False), (4, False), (2, False), (4, True), (2, True)])
def test_dw_int8_act_kernel_bitexact_vs_ref(bits, packed):
    """Forced interpret-mode direct kernel vs the integer oracle: identical
    int32 window MACs + pow2 scale folds -> array_equal, not allclose."""
    x_codes, xs, codes, scale, bias = _dw_problem(bits)
    w_arg = pack_rows(codes, bits, align=DW_PACK_ALIGN) if packed else codes
    kw = dict(kh=3, kw=3, strides=(1, 1), pads="SAME", bits=bits,
              relu=True, act_qt=(10, -(2 ** 15), 2 ** 15 - 1))
    y_k = qconv_dw_int8_act(x_codes, xs, w_arg, scale, bias, packed=packed,
                            interpret=True, use_kernel=True, **kw)
    y_r = qconv_dw_int8_act_ref(x_codes, xs, codes, scale, bias, **kw)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("strides,pads", [
    ((1, 1), "VALID"), ((2, 2), "SAME"), ((2, 2), "VALID"), ((1, 2), "SAME")])
def test_dw_int8_act_strides_and_pads_bitexact(strides, pads):
    # no bias: the jitted kernel may fma-contract acc*s + bias while the
    # eager oracle rounds twice — this test isolates the spatial indexing
    x_codes, xs, codes, scale, _ = _dw_problem(7, H=11, W=10)
    kw = dict(kh=3, kw=3, strides=strides, pads=pads, bits=8)
    y_k = qconv_dw_int8_act(x_codes, xs, codes, scale, None,
                            interpret=True, use_kernel=True, **kw)
    y_r = qconv_dw_int8_act_ref(x_codes, xs, codes, scale, None, **kw)
    assert y_k.shape == y_r.shape == (
        2, *out_spatial(11, 10, 3, 3, strides, pads)[:2], 8)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_dw_out_code_emits_consumer_int8_codes(bits):
    """``out_code=True`` requantizes in the fused epilogue — the depthwise
    stage never leaves the code domain."""
    x_codes, xs, codes, scale, bias = _dw_problem(3)
    kw = dict(kh=3, kw=3, bits=bits, relu=True, act_qt=(4, -127, 127),
              out_code=True)
    y_k = qconv_dw_int8_act(x_codes, xs, codes, scale, bias,
                            interpret=True, use_kernel=True, **kw)
    y_r = qconv_dw_int8_act_ref(x_codes, xs, codes, scale, bias, **kw)
    assert y_k.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_dw_fallback_path_is_the_ref():
    # bias-free: the jitted wrapper may fma-contract the epilogue the eager
    # oracle rounds in two steps; dispatch, unpacking and MACs stay exact
    x_codes, xs, codes, scale, _ = _dw_problem(5)
    packed = pack_rows(codes, 4, align=DW_PACK_ALIGN)
    y_f = qconv_dw_int8_act(x_codes, xs, packed, scale, None, kh=3, kw=3,
                            bits=4, packed=True, use_kernel=False)
    y_r = qconv_dw_int8_act_ref(x_codes, xs, codes, scale, None, kh=3, kw=3,
                                bits=4)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_r))


@pytest.mark.parametrize("bits", [8, 4])
def test_dw_float_kernel_matches_ref_to_ulp(bits):
    """Float-activation path: identical window products (f32, fixed-point
    exact), but XLA may fma-contract the scale/bias epilogue — ulp-of-max
    tolerance, the same contract qmatmul's float path carries."""
    x = jax.random.uniform(jax.random.PRNGKey(11), (2, 9, 9, 8), jnp.float32)
    _, _, codes, scale, bias = _dw_problem(11)
    kw = dict(kh=3, kw=3, bits=bits, relu=True)
    y_k = qconv_dw(x, codes, scale, bias, interpret=True, use_kernel=True,
                   **kw)
    y_r = qconv_dw_ref(x, codes, scale, bias, **kw)
    tol = float(jnp.max(jnp.abs(y_r))) * 2 ** -22 + 1e-9
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=tol)


def test_dw_nested_views_truncate_master_codes():
    """W4/W2 outputs are functions of the truncated master codes alone: the
    kernel at ``bits`` equals the ref fed the pre-truncated view at 8 bits
    with the matching scale fold."""
    x_codes, xs, codes, scale, _ = _dw_problem(9)
    for bits in (4, 2):
        view = derive_view(codes, bits)            # codes >> (8-bits)
        y_b = qconv_dw_int8_act(x_codes, xs, codes, scale, None, kh=3, kw=3,
                                bits=bits, interpret=True, use_kernel=True)
        y_v = qconv_dw_int8_act_ref(x_codes, xs, view, scale, None,
                                    kh=3, kw=3, bits=8)
        np.testing.assert_array_equal(np.asarray(y_b), np.asarray(y_v))


def test_dw_pack_rows_align8_byte_accounting():
    """Depthwise tap rows pack at align=8 (not the matmul tile's 128): a 3x3
    window stores 16 aligned rows, and unpack restores the row order."""
    codes = jax.random.randint(jax.random.PRNGKey(0), (9, 8), -127, 128,
                               jnp.int8)
    for bits, rows in ((4, 8), (2, 4)):
        p = pack_rows(codes, bits, align=DW_PACK_ALIGN)
        assert p.shape == (rows, 8)                # align(9,8)=16, /ratio
        got = unpack_rows(p, bits)[:9]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(derive_view(codes, bits)))


def test_expand_dw_codes_is_block_diagonal():
    codes = jax.random.randint(jax.random.PRNGKey(1), (3, 3, 1, 4), -127,
                               128, jnp.int8)
    dense = np.asarray(expand_dw_codes(codes))
    taps = np.asarray(codes).reshape(9, 4)
    assert dense.shape == (9 * 4, 4)
    for t in range(9):
        block = dense[t * 4:(t + 1) * 4]
        np.testing.assert_array_equal(np.diag(block), taps[t])
        assert np.count_nonzero(block - np.diag(np.diag(block))) == 0


# ---------------------------------------------------------------------------
# autotune: dw keys in the versioned shared disk cache
# ---------------------------------------------------------------------------

def test_dw_autotune_schema_gate_and_arity(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.AUTOTUNE_CACHE_ENV, str(cache))
    dwops._BC_CACHE.clear()
    shape = dict(B=2, oh=9, Wpp=24, Cp=128, kh=3, kw=3, sh=1, sw=1)
    dk = dwops._disk_key_dw(**shape, bits=8, int8_act=True, packed=False)
    # a stale pre-versioned flat file (the PR-5 format) loads as empty
    cache.write_text(json.dumps({dk: [64]}))
    assert autotune.disk_cache() == {}
    pick = dict(kh=3, kw=3, sh=1, sw=1, oh=9, ow=16, w_rows=16, bits=8,
                interpret=False, int8_act=True)
    # wrong-arity entry (qmatmul's 3-tuple under a dw key) is ignored, not
    # returned mis-shaped: the pick falls through to the static default
    autotune.disk_put(dk, (512, 256, 128))
    assert pick_blocks_dw(2, 12, 24, 128, **pick) == 128
    # a well-formed 1-tuple round-trips through the schema envelope
    dwops._BC_CACHE.clear()
    autotune.disk_put(dk, (64,))
    raw = json.loads(cache.read_text())
    assert raw["schema"] == autotune.CACHE_SCHEMA
    assert raw["entries"][dk] == [64]
    assert pick_blocks_dw(2, 12, 24, 128, **pick) == 64
    dwops._BC_CACHE.clear()


def test_dw_autotune_interpret_mode_skips_disk():
    dwops._BC_CACHE.clear()
    bc = pick_blocks_dw(1, 12, 24, 256, kh=3, kw=3, sh=1, sw=1, oh=9, ow=16,
                        w_rows=16, bits=8, interpret=True)
    assert bc == 128                               # static default, no timing
    dwops._BC_CACHE.clear()


# ---------------------------------------------------------------------------
# reader: ONNX group attribute normalization
# ---------------------------------------------------------------------------

def _group_graph(w_shape, group, weight_as_input=False):
    inits = {"w": np.random.default_rng(0).normal(
        size=w_shape).astype(np.float32)}
    inputs = [TensorInfo("input", (BATCH, 8, 8, w_shape[2] * group
                                   if w_shape[2] != 1 else w_shape[3]))]
    w_in = "w"
    if weight_as_input:
        inputs.append(TensorInfo("w", w_shape))
        inits = {}
    g = Graph("grp", [
        Node("Conv", "c", ["input", w_in], ["out"],
             {"kernel_shape": [w_shape[0], w_shape[1]], "pads": "SAME",
              "strides": [1, 1], "group": group}),
    ], inputs, ["out"], inits)
    return g


def test_reader_group_one_is_plain_conv():
    g = normalize_groups(_group_graph((3, 3, 4, 8), 1))
    (node,) = g.nodes
    assert node.op == "Conv" and "group" not in node.attrs


def test_reader_group_cin_becomes_depthwise():
    g = normalize_groups(_group_graph((3, 3, 1, 16), 16))
    (node,) = g.nodes
    assert node.op == "DepthwiseConv" and "group" not in node.attrs


def test_reader_rejects_general_grouped_conv():
    with pytest.raises(ValueError, match="not depthwise"):
        normalize_groups(_group_graph((3, 3, 2, 8), 4))


def test_reader_rejects_activation_fed_grouped_weight():
    with pytest.raises(ValueError, match="activation-fed"):
        normalize_groups(_group_graph((3, 3, 1, 16), 16,
                                      weight_as_input=True))


# ---------------------------------------------------------------------------
# shape inference: grouped rule, symbolic batch
# ---------------------------------------------------------------------------

def test_depthwise_shape_inference_symbolic_batch():
    inits = {"w": np.zeros((3, 3, 1, 16), np.float32),
             "b": np.zeros((16,), np.float32)}
    g = Graph("dw", [
        Node("DepthwiseConv", "d", ["input", "w", "b"], ["out"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [2, 2]}),
    ], [TensorInfo("input", (BATCH, 15, 15, 16))], ["out"], inits)
    infer_shapes(g)
    assert g.value_info["out"].shape == (BATCH, 8, 8, 16)


def test_depthwise_shape_inference_rejects_channel_mismatch():
    inits = {"w": np.zeros((3, 3, 1, 8), np.float32)}
    g = Graph("dw", [
        Node("DepthwiseConv", "d", ["input", "w"], ["out"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}),
    ], [TensorInfo("input", (BATCH, 8, 8, 16))], ["out"], inits)
    with pytest.raises(ValueError):
        infer_shapes(g)


def test_shape_inference_rejects_unnormalized_grouped_conv():
    inits = {"w": np.zeros((3, 3, 1, 16), np.float32)}
    g = Graph("grp", [
        Node("Conv", "c", ["input", "w"], ["out"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1],
              "group": 16}),
    ], [TensorInfo("input", (BATCH, 8, 8, 16))], ["out"], inits)
    with pytest.raises(ValueError, match="normalize_groups"):
        infer_shapes(g)


# ---------------------------------------------------------------------------
# passes: DW+BN+Relu fusion, Relu->MaxPool reordering
# ---------------------------------------------------------------------------

def test_separable_pipeline_fuses_and_reorders():
    g = _sep_graph()
    g2 = PassManager(structural_pipeline()).run(g)
    ops = [n.op for n in g2.topo_order()]
    assert ops.count("FusedDepthwiseConv") == len(SEP.blocks)
    assert "BatchNormalization" not in ops
    # the stem's Relu -> MaxPool chain got swapped: pool first, fewer relus
    order = [n.name for n in g2.topo_order()]
    assert order.index("stem_pool") < order.index("stem_relu")
    # numerics survive the whole pipeline (BN fold is f64: tiny tolerance)
    x = np.random.default_rng(0).random((2, 28, 28, 1)).astype(np.float32)
    y_raw = np.asarray(JaxWriter(g).build()(x))
    y_opt = np.asarray(JaxWriter(g2).build()(x))
    np.testing.assert_allclose(y_opt, y_raw,
                               atol=1e-5 * max(1.0, np.abs(y_raw).max()))


def test_reorder_relu_maxpool_is_exact():
    """Relu commutes with the max window: the swapped graph is bit-identical,
    and the moved pool renames its output so FIFO labels stay unique."""
    inits = {"w": np.random.default_rng(1).normal(
        size=(3, 3, 2, 4)).astype(np.float32)}
    g = Graph("rm", [
        Node("Conv", "c", ["input", "w"], ["c_out"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}),
        Node("Relu", "r", ["c_out"], ["r_out"]),
        Node("MaxPool", "p", ["r_out"], ["p_out"],
             {"kernel_shape": [2, 2], "strides": [2, 2]}),
    ], [TensorInfo("input", (BATCH, 8, 8, 2))], ["p_out"], inits)
    x = np.random.default_rng(2).standard_normal((3, 8, 8, 2)).astype(
        np.float32)
    y_raw = np.asarray(JaxWriter(g).build()(x))
    g2 = reorder_relu_maxpool(g)
    order = [(n.op, n.name) for n in g2.topo_order()]
    assert order == [("Conv", "c"), ("MaxPool", "p"), ("Relu", "r")]
    y_sw = np.asarray(JaxWriter(infer_shapes(g2)).build()(x))
    np.testing.assert_array_equal(y_sw, y_raw)


def test_reorder_skips_fanout_relu():
    """A Relu with a second consumer must keep feeding it pre-pool."""
    inits = {"w": np.random.default_rng(1).normal(
        size=(3, 3, 2, 2)).astype(np.float32)}
    g = Graph("fan", [
        Node("Conv", "c", ["input", "w"], ["c_out"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}),
        Node("Relu", "r", ["c_out"], ["r_out"]),
        Node("MaxPool", "p", ["r_out"], ["p_out"],
             {"kernel_shape": [2, 2], "strides": [2, 2]}),
        Node("Flatten", "f", ["r_out"], ["flat"]),
    ], [TensorInfo("input", (BATCH, 8, 8, 2))], ["p_out", "flat"], inits)
    g2 = reorder_relu_maxpool(g)
    assert [(n.op, n.name) for n in g2.topo_order()] == \
        [("Conv", "c"), ("Relu", "r"), ("MaxPool", "p"), ("Flatten", "f")]


# ---------------------------------------------------------------------------
# writer: direct vs im2col differential at D8 — the kill-im2col proof
# ---------------------------------------------------------------------------

def _d8_flow(g, calib, dw_mode, **wkw):
    return DesignFlow(g).run(
        targets=("qjax",), dtconfig=DatatypeConfig(8, 8),
        calib_inputs=(calib,),
        writer_kwargs={"qjax": {"dw_mode": dw_mode, **wkw}})


def test_writer_direct_vs_im2col_bitexact_at_d8():
    """Same D8 integer graph, depthwise lowered direct vs through the dense
    block-diagonal im2col+qgemm reference: identical int32 accumulators and
    pow2 folds -> every output bit matches."""
    g = _sep_graph()
    rng = np.random.default_rng(0)
    calib = rng.random((2, 28, 28, 1), np.float32)
    x = rng.random((3, 28, 28, 1), np.float32)
    y_dir = np.asarray(_d8_flow(g, calib, "direct").batched["qjax"](x))
    y_im = np.asarray(_d8_flow(g, calib, "im2col").batched["qjax"](x))
    np.testing.assert_array_equal(y_dir, y_im)


def test_writer_direct_kernel_vs_im2col_bitexact_forced_interpret():
    """The differential holds on the forced Pallas kernel path too."""
    g = _sep_graph(1)
    rng = np.random.default_rng(1)
    calib = rng.random((2, 28, 28, 1), np.float32)
    x = rng.random((1, 28, 28, 1), np.float32)
    kw = dict(use_kernel=True, interpret=True)
    y_dir = np.asarray(_d8_flow(g, calib, "direct", **kw).batched["qjax"](x))
    y_im = np.asarray(_d8_flow(g, calib, "im2col", **kw).batched["qjax"](x))
    np.testing.assert_array_equal(y_dir, y_im)


def test_writer_validates_dw_mode():
    with pytest.raises(ValueError, match="dw_mode"):
        QJaxWriter(_sep_graph(), DatatypeConfig(8, 8), dw_mode="magic")


def test_separable_d8_agrees_with_float_reference():
    """End to end: the fully-integer separable network tracks the f32
    fake-quant reference to quantization tolerance."""
    g = _sep_graph()
    rng = np.random.default_rng(3)
    calib = rng.random((2, 28, 28, 1), np.float32)
    res = DesignFlow(g).run(targets=("jax", "qjax"),
                            dtconfig=DatatypeConfig(8, 8),
                            calib_inputs=(calib,))
    x = rng.random((4, 28, 28, 1), np.float32)
    y_ref = np.asarray(res.batched["jax"](x))
    y_int = np.asarray(res.batched["qjax"](x))
    scale = np.max(np.abs(y_ref)) + 1e-9
    # 9 quantized layers deep with untrained (near-zero) logits: the error
    # budget is a handful of final-FIFO code steps, ~10% of the tiny range
    assert np.max(np.abs(y_ref - y_int)) / scale < 0.12
