"""Batch-coalescing serving runtime: deterministic scheduler behaviour
(fake clock), bucket-vs-LRU selection, pad/slice-back round-trips, precision
working-point selection, and the differential property that coalesced
execution equals naive per-request execution.
"""

import jax
import numpy as np
import pytest

from repro.core.adaptive import RuntimePolicy, WorkingPoint
from repro.core.flow import DesignFlow
from repro.core.reader import mlp_to_ir
from repro.runtime.scheduler import (
    BucketPolicy,
    CoalescingScheduler,
    QueueFull,
)
from repro.runtime.serve import AccelServer


class FakeClock:
    """Injected monotonic clock: tests advance time explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def mlp_flow(seed=0, feat=6, hidden=12, classes=4):
    rng = np.random.default_rng(seed)
    sizes = [feat, hidden, classes]
    params = {}
    for i in range(len(sizes) - 1):
        params[f"fc{i}/w"] = rng.normal(size=(sizes[i], sizes[i + 1])).astype(
            np.float32
        )
        params[f"fc{i}/b"] = rng.normal(size=(sizes[i + 1],)).astype(np.float32)
    return DesignFlow(mlp_to_ir(sizes, params)).run()


def req(size, feat=6, seed=0):
    return np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed * 1000 + size), (size, feat))
    )


# ---------------------------------------------------------------------------
# scheduler (pure host logic, fake clock)
# ---------------------------------------------------------------------------


def test_max_wait_flushes_partial_batch():
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=8, max_wait=0.01, clock=clock)
    sched.submit((req(2),))
    assert sched.ready() is None  # partial batch: keep waiting
    clock.advance(0.005)
    assert sched.ready() is None  # still inside max_wait
    clock.advance(0.006)
    batch = sched.ready()
    assert batch is not None and batch.size == 2 and len(batch.requests) == 1
    assert len(sched) == 0


def test_full_batch_flushes_without_waiting():
    sched = CoalescingScheduler(max_batch=4, max_wait=1e9, clock=FakeClock())
    for _ in range(2):
        sched.submit((req(2),))
    batch = sched.ready()
    assert batch is not None and batch.size == 4 and batch.padding == 0


def test_oversubscribed_queue_closes_batch_early():
    # 5 + 4 > max_batch: the head batch is as full as it can get, so it
    # flushes immediately instead of waiting out max_wait
    sched = CoalescingScheduler(max_batch=8, max_wait=1e9, clock=FakeClock())
    a = sched.submit((req(5),))
    b = sched.submit((req(4),))
    batch = sched.ready()
    assert [r.rid for r in batch.requests] == [a.rid]
    assert batch.bucket == 8  # 5 rows pad to the ladder bucket
    assert sched.ready() is None  # the 4-row tail keeps waiting
    batch2 = sched.ready(flush=True)
    assert [r.rid for r in batch2.requests] == [b.rid]


def test_fifo_order_preserved_across_batches():
    sched = CoalescingScheduler(max_batch=4, max_wait=0.0, clock=FakeClock())
    rids = [sched.submit((req(2, seed=i),)).rid for i in range(4)]
    seen = []
    for batch in sched.drain():
        seen.extend(r.rid for r in batch.requests)
    assert seen == rids


def test_queue_depth_backpressure():
    sched = CoalescingScheduler(max_batch=8, queue_depth=2, clock=FakeClock())
    sched.submit((req(1),))
    sched.submit((req(1),))
    with pytest.raises(QueueFull):
        sched.submit((req(1),))


def test_submit_validation():
    sched = CoalescingScheduler(max_batch=4, clock=FakeClock())
    with pytest.raises(ValueError, match="leading dim"):
        sched.submit((req(2), req(3)))
    with pytest.raises(ValueError, match="no inputs"):
        sched.submit(())


def test_oversize_submit_splits_into_chunks():
    """A request larger than max_batch no longer raises: it splits into
    back-to-back chunk requests and returns a parent carrying their rids."""
    sched = CoalescingScheduler(max_batch=4, max_wait=0.0, clock=FakeClock())
    parent = sched.submit((req(10),))
    assert parent.size == 10 and len(parent.children) == 3
    assert len(sched) == 3  # only the chunks are queued
    sizes = [r.size for r in sched._queue]
    assert sizes == [4, 4, 2]
    # chunks drain contiguously in arrival order
    seen = []
    for batch in sched.drain():
        seen.extend(r.rid for r in batch.requests)
    assert seen == parent.children
    s = sched.stats()
    assert s["split_requests"] == 1 and s["split_chunks"] == 3
    assert s["submitted"] == 1


def test_oversize_submit_respects_queue_depth_atomically():
    sched = CoalescingScheduler(max_batch=4, queue_depth=2, clock=FakeClock())
    with pytest.raises(QueueFull):
        sched.submit((req(12),))  # needs 3 chunk slots, only 2 exist
    assert len(sched) == 0  # nothing partially enqueued


def test_mismatched_request_signature_rejected_at_submit():
    """A request whose arity / trailing shape / dtype differs from the served
    artifact's cannot share a padded column — it must be rejected up front,
    not poison the batch it would have coalesced into."""
    sched = CoalescingScheduler(max_batch=8, clock=FakeClock())
    sched.submit((req(2, feat=6),))
    with pytest.raises(ValueError, match="signature"):
        sched.submit((req(2, feat=5),))  # trailing shape differs
    with pytest.raises(ValueError, match="signature"):
        sched.submit((req(2), req(2)))  # arity differs
    with pytest.raises(ValueError, match="signature"):
        sched.submit((req(2).astype(np.float64),))  # dtype differs
    sched.submit((req(3, feat=6),))  # matching request still accepted


def test_flow_serve_locks_signature_to_the_artifact():
    """FlowResult.serve passes the graph's input spec down, so a malformed
    FIRST request is rejected immediately instead of poisoning the lock for
    every correctly-shaped request after it."""
    res = mlp_flow()  # 6-feature MLP
    srv = res.serve(max_batch=8, max_wait=0.0)
    with pytest.raises(ValueError, match="served artifact"):
        srv.submit(req(2, feat=5))  # wrong trailing shape, never enqueued
    t = srv.submit(req(2, feat=6))  # the server is not poisoned
    assert np.asarray(srv.result(t)).shape == (2, 4)


def test_failed_batch_resolves_member_tickets_to_errors():
    """An executable failure must not lose the batch's tickets: pump raises,
    but every member resolves to a per-ticket error, and the server keeps
    serving afterwards."""

    class Flaky:
        fail = True

        def __call__(self, x):
            if self.fail:
                raise RuntimeError("device fell over")
            return x

    exe = Flaky()
    srv = AccelServer(exe, max_batch=8, max_wait=0.0, clock=FakeClock())
    ta, tb = srv.submit(req(2)), srv.submit(req(3))
    with pytest.raises(RuntimeError, match="device fell over"):
        srv.pump(flush=True)
    for t in (ta, tb):
        with pytest.raises(RuntimeError, match="batch execution failed"):
            srv.result(t)
    exe.fail = False  # transient failure clears: later requests serve fine
    tc = srv.submit(req(2, seed=9))
    assert np.asarray(srv.result(tc)).shape == (2, 6)


# ---------------------------------------------------------------------------
# bucket policy vs the executable's LRU
# ---------------------------------------------------------------------------


def test_bucket_ladder_defaults_to_powers_of_two():
    pol = BucketPolicy(max_batch=8)
    assert pol.buckets == (1, 2, 4, 8)
    assert BucketPolicy(max_batch=6).buckets == (1, 2, 4, 6)
    with pytest.raises(ValueError, match="exceed max_batch"):
        BucketPolicy(buckets=(16,), max_batch=8)  # would pad every batch 2x


def test_bucket_prefers_cached_size_when_padding_no_worse():
    pol = BucketPolicy(max_batch=8)
    assert pol.bucket_for(3, cached=()) == 4  # ladder
    assert pol.bucket_for(3, cached=(3,)) == 3  # exact trace resident: reuse
    assert pol.bucket_for(3, cached=(8,)) == 4  # cached 8 pads worse: ladder
    assert pol.bucket_for(5, cached=(6,)) == 6  # 6 <= ladder 8: hit wins
    assert pol.bucket_for(5, cached=(6, 7)) == 6  # smallest fitting hit
    assert pol.bucket_for(2, cached=(2, 4)) == 2


def test_scheduler_bucket_tracks_lru_contents():
    sched = CoalescingScheduler(max_batch=8, max_wait=0.0, clock=FakeClock())
    sched.submit((req(3),))
    assert sched.ready(cached=(3, 8)).bucket == 3
    sched.submit((req(3),))
    assert sched.ready(cached=(8,)).bucket == 4


def test_server_reuses_prewarmed_trace_instead_of_retracing():
    res = mlp_flow()
    exe = res.batched["jax"]
    exe(req(4))  # pre-warm a batch-4 trace
    assert exe.misses == 1 and exe.cached_batches == (4,)
    srv = AccelServer(exe, max_batch=8, max_wait=0.0)
    srv.submit(req(3))
    srv.pump(flush=True)
    # 3 useful rows ride the resident batch-4 trace: a hit, not a retrace
    assert exe.misses == 1 and exe.hits == 1
    assert srv.reports[-1].bucket == 4 and srv.reports[-1].padding == 1


def test_on_compile_hook_observes_trace_misses():
    res = mlp_flow()
    seen = []
    exe = res.writers["jax"].build_batched(on_compile=seen.append)
    srv = AccelServer(exe, max_batch=8, max_wait=0.0)
    for size in (1, 2, 1):
        srv.submit(req(size, seed=size))
        srv.pump(flush=True)
    assert [sig[0][0][0] for sig in seen] == [1, 2]  # batch-1 retrace avoided


# ---------------------------------------------------------------------------
# pad / slice-back and differential conformance
# ---------------------------------------------------------------------------


def assert_matches(actual, desired):
    """Coalesced vs per-request outputs agree to float32 rounding: executing
    at a different batch size may legally change XLA's reduction order by an
    ulp, so "equal" means ulp-level closeness, not bitwise identity."""
    np.testing.assert_allclose(
        np.asarray(actual), np.asarray(desired), rtol=1e-5, atol=1e-6
    )


def test_pad_slice_back_roundtrip_is_exact():
    res = mlp_flow()
    srv = res.serve(max_batch=8, max_wait=0.0)
    x = req(3)
    y = srv(x)  # pads 3 -> bucket 4, slices back
    assert srv.reports[-1].padding == 1
    assert_matches(y, res.executables["jax"](x))


def test_coalesced_results_match_per_request_execution():
    """The differential property: a mixed-size stream served coalesced is
    identical (to float rounding) to executing every request alone."""
    res = mlp_flow(seed=7)
    srv = res.serve(max_batch=8, max_wait=0.0)
    sizes = [1, 3, 2, 5, 1, 4, 2, 8, 1]
    xs = [req(s, seed=i) for i, s in enumerate(sizes)]
    tickets = [srv.submit(x) for x in xs]
    srv.pump(flush=True)
    naive = res.executables["jax"]
    for t, x in zip(tickets, xs):
        assert_matches(srv.result(t), naive(x))
    stats = srv.stats()
    assert stats["submitted"] == len(sizes)
    assert stats["executed_batches"] == len(srv.reports) < len(sizes)
    assert stats["scheduled_rows"] == sum(sizes)


def test_every_ticket_demuxes_its_own_rows():
    res = mlp_flow(seed=1)
    srv = res.serve(max_batch=8, max_wait=0.0)
    a, b = req(2, seed=1), req(2, seed=2)
    ta, tb = srv.submit(a), srv.submit(b)
    ya, yb = srv.result(ta), srv.result(tb)
    naive = res.executables["jax"]
    assert_matches(ya, naive(a))
    assert_matches(yb, naive(b))
    with pytest.raises(KeyError):
        srv.result(ta)  # results are single-consumption
    tc = srv.submit(req(2, seed=3))
    srv.pump(flush=True)
    srv.drop(tc)  # abandoned ticket: result released, not resident forever
    assert not srv._results
    td = srv.submit(req(2, seed=4))
    srv.drop(td)  # dropped BEFORE execution: output discarded at demux
    srv.pump(flush=True)
    assert not srv._results and not srv._dropped


def test_split_request_demuxes_to_one_ticket():
    """An oversize submission is served in chunks but claimed as ONE ticket
    whose rows equal the unsplit execution."""
    res = mlp_flow(seed=3)
    srv = res.serve(max_batch=4, max_wait=0.0)
    x = req(11, seed=5)
    t = srv.submit(x)
    srv.pump(flush=True)
    assert_matches(srv.result(t), res.executables["jax"](x))
    s = srv.stats()
    assert s["split_requests"] == 1 and s["split_chunks"] == 3
    assert not srv._results and not srv._split


def test_split_request_interleaves_with_normal_traffic():
    res = mlp_flow(seed=4)
    srv = res.serve(max_batch=4, max_wait=0.0)
    a, big, b = req(2, seed=1), req(9, seed=2), req(3, seed=3)
    ta, tbig, tb = srv.submit(a), srv.submit(big), srv.submit(b)
    srv.pump(flush=True)
    naive = res.executables["jax"]
    assert_matches(srv.result(tbig), naive(big))
    assert_matches(srv.result(ta), naive(a))
    assert_matches(srv.result(tb), naive(b))


def test_dropped_split_parent_releases_every_chunk():
    res = mlp_flow(seed=5)
    srv = res.serve(max_batch=4, max_wait=0.0)
    t = srv.submit(req(10, seed=6))
    srv.drop(t)  # before execution
    srv.pump(flush=True)
    assert not srv._results and not srv._dropped and not srv._split
    t2 = srv.submit(req(10, seed=7))
    srv.pump(flush=True)
    srv.drop(t2)  # after execution
    assert not srv._results and not srv._split


def test_server_pump_respects_fake_clock():
    clock = FakeClock()
    res = mlp_flow()
    srv = res.serve(max_batch=8, max_wait=0.5, clock=clock)
    srv.submit(req(2))
    assert srv.pump() == 0  # nothing ready yet
    clock.advance(1.0)
    assert srv.pump() == 1  # max_wait elapsed on the fake clock
    assert srv.latencies and srv.latencies[0] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# precision working points per scheduled batch
# ---------------------------------------------------------------------------


def test_policy_selects_point_from_batch_budget():
    points = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
    policy = RuntimePolicy(points, thresholds=[0.66, 0.33])
    calls = []

    def fake_point(name):
        def run(x):
            calls.append(name)
            return x

        return run

    srv = AccelServer(
        fake_point("default"),
        max_batch=8,
        max_wait=0.0,
        policy=policy,
        point_executables={n: fake_point(n) for n in ("w8", "w4", "w2")},
        clock=FakeClock(),
    )
    for budget in (1.0, 0.5, 0.1):
        srv.submit(req(2), budget=budget)
        srv.pump(flush=True)
    assert calls == ["w8", "w4", "w2"]
    assert [r.point for r in srv.reports] == ["w8", "w4", "w2"]
    assert srv.stats()["points"] == {"w8": 1, "w4": 1, "w2": 1}


def test_batch_budget_is_most_constrained_member():
    points = [WorkingPoint("w8", 8), WorkingPoint("w2", 2)]
    policy = RuntimePolicy(points, thresholds=[0.5])
    srv = AccelServer(
        lambda x: x,
        max_batch=8,
        max_wait=0.0,
        policy=policy,
        clock=FakeClock(),
    )
    srv.submit(req(2), budget=1.0)
    srv.submit(req(2), budget=0.2)  # constrained member drags the batch down
    srv.pump(flush=True)
    assert [r.point for r in srv.reports] == ["w2"]


# ---------------------------------------------------------------------------
# best-fit packing (BucketPolicy.packing="best_fit")
# ---------------------------------------------------------------------------


def test_best_fit_dispatches_min_waste_prefix():
    # sizes [4, 3]: fifo packs both (7 rows -> bucket 8, waste 1); best-fit
    # stops at [4] (bucket 4, waste 0) and serves [3] from the next batch
    sched = CoalescingScheduler(
        max_batch=8, max_wait=1e9, clock=FakeClock(), packing="best_fit"
    )
    for n in (4, 3):
        sched.submit((req(n),))
    first = sched.ready(flush=True)
    assert [r.size for r in first.requests] == [4]
    assert first.bucket == 4 and first.padding == 0
    second = sched.ready(flush=True)
    assert [r.size for r in second.requests] == [3]


def test_best_fit_tie_prefers_longer_prefix():
    # [2, 2]: prefix [2] (bucket 2, waste 0) ties with [2, 2] (bucket 4,
    # waste 0) -> the longer prefix wins (more requests per dispatch)
    sched = CoalescingScheduler(
        max_batch=8, max_wait=1e9, clock=FakeClock(), packing="best_fit"
    )
    for _ in range(2):
        sched.submit((req(2),))
    batch = sched.ready(flush=True)
    assert [r.size for r in batch.requests] == [2, 2]
    assert batch.padding == 0


def test_best_fit_never_reorders_the_queue():
    # arrival order is preserved: best-fit only picks a PREFIX length, so the
    # head request is always in the dispatched batch (no starvation)
    sched = CoalescingScheduler(
        max_batch=8, max_wait=1e9, clock=FakeClock(), packing="best_fit"
    )
    for n in (3, 4, 1):
        sched.submit((req(n),))
    batch = sched.ready(flush=True)
    assert batch.requests[0].size == 3


def test_fifo_stays_the_default_packing():
    sched = CoalescingScheduler(max_batch=8, max_wait=1e9, clock=FakeClock())
    for n in (4, 3):
        sched.submit((req(n),))
    batch = sched.ready(flush=True)
    assert [r.size for r in batch.requests] == [4, 3]
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=8, packing="round_robin")


def test_accel_server_passes_packing_through():
    res = mlp_flow()
    srv = AccelServer(
        res.batched["jax"],
        max_batch=8,
        max_wait=1e9,
        clock=FakeClock(),
        packing="best_fit",
    )
    t4, t3 = srv.submit(req(4)), srv.submit(req(3, seed=1))
    srv.pump(flush=True)
    assert [r.rows for r in srv.reports] == [4, 3]  # two min-waste batches
    ref = res.executables["jax"]
    np.testing.assert_allclose(
        np.asarray(srv.result(t4)), np.asarray(ref(req(4))), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(srv.result(t3)), np.asarray(ref(req(3, seed=1))), atol=1e-5
    )
