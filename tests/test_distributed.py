"""Multi-device SPMD tests (subprocess with 8 forced host devices, so the rest
of the suite keeps seeing 1 device as required by the brief)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """2x4 mesh train step == single-device train step (same seeds)."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.params import init_params
        from repro.optim.adamw import OptConfig
        from repro.runtime.train import (init_train_state, make_train_step,
                                         state_shardings, batch_shardings)
        cfg = get_config("qwen1.5-0.5b").smoke()
        params = init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
        state = init_train_state(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        # single device
        s1, m1 = jax.jit(make_train_step(cfg, opt))(state, batch)
        # sharded 2x4
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        step = make_train_step(cfg, opt, mesh=mesh, tp_total=4)
        st_sh = state_shardings(cfg, state, mesh)
        b_sh = batch_shardings(batch, mesh)
        with mesh:
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / abs(l1) < 2e-2, (l1, l2)
        g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
        assert abs(g1 - g2) / abs(g1) < 2e-2, (g1, g2)
        for k in s1.params:
            if k.endswith(("/bq", "/bk", "/bv")):
                # zero-init biases: Adam's first update is +-lr * sign(g) and
                # tiny bf16 grads flip sign under different reduction orders
                continue
            a = np.asarray(s1.params[k], np.float32)
            b = np.asarray(jax.device_get(s2.params[k]), np.float32)
            rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
            assert rel < 5e-2, (k, rel)
        print("OK sharded==single")
    """))


def test_moe_shard_map_matches_local():
    """Expert-parallel shard_map output == local MoE block (same routing)."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models.moe import moe_block, MoELayerParams
        from repro.models.params import init_params, moe_factors
        cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").smoke(),
                                  dtype="float32")
        # high capacity factor => no token drops => local/sharded bit-comparable
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
        # local layout (tp_total=1)
        p1 = init_params(cfg, jax.random.PRNGKey(0), max_seq=32, tp_total=1)
        # sharded layout (tp_total=4): rebuild the same weights in EP layout
        E = cfg.moe.n_experts
        f = cfg.moe.d_ff_expert
        d = cfg.d_model
        ep, tp = moe_factors(E, 4)
        def to_ep(w, last_is_d):
            # (L, 1, E, d, f) -> (L, 4, E/ep, d, f/tp) matching moe layout
            L = w.shape[0]
            w = w[:, 0]
            if last_is_d:      # w_down (E, f, d): split f
                w = w.reshape(L, ep, E // ep, tp, f // tp, d)
                w = w.transpose(0, 1, 3, 2, 4, 5).reshape(L, 4, E // ep, f // tp, d)
            else:              # w_gate/up (E, d, f): split f
                w = w.reshape(L, ep, E // ep, d, tp, f // tp)
                w = w.transpose(0, 1, 4, 2, 3, 5).reshape(L, 4, E // ep, d, f // tp)
            return w
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, d), jnp.float32)
        lp = MoELayerParams(router=p1["layers/moe/router"][0],
                            w_gate=p1["layers/moe/w_gate"][0],
                            w_up=p1["layers/moe/w_up"][0],
                            w_down=p1["layers/moe/w_down"][0])
        y1, lb1, z1 = moe_block(x, lp, cfg, None, 1)
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        lp4 = MoELayerParams(router=p1["layers/moe/router"][0],
                             w_gate=to_ep(p1["layers/moe/w_gate"], False)[0],
                             w_up=to_ep(p1["layers/moe/w_up"], False)[0],
                             w_down=to_ep(p1["layers/moe/w_down"], True)[0])
        with mesh:
            y4, lb4, z4 = jax.jit(lambda x, p: moe_block(x, p, cfg, mesh, 4))(x, lp4)
        a, b = np.asarray(y1), np.asarray(jax.device_get(y4))
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
        assert rel < 1e-3, rel
        # aux losses aggregate per data shard (nonlinear in the routing
        # stats), so sharded != global exactly; sanity-range only
        assert 0.5 < float(lb4) / float(lb1) < 2.0, (float(lb1), float(lb4))
        print("OK moe ep==local", rel)
    """))


def test_production_mesh_constructs():
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK meshes")
    """))


def test_dist_batched_executable_serves_indivisible_batches():
    """One DistWriter artifact on a 4-way data mesh serves batch 8 (sharded
    evenly), 3 and 1 (zero-padded to the DP multiple, output sliced back)."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.mnist_cnn import CONFIG as CNN
        from repro.models import cnn
        from repro.core.reader import cnn_to_ir
        from repro.core.passes import PassManager, structural_pipeline
        from repro.core.writers.dist_writer import DistWriter
        from repro.launch.mesh import compat_make_mesh
        params = cnn.init_params(CNN, jax.random.PRNGKey(0))
        g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
        g = PassManager(structural_pipeline()).run(g)
        mesh = compat_make_mesh((4,), ("data",))
        w = DistWriter(g)
        exe = w.build_batched(mesh)
        ref = w.build()
        x = jax.random.uniform(jax.random.PRNGKey(1), (8, 28, 28, 1))
        for b in (8, 3, 1):
            y = np.asarray(exe(x[:b]))
            assert y.shape == (b, 10), y.shape
            np.testing.assert_allclose(y, np.asarray(ref(x[:b])), atol=1e-5)
        assert exe.cached_batches == (8, 3, 1)
        # symbolic graphs refuse AOT lowering without a concrete batch
        try:
            w.lower_compile(mesh)
        except ValueError as e:
            assert "symbolic" in str(e)
        else:
            raise AssertionError("lower_compile should require batch=")
        print("OK dist batched")
    """))


def test_accel_server_coalesces_onto_mesh():
    """The batch-coalescing AccelServer drives DistWriter.build_batched on a
    4-way data mesh: mixed-size requests are packed, padded to LRU-aligned
    buckets, executed SPMD, and demuxed back per request."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.mnist_cnn import CONFIG as CNN
        from repro.models import cnn
        from repro.core.reader import cnn_to_ir
        from repro.core.passes import PassManager, structural_pipeline
        from repro.core.writers.dist_writer import DistWriter
        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.serve import AccelServer
        params = cnn.init_params(CNN, jax.random.PRNGKey(0))
        g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
        g = PassManager(structural_pipeline()).run(g)
        mesh = compat_make_mesh((4,), ("data",))
        w = DistWriter(g)
        traced = []
        srv = AccelServer(w.build_batched(mesh, on_compile=traced.append),
                          max_batch=8, max_wait=0.0)
        ref = w.build()
        x = jax.random.uniform(jax.random.PRNGKey(1), (8, 28, 28, 1))
        sizes = (2, 3, 1, 4, 2)
        tickets = [srv.submit(x[:s]) for s in sizes]
        srv.pump(flush=True)
        for t, s in zip(tickets, sizes):
            np.testing.assert_allclose(np.asarray(srv.result(t)),
                                       np.asarray(ref(x[:s])), atol=1e-5)
        stats = srv.stats()
        assert stats["executed_batches"] < len(sizes)   # coalescing happened
        assert len(traced) == stats["misses"]           # hook saw every trace
        print("OK accel server on mesh")
    """))
