"""Fleet serving: health-checked failover router over N AccelServer
replicas — chaos injection, circuit breakers, retry/hedge semantics,
eject/heal/readmit lifecycle, fleet-wide precision brownout, and the
typed-shutdown / fail-fast contracts on the underlying server.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.adaptive import (BrownoutSelector, ServiceObjective,
                                 WorkingPoint)
from repro.runtime.fleet import (ChaosExecutable, CircuitBreaker,
                                 DeadlineExceeded, FleetRouter,
                                 NoReplicaAvailable, ReplicaCrash,
                                 RequestFailed)
from repro.runtime.ft import FailureInjector
from repro.runtime.serve import AccelServer, ServerStopped


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def double(x):
    return np.asarray(x) * 2.0


def vals(n, start=0):
    return [np.full((2, 3), start + i, np.float32) for i in range(n)]


def make_factory(exe=double, **kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait", 0.001)
    return lambda: AccelServer(exe, **kw)


def make_router(factories, **kw):
    kw.setdefault("probe", [np.ones((1, 3), np.float32)])
    kw.setdefault("probe_interval_s", 0.01)
    kw.setdefault("heal_cooldown_s", 0.05)
    kw.setdefault("default_deadline_s", 15.0)
    return FleetRouter(factories, **kw)


# ---------------------------------------------------------------------------
# chaos layer
# ---------------------------------------------------------------------------


def test_chaos_executable_passes_through_and_counts():
    chaos = ChaosExecutable(double)
    out = chaos(np.ones((2, 2)))
    np.testing.assert_array_equal(out, np.full((2, 2), 2.0))
    assert chaos.calls == 1


def test_chaos_executable_crash_fires_once():
    chaos = ChaosExecutable(double, crash_at=[1])
    chaos(np.ones((1, 1)))
    with pytest.raises(ReplicaCrash):
        chaos(np.ones((1, 1)))
    # fire-once: the healed replica's fresh pump is not re-killed
    chaos(np.ones((1, 1)))
    assert chaos.calls == 3


def test_chaos_executable_injects_failures_and_delays():
    slept = []
    inj = FailureInjector(fail_at=[0], delay_at=[1], delay_s=0.5,
                          sleep=slept.append)
    chaos = ChaosExecutable(double, inj)
    with pytest.raises(RuntimeError, match="injected"):
        chaos(np.ones((1, 1)))
    chaos(np.ones((1, 1)))
    assert slept == [0.5]


def test_chaos_executable_shares_counter_across_points():
    # one schedule spans a replica's W8/W4/W2 point executables
    counter = [0]
    w8 = ChaosExecutable(double, crash_at=[2], counter=counter)
    w4 = ChaosExecutable(double, crash_at=[2], counter=counter)
    w8(np.ones((1, 1)))
    w4(np.ones((1, 1)))
    with pytest.raises(ReplicaCrash):
        w8(np.ones((1, 1)))   # third call overall, whichever point runs it


def test_chaos_executable_delegates_telemetry():
    class Exe:
        bits = 4

        def __call__(self, x):
            return x

    chaos = ChaosExecutable(Exe())
    assert chaos.bits == 4


def test_replica_crash_escapes_exception_containment():
    # ReplicaCrash must be a BaseException so it skips the pump's per-batch
    # `except Exception` containment and kills the whole pump thread
    assert issubclass(ReplicaCrash, BaseException)
    assert not issubclass(ReplicaCrash, Exception)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_opens_after_threshold_and_half_opens():
    clk = FakeClock()
    br = CircuitBreaker(threshold=3, cooldown_s=1.0, clock=clk)
    assert br.allows()
    br.record_failure()
    br.record_failure()
    assert br.allows()            # below threshold
    br.record_failure()
    assert not br.allows() and br.open and br.trips == 1
    clk.advance(1.5)
    assert br.allows()            # cooldown over: half-open trickle
    br.record_success()
    assert br.allows() and not br.open and br.failures == 0


def test_breaker_reopens_on_half_open_failure():
    clk = FakeClock()
    br = CircuitBreaker(threshold=1, cooldown_s=1.0, clock=clk)
    br.record_failure()
    assert not br.allows()
    clk.advance(1.0)
    assert br.allows()            # half-open
    br.record_failure()           # probe failed
    assert not br.allows()
    clk.advance(0.5)
    assert not br.allows()        # cooldown restarted from the re-open
    clk.advance(0.6)
    assert br.allows()


# ---------------------------------------------------------------------------
# brownout selector (fleet-wide precision ladder)
# ---------------------------------------------------------------------------

POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]
NAMES = [p.name for p in POINTS]


def _slo(**kw):
    kw.setdefault("p95_latency_s", 0.1)
    kw.setdefault("window", 8)
    kw.setdefault("min_samples", 4)
    kw.setdefault("hold", 4)
    return ServiceObjective(**kw)


def test_brownout_walks_down_under_p95_pressure_and_recovers():
    sel = BrownoutSelector(POINTS, _slo())
    assert sel.select().name == "w8"
    for _ in range(4):
        sel.observe(0.5)          # way over the 0.1s target
    assert sel.select().name == "w4"
    for _ in range(4):
        sel.observe(0.5)
    assert sel.select().name == "w2"   # keeps walking down
    for _ in range(8):
        sel.observe(0.5)
    assert sel.select().name == "w2"   # clamps at the floor
    for _ in range(20):
        sel.observe(0.001)        # recovery with margin
    assert sel.select().name == "w8"
    downs = [s for s in sel.shifts if NAMES.index(s[1]) > NAMES.index(s[0])]
    ups = [s for s in sel.shifts if NAMES.index(s[1]) < NAMES.index(s[0])]
    assert len(downs) == 2 and len(ups) == 2


def test_brownout_downshifts_on_queue_depth():
    sel = BrownoutSelector(POINTS, _slo(), max_queue_depth=10)
    for _ in range(4):
        sel.observe_depth(50)     # backlog breach alone, no latency samples
    assert sel.select().name == "w4"
    for _ in range(4):
        sel.observe_depth(50)     # breach persists: keep shedding precision
    assert sel.select().name == "w2"
    # fast samples while the backlog is still over: NO recovery
    for _ in range(8):
        sel.observe(0.001)
    assert sel.select().name == "w2"
    # backlog clears: fast samples walk the ladder back up
    sel.observe_depth(0)
    for _ in range(10):
        sel.observe(0.001)
    assert sel.select().name == "w8"


def test_brownout_holds_between_shifts():
    sel = BrownoutSelector(POINTS, _slo(hold=100))
    for _ in range(50):
        sel.observe(0.5)
    assert sel.select().name == "w8"   # hold not satisfied yet
    for _ in range(60):
        sel.observe(0.5)
    assert sel.select().name == "w4"


def test_brownout_telemetry_and_validation():
    sel = BrownoutSelector(POINTS, _slo(), max_queue_depth=4)
    t = sel.telemetry()
    assert t["point"] == "w8" and t["max_queue_depth"] == 4
    with pytest.raises(ValueError):
        BrownoutSelector([], _slo())
    with pytest.raises(ValueError):
        BrownoutSelector(POINTS, _slo(), max_queue_depth=0)


def test_brownout_is_thread_safe_under_concurrent_observers():
    sel = BrownoutSelector(POINTS, _slo())

    def hammer():
        for _ in range(200):
            sel.observe(0.5)
            sel.select()

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert sel.select() in POINTS


# ---------------------------------------------------------------------------
# fleet router: routing, failover, lifecycle
# ---------------------------------------------------------------------------


def test_fleet_serves_and_spreads_load():
    r = make_router({"a": make_factory(), "b": make_factory(),
                     "c": make_factory()})
    with r:
        tks = [r.submit(v) for v in vals(30)]
        for i, t in enumerate(tks):
            np.testing.assert_allclose(t.result(timeout=10), 2.0 * vals(30)[i])
        s = r.stats()
    assert s["succeeded"] == 30 and s["failed"] == 0
    assert s["availability"] == 1.0
    served = [rep["served"] for rep in s["replicas"].values()]
    assert all(n > 0 for n in served)    # every replica took traffic


def test_fleet_requires_start_and_validates():
    r = make_router({"a": make_factory()})
    with pytest.raises(RuntimeError, match="not running"):
        r.submit(*vals(1))
    with pytest.raises(ValueError):
        FleetRouter({})
    with pytest.raises(ValueError):
        FleetRouter({"a": make_factory()}, retries=-1)


def test_fleet_retries_batch_failure_on_another_replica():
    # replica b fails its first executable call; the ticket must be retried
    # on a sibling and still resolve successfully
    bad = ChaosExecutable(double, FailureInjector(fail_at=[0]))
    r = make_router({"a": make_factory(), "b": make_factory(bad)},
                    retries=2, backoff_s=0.001)
    with r:
        tks = [r.submit(v) for v in vals(12)]
        outs = [t.result(timeout=10) for t in tks]
        s = r.stats()
    for i, o in enumerate(outs):
        np.testing.assert_allclose(o, 2.0 * vals(12)[i])
    assert s["failed"] == 0 and s["retries"] >= 1


def test_fleet_pump_crash_ejects_heals_and_readmits():
    chaos = ChaosExecutable(double, crash_at=[2])
    r = make_router({"a": make_factory(), "b": make_factory(chaos),
                     "c": make_factory()},
                    retries=2, backoff_s=0.001, heal_cooldown_s=0.02)
    with r:
        tks = [r.submit(v) for v in vals(40)]
        for i, t in enumerate(tks):
            np.testing.assert_allclose(t.result(timeout=10), 2.0 * vals(40)[i])
        # replica b's pump died mid-burst, yet zero tickets were lost
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            rb = r.stats()["replicas"]["b"]
            if rb["readmissions"] >= 1 and rb["state"] == "healthy":
                break
            time.sleep(0.01)
        s = r.stats()
    rb = s["replicas"]["b"]
    assert rb["ejections"] >= 1, s
    assert rb["readmissions"] >= 1 and rb["state"] == "healthy", s
    assert rb["generation"] >= 2          # healed via a fresh server build
    assert s["availability"] == 1.0


def test_fleet_terminal_failure_is_typed_and_chains_cause():
    def always_fail(x):
        raise ValueError("device poisoned")

    r = make_router({"a": make_factory(always_fail),
                     "b": make_factory(always_fail)},
                    retries=1, backoff_s=0.001, probe=None)
    with r:
        t = r.submit(*vals(1))
        with pytest.raises(RequestFailed) as ei:
            t.result(timeout=10)
        assert "device poisoned" in str(ei.value.__cause__)
        # a terminal ticket re-raises the same typed error on re-claim
        with pytest.raises(RequestFailed):
            t.result(timeout=10)
        s = r.stats()
    assert s["failed"] == 1 and s["availability"] < 1.0


def test_fleet_sheds_when_no_replica_routable():
    chaos = ChaosExecutable(double, crash_at=[0])
    r = make_router({"a": make_factory(chaos)}, probe=None,
                    heal_cooldown_s=30.0)
    with r:
        t = r.submit(*vals(1))
        with pytest.raises(RequestFailed):
            t.result(timeout=10)   # crash + nowhere to retry
        # the lone replica is now ejected: new submits are shed, typed
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if r.stats()["replicas"]["a"]["state"] == "ejected":
                break
            time.sleep(0.005)
        with pytest.raises(NoReplicaAvailable):
            r.submit(*vals(1))
        assert r.stats()["shed"] == 1


def test_fleet_sheds_when_every_queue_is_full():
    # all routable replicas rejecting with QueueFull must raise a typed
    # NoReplicaAvailable (shed), not busy-spin re-routing forever
    gate = threading.Event()

    def wedged(x):
        gate.wait(10.0)
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(wedged, queue_depth=1),
                     "b": make_factory(wedged, queue_depth=1)},
                    probe=None)
    out = {}

    def fill():
        try:
            for v in vals(10):     # > pump slots + queue slots of the fleet
                r.submit(v)
            out["err"] = None
        except NoReplicaAvailable as e:
            out["err"] = e

    try:
        with r:
            th = threading.Thread(target=fill, daemon=True)
            th.start()
            th.join(5.0)
            assert not th.is_alive(), \
                "submit busy-spun on full queues instead of shedding"
            assert isinstance(out["err"], NoReplicaAvailable)
            assert r.stats()["shed"] == 1
    finally:
        gate.set()


def test_stale_attempt_never_touches_a_healed_servers_tickets():
    # an attempt outstanding across a heal must settle against the server
    # GENERATION it was submitted to: the rebuilt server restarts its rid
    # counter, so settling against rep.server would claim/drop an unrelated
    # request's result on the new generation
    gate = threading.Event()
    calls = {"n": 0}
    lock = threading.Lock()

    def slow_first(x):
        with lock:
            calls["n"] += 1
            first = calls["n"] == 1
        if first:
            gate.wait(10.0)       # only generation 1's first batch wedges
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(slow_first)}, probe=None, retries=0)
    try:
        with r:
            rep = r.replicas["a"]
            old_srv = rep.server
            v0, v1 = vals(2)
            t1 = r.submit(v0)     # rid 0 on generation 1, wedged in-flight
            deadline = time.monotonic() + 5.0
            while time.monotonic() < deadline and calls["n"] < 1:
                time.sleep(0.005)
            with r._lock:
                r._build_server(rep)          # heal: fresh pump, rids reset
            assert rep.server is not old_srv
            t2 = r.submit(v1)     # rid 0 again — on generation 2
            gate.set()
            # each ticket must claim from ITS OWN generation's server
            np.testing.assert_allclose(t1.result(timeout=10), 2.0 * v0)
            np.testing.assert_allclose(t2.result(timeout=10), 2.0 * v1)
            old_srv.stop(drain=False, timeout=2.0)
    finally:
        gate.set()


def test_fleet_drop_releases_only_its_own_generation():
    # drop() of a pre-heal ticket must not discard the rid-colliding request
    # on the healed server
    gate = threading.Event()
    calls = {"n": 0}
    lock = threading.Lock()

    def slow_first(x):
        with lock:
            calls["n"] += 1
            first = calls["n"] == 1
        if first:
            gate.wait(10.0)
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(slow_first)}, probe=None, retries=0)
    try:
        with r:
            rep = r.replicas["a"]
            old_srv = rep.server
            v0, v1 = vals(2)
            t1 = r.submit(v0)     # rid 0 on generation 1
            with r._lock:
                r._build_server(rep)
            t2 = r.submit(v1)     # rid 0 on generation 2
            r.drop(t1)            # must hit generation 1, not t2's ticket
            np.testing.assert_allclose(t2.result(timeout=10), 2.0 * v1)
            with pytest.raises(RequestFailed, match="dropped"):
                t1.result(timeout=10)
            gate.set()
            old_srv.stop(drain=False, timeout=2.0)
    finally:
        gate.set()


def test_probe_failure_drops_canary_ticket():
    # a timed-out probe must release its canary so repeated probes of a
    # persistently suspect replica never accumulate unclaimed results
    gate = threading.Event()

    def wedged(x):
        gate.wait(10.0)
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(wedged)}, probe_timeout_s=0.02,
                    probe_interval_s=30.0)   # sentinel effectively quiet
    try:
        with r:
            rep = r.replicas["a"]
            srv = rep.server
            for _ in range(3):
                assert r._probe(rep) == "probe"   # canary times out
            gate.set()
            deadline = time.monotonic() + 5.0
            while time.monotonic() < deadline \
                    and (srv.queue_depth() or srv._results):
                time.sleep(0.01)
            assert srv._results == {}   # canary outputs never stay resident
    finally:
        gate.set()


def test_fleet_result_single_consumption_under_concurrency():
    # concurrent result() calls on one fleet ticket: exactly one claims,
    # the rest get the documented KeyError (no race on the attempt list)
    gate = threading.Event()

    def slow(x):
        gate.wait(10.0)
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(slow)}, probe=None)
    try:
        with r:
            t = r.submit(*vals(1))
            oks, errs = [], []

            def claim():
                try:
                    oks.append(t.result(timeout=10))
                except KeyError:
                    errs.append("key")

            ths = [threading.Thread(target=claim) for _ in range(4)]
            for th in ths:
                th.start()
            time.sleep(0.05)      # let every thread reach the claim gate
            gate.set()
            for th in ths:
                th.join(10.0)
            assert len(oks) == 1 and len(errs) == 3
            np.testing.assert_allclose(oks[0], 2.0 * vals(1)[0])
    finally:
        gate.set()


def test_fleet_deadline_budget_is_typed():
    gate = threading.Event()

    def wedged(x):
        gate.wait(5.0)
        return x

    r = make_router({"a": make_factory(wedged)}, probe=None,
                    hedge_after_s=None)
    try:
        with r:
            t = r.submit(*vals(1), deadline_s=0.15)
            with pytest.raises(DeadlineExceeded):
                t.result(timeout=10)
            assert r.stats()["deadlines_exceeded"] == 1
    finally:
        gate.set()


def test_fleet_caller_timeout_leaves_ticket_claimable():
    gate = threading.Event()

    def slow(x):
        gate.wait(0.3)
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(slow)}, probe=None)
    with r:
        t = r.submit(*vals(1))
        with pytest.raises(TimeoutError):
            t.result(timeout=0.05)
        gate.set()
        np.testing.assert_allclose(t.result(timeout=10), 2.0 * vals(1)[0])


def test_fleet_hedges_stragglers_first_result_wins():
    gate = threading.Event()
    calls = {"n": 0}
    lock = threading.Lock()

    def sometimes_slow(x):
        with lock:
            calls["n"] += 1
            slow = calls["n"] == 1
        if slow:
            gate.wait(5.0)        # first batch straggles
        return np.asarray(x) * 2.0

    r = make_router({"a": make_factory(sometimes_slow),
                     "b": make_factory()},
                    hedge_after_s=0.05, probe=None)
    try:
        with r:
            t = r.submit(*vals(1))
            np.testing.assert_allclose(t.result(timeout=10), 2.0 * vals(1)[0])
            s = r.stats()
        assert s["hedges"] >= 1 and s["hedge_wins"] >= 1
        assert s["succeeded"] == 1       # one request, despite two attempts
    finally:
        gate.set()


def test_fleet_brownout_wired_into_every_replica():
    sel = BrownoutSelector(POINTS, _slo())
    seen = []
    lock = threading.Lock()

    class PointExe:
        def __init__(self, tag):
            self.tag = tag

        def __call__(self, x):
            with lock:
                seen.append(self.tag)
            return np.asarray(x) * 2.0

    def factory():
        return AccelServer(PointExe("w8"), max_batch=8, max_wait=0.001,
                           point_executables={p.name: PointExe(p.name)
                                              for p in POINTS})

    r = make_router({"a": factory, "b": factory}, brownout=sel, probe=None)
    with r:
        for v in vals(6):
            r.submit(v).result(timeout=10)
        # force the shared selector down: BOTH replicas must follow the rung
        for _ in range(12):
            sel.observe(10.0)
        rung = sel.select().name
        assert rung != "w8"
        seen.clear()
        # fewer requests than the SLO hold: the rung cannot move mid-check
        for v in vals(3):
            r.submit(v).result(timeout=10)
    assert set(seen) == {rung}
    assert r.stats()["brownout"]["point"] == rung


def test_fleet_sentinel_feeds_queue_depth_to_brownout():
    sel = BrownoutSelector(POINTS, _slo(hold=1), max_queue_depth=1000)
    r = make_router({"a": make_factory()}, brownout=sel, probe=None,
                    probe_interval_s=0.005)
    with r:
        r.submit(*vals(1)).result(timeout=10)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if sel.telemetry()["queue_depth"] is not None:
                break
            time.sleep(0.005)
    assert sel.telemetry()["queue_depth"] == 0   # drained fleet, depth fed


def test_fleet_drop_releases_all_attempts():
    r = make_router({"a": make_factory()}, probe=None)
    with r:
        t = r.submit(*vals(1))
        r.drop(t)
        with pytest.raises(RequestFailed, match="dropped"):
            t.result(timeout=10)


def test_fleet_stop_is_idempotent_and_restart_guarded():
    r = make_router({"a": make_factory()})
    r.start()
    with pytest.raises(RuntimeError, match="already running"):
        r.start()
    r.stop()
    r.stop()   # safe no-op
    with pytest.raises(RuntimeError, match="not running"):
        r.submit(*vals(1))


def test_fleet_call_shorthand():
    r = make_router({"a": make_factory()}, probe=None)
    with r:
        out = r(*vals(1))
    np.testing.assert_allclose(out, 2.0 * vals(1)[0])


# ---------------------------------------------------------------------------
# AccelServer shutdown / fail-fast contracts (satellites)
# ---------------------------------------------------------------------------


def _wedged_server():
    gate = threading.Event()

    def wedge(x):
        gate.wait(30.0)
        return x

    return AccelServer(wedge, max_batch=4, max_wait=0.001), gate


def test_stop_timeout_resolves_all_tickets_with_typed_error():
    srv, gate = _wedged_server()
    try:
        srv.start()
        tks = [srv.submit(v) for v in vals(6)]
        with pytest.raises(RuntimeError, match="did not exit"):
            srv.stop(drain=True, timeout=0.05)
        # EVERY ticket — in-flight and still-queued — resolved, typed
        for t in tks:
            assert t.done()
            with pytest.raises(ServerStopped):
                t.result(timeout=1.0)
        assert not srv.alive and isinstance(srv.fatal, ServerStopped)
        srv.stop(drain=True, timeout=0.05)    # repeated stop: safe no-op
        with pytest.raises(RuntimeError, match="no new requests"):
            srv.submit(*vals(1))
    finally:
        gate.set()


def test_stop_never_started_is_noop():
    srv = AccelServer(double, max_batch=4)
    srv.stop()
    srv.stop(drain=False)


def test_dead_pump_fails_fast_instead_of_hanging(monkeypatch):
    # a pump thread that exits without resolving tickets (crashed start)
    # must not block a timeout=None waiter forever
    srv = AccelServer(double, max_batch=4, max_wait=60.0)
    monkeypatch.setattr(AccelServer, "_pump_loop", lambda self: None)
    srv.start()
    srv._thread.join(5.0)
    tk = srv.submit(*vals(1))
    with pytest.raises(RuntimeError, match="pump thread is not running"):
        tk.result()       # timeout=None: would previously hang forever
    srv._thread = None    # detach the dead thread: sync path still works
    np.testing.assert_allclose(tk.result(), vals(1)[0] * 2.0)


def test_unresolvable_claim_names_unstarted_pump():
    srv = AccelServer(double, max_batch=4, max_wait=60.0)
    tk = srv.submit(*vals(1))
    # empty the queue behind the ticket's back: the sync on-demand pump can
    # no longer produce it, and nobody is running the background pump
    srv._default.scheduler.abandon()
    with pytest.raises(RuntimeError, match="never start"):
        tk.result()
