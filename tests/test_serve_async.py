"""Async multi-tenant AccelServer: background pump with future-style
tickets, weighted round-robin QoS between tenants, per-tenant admission
control, pump-death ticket resolution, and the two closed loops (measured
per-bucket latency -> BucketPolicy, measured request p95 -> precision
ladder under an SLO).
"""

import threading

import numpy as np
import pytest

from repro.core.adaptive import (RuntimePolicy, ServiceObjective,
                                 SLOController, WorkingPoint)
from repro.runtime.scheduler import BucketPolicy, LatencyEWMA, QueueFull
from repro.runtime.serve import AccelServer


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class Recorder:
    """Executable that tags rows and records call order (thread-safe)."""

    def __init__(self, tag=0.0, fail=False):
        self.tag = tag
        self.fail = fail
        self.calls = []
        self._lock = threading.Lock()

    def __call__(self, x):
        if self.fail:
            raise RuntimeError("injected executable failure")
        with self._lock:
            self.calls.append(np.asarray(x).copy())
        return np.asarray(x) + self.tag


def vals(n, start=0):
    """n distinct single-row requests with a recognizable payload."""
    return [np.full((1, 3), start + i, np.float32) for i in range(n)]


# ---------------------------------------------------------------------------
# background pump: tickets, lifecycle, drain
# ---------------------------------------------------------------------------


def test_async_pump_resolves_tickets():
    srv = AccelServer(Recorder(tag=100.0), max_batch=4, max_wait=0.001)
    with srv:
        tks = [srv.submit(v) for v in vals(16)]
        outs = [t.result(timeout=10) for t in tks]
    for i, o in enumerate(outs):
        assert o.shape == (1, 3) and float(o[0, 0]) == 100.0 + i


def test_stop_drains_queue():
    srv = AccelServer(Recorder(), max_batch=4, max_wait=60.0).start()
    tks = [srv.submit(v) for v in vals(6)]
    # max_wait is huge and the batch is partial: nothing is due yet, but
    # stop(drain=True) must flush and serve everything before exiting
    srv.stop(drain=True)
    for i, t in enumerate(tks):
        assert t.done()
        assert float(srv.result(t)[0, 0]) == i


def test_stop_without_drain_errors_queued_tickets():
    srv = AccelServer(Recorder(), max_batch=4, max_wait=60.0).start()
    tks = [srv.submit(v) for v in vals(3)]
    srv.stop(drain=False)
    for t in tks:
        assert t.done()
        with pytest.raises(RuntimeError, match="stopped before serving"):
            t.result()


def test_result_timeout_leaves_ticket_claimable():
    srv = AccelServer(Recorder(), max_batch=4, max_wait=60.0).start()
    try:
        tk = srv.submit(*vals(1))
        with pytest.raises(TimeoutError):
            tk.result(timeout=0.01)
    finally:
        srv.stop(drain=True)
    assert float(tk.result()[0, 0]) == 0.0


def test_sync_pump_refused_while_thread_runs():
    srv = AccelServer(Recorder(), max_batch=4).start()
    try:
        with pytest.raises(RuntimeError, match="background pump"):
            srv.pump()
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# concurrency: N producer threads, interleaved tenants, exact demux
# ---------------------------------------------------------------------------


def test_threaded_submit_exact_demux_across_tenants():
    srv = AccelServer(max_batch=4, max_wait=0.001)
    tenants = ["a", "b", "c"]
    for k, name in enumerate(tenants):
        srv.add_tenant(name, Recorder(tag=1000.0 * (k + 1)),
                       max_batch=4, max_wait=0.001)
    per_thread = 40
    results = {}
    errors = []

    def producer(k, name):
        try:
            for i in range(per_thread):
                payload = 10_000 * k + i
                tk = srv.submit(np.full((1, 3), payload, np.float32),
                                tenant=name)
                results[(k, i)] = (payload, tk.result(timeout=30))
        except Exception as e:   # pragma: no cover - surfaced via errors
            errors.append(e)

    with srv:
        threads = [threading.Thread(target=producer, args=(k, name))
                   for k, name in enumerate(tenants)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    assert len(results) == per_thread * len(tenants)
    for (k, i), (payload, out) in results.items():
        # each ticket got exactly its own row back, transformed by its own
        # tenant's executable (tag identifies the tenant)
        assert float(out[0, 0]) == payload + 1000.0 * (k + 1)


def test_threaded_submit_fifo_order_per_tenant():
    recs = {"a": Recorder(), "b": Recorder()}
    srv = AccelServer(max_batch=4, max_wait=0.001)
    for name, rec in recs.items():
        srv.add_tenant(name, rec, max_batch=4, max_wait=0.001)

    def producer(name):
        for i in range(1, 31):          # nonzero payloads: zero rows = padding
            srv.submit(np.full((1, 3), i, np.float32), tenant=name)

    with srv:
        threads = [threading.Thread(target=producer, args=(n,)) for n in recs]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    # schedulers pack arrival-order prefixes and the pump pops batches in
    # order, so each tenant's executed rows (padding stripped) must be its
    # submission order exactly
    for name, rec in recs.items():
        real = [int(r[0]) for call in rec.calls for r in call if r[0] != 0]
        assert real == list(range(1, 31)), name


# ---------------------------------------------------------------------------
# QoS: weighted round-robin + per-tenant admission control
# ---------------------------------------------------------------------------


def test_wrr_ratio_between_backlogged_tenants():
    order = []

    def make_exe(name):
        def exe(x):
            order.append(name)
            return x
        return exe

    srv = AccelServer(max_batch=2, max_wait=0.0)
    srv.add_tenant("gold", make_exe("gold"), max_batch=2, max_wait=0.0,
                   weight=2)
    srv.add_tenant("bronze", make_exe("bronze"), max_batch=2, max_wait=0.0,
                   weight=1)
    # backlog both queues with full batches, then drive synchronously: the
    # pump must interleave gold:bronze = 2:1 while both are backlogged
    for i in range(12):
        srv.submit(np.full((2, 3), i, np.float32), tenant="gold")
    for i in range(6):
        srv.submit(np.full((2, 3), i, np.float32), tenant="bronze")
    srv.pump(flush=True)
    assert order[:9] == ["gold", "gold", "bronze"] * 3
    assert order.count("gold") == 12 and order.count("bronze") == 6


def test_wrr_is_work_conserving_when_one_tenant_idle():
    order = []
    srv = AccelServer(max_batch=2, max_wait=0.0)
    srv.add_tenant("gold", lambda x: (order.append("gold"), x)[1],
                   max_batch=2, max_wait=0.0, weight=3)
    srv.add_tenant("bronze", lambda x: (order.append("bronze"), x)[1],
                   max_batch=2, max_wait=0.0, weight=1)
    for i in range(4):
        srv.submit(np.full((2, 3), i, np.float32), tenant="bronze")
    srv.pump(flush=True)
    # gold idle: bronze gets the whole device, no slots wasted on gold
    assert order == ["bronze"] * 4


def test_admission_control_is_per_tenant():
    srv = AccelServer(max_batch=4, max_wait=60.0)
    srv.add_tenant("small", Recorder(), max_batch=4, max_wait=60.0,
                   queue_depth=2)
    srv.add_tenant("big", Recorder(), max_batch=4, max_wait=60.0,
                   queue_depth=64)
    srv.submit(*vals(1), tenant="small")
    srv.submit(*vals(1), tenant="small")
    with pytest.raises(QueueFull):
        srv.submit(*vals(1), tenant="small")
    # the other tenant's queue is unaffected by small's backpressure
    for _ in range(10):
        srv.submit(*vals(1), tenant="big")


def test_duplicate_tenant_rejected():
    srv = AccelServer(Recorder())
    with pytest.raises(ValueError, match="already registered"):
        srv.add_tenant("default", Recorder())


# ---------------------------------------------------------------------------
# fault handling: failing batches and pump death
# ---------------------------------------------------------------------------


def test_failing_executable_resolves_tickets_with_errors_async():
    srv = AccelServer(Recorder(fail=True), max_batch=4, max_wait=0.001)
    with srv:
        tks = [srv.submit(v) for v in vals(8)]
        for t in tks:
            with pytest.raises(RuntimeError, match="batch execution failed"):
                t.result(timeout=10)
    # per-batch containment: the failures were recorded, the pump survived
    assert len(srv.pump_errors) >= 1
    assert srv._fatal is None


def test_failing_tenant_does_not_poison_healthy_tenant():
    srv = AccelServer(max_batch=4, max_wait=0.001)
    srv.add_tenant("bad", Recorder(fail=True), max_batch=4, max_wait=0.001)
    srv.add_tenant("good", Recorder(tag=7.0), max_batch=4, max_wait=0.001)
    with srv:
        bad = [srv.submit(v, tenant="bad") for v in vals(4)]
        good = [srv.submit(v, tenant="good") for v in vals(4)]
        for t in bad:
            with pytest.raises(RuntimeError):
                t.result(timeout=10)
        for i, t in enumerate(good):
            assert float(t.result(timeout=10)[0, 0]) == 7.0 + i


def test_pump_death_resolves_all_outstanding_and_queued_tickets(monkeypatch):
    srv = AccelServer(Recorder(), max_batch=4, max_wait=60.0)

    def boom(flush):
        raise MemoryError("injected pump catastrophe")

    monkeypatch.setattr(srv, "_pump_async", boom)
    tks = [srv.submit(v) for v in vals(6)]
    srv.start()
    # every ticket must resolve with the error — no caller blocks forever
    for t in tks:
        assert t._event.wait(timeout=10)
        with pytest.raises(RuntimeError, match="batch execution failed"):
            t.result(timeout=10)
    with pytest.raises(RuntimeError, match="pump died"):
        srv.submit(*vals(1))
    with pytest.raises(RuntimeError, match="pump died"):
        srv.start()


def test_sync_failed_batch_still_raises_and_resolves():
    srv = AccelServer(Recorder(fail=True), max_batch=4, max_wait=0.0)
    tk = srv.submit(*vals(1))
    with pytest.raises(RuntimeError, match="injected executable failure"):
        srv.pump(flush=True)
    with pytest.raises(RuntimeError, match="batch execution failed"):
        srv.result(tk)


class FailNthCall:
    """Executable that fails on exactly the given 0-based call indices."""

    def __init__(self, fail_calls, tag=0.0):
        self.fail_calls = set(fail_calls)
        self.n = 0
        self._lock = threading.Lock()

    def __call__(self, x):
        with self._lock:
            i = self.n
            self.n += 1
        if i in self.fail_calls:
            raise RuntimeError(f"injected failure on call {i}")
        return np.asarray(x) * 2.0


def test_split_chunk_failure_resolves_parent_and_releases_siblings_async():
    # an oversize submit splits into 3 chunks; the MIDDLE chunk's batch
    # fails mid-flight.  The ONE parent ticket must resolve with the
    # failure and every sibling chunk must be released — no resident
    # outputs, no dangling split state, no hung waiter.
    srv = AccelServer(FailNthCall([1]), max_batch=4, max_wait=0.001)
    with srv:
        big = np.arange(11 * 3, dtype=np.float32).reshape(11, 3)
        tk = srv.submit(big)
        with pytest.raises(RuntimeError, match="injected failure"):
            tk.result(timeout=10)
        # the failure was contained to the batch: pump alive, server usable
        assert srv.alive and srv._fatal is None
        out = srv.submit(*vals(1)).result(timeout=10)
        assert float(out[0, 0]) == 0.0
    assert not srv._results and not srv._split and not srv._dropped
    assert not srv._default.parent_left and not srv._default.child_parent


def test_split_chunk_failure_releases_siblings_sync():
    srv = AccelServer(FailNthCall([0]), max_batch=4, max_wait=0.0)
    big = np.arange(10 * 3, dtype=np.float32).reshape(10, 3)
    tk = srv.submit(big)
    with pytest.raises(RuntimeError, match="injected failure"):
        srv.pump(flush=True)
    with pytest.raises(RuntimeError, match="injected failure"):
        srv.result(tk)
    # the sibling chunks were still queued when the claim raised; once the
    # pump flushes them their (dropped) outputs are discarded at demux and
    # every piece of split bookkeeping unwinds
    srv.pump(flush=True)
    assert not srv._results and not srv._split and not srv._dropped
    assert not srv._default.parent_left and not srv._default.child_parent


def test_split_failure_does_not_poison_other_requests():
    # a failing split must not take down traffic in OTHER batches: only the
    # batch containing the failing call is lost
    exe = FailNthCall([0])
    srv = AccelServer(exe, max_batch=4, max_wait=0.0)
    big = np.arange(9 * 3, dtype=np.float32).reshape(9, 3)
    a = np.full((2, 3), 500.0, np.float32)
    tbig = srv.submit(big)       # chunks dispatch first: call 0 fails
    ta = srv.submit(a)
    with pytest.raises(RuntimeError, match="injected failure"):
        srv.pump(flush=True)
    srv.pump(flush=True)         # remaining batches (incl. a's) execute
    np.testing.assert_allclose(srv.result(ta), a * 2.0)
    with pytest.raises(RuntimeError, match="injected failure"):
        srv.result(tbig)
    assert not srv._results and not srv._split and not srv._dropped


# ---------------------------------------------------------------------------
# closed loop 1: measured per-bucket latency drives bucket selection
# ---------------------------------------------------------------------------


def test_bucket_policy_prefers_measured_faster_bucket():
    lat = LatencyEWMA()
    pol = BucketPolicy(max_batch=8, latency=lat)
    assert pol.bucket_for(3) == 4            # cold start: static ladder
    lat.observe(4, 0.010)
    lat.observe(8, 0.002)                    # bigger bucket measured faster
    assert pol.bucket_for(3) == 8            # measurements overrule padding
    lat.observe(8, 0.050)                    # bucket 8 regresses (EWMA rises)
    assert pol.bucket_for(3) == 4


def test_bucket_policy_explores_unmeasured_fallback_first():
    lat = LatencyEWMA()
    pol = BucketPolicy(max_batch=8, latency=lat)
    lat.observe(8, 0.001)
    # the heuristic picks 2 for size 2; 2 is unmeasured, so the policy must
    # route through it (exploration) rather than jumping to measured 8
    assert pol.bucket_for(2) == 2


def test_server_feeds_bucket_latency_from_reports():
    clock = FakeClock()

    def exe(x):
        clock.advance(0.25)
        return x

    srv = AccelServer(exe, max_batch=4, max_wait=0.0, clock=clock)
    srv.submit(np.ones((4, 3), np.float32))
    srv.pump(flush=True)
    assert srv.reports[-1].exec_s == pytest.approx(0.25)
    est = srv.stats()["bucket_latency_s"]
    assert est[4] == pytest.approx(0.25)
    # the scheduler's policy reads the same EWMA instance the server feeds
    assert srv.scheduler.policy.latency.estimate(4) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# closed loop 2: p95 SLO walks the precision ladder (deterministic clock)
# ---------------------------------------------------------------------------

POINTS = [WorkingPoint("w8", 8), WorkingPoint("w4", 4), WorkingPoint("w2", 2)]


class BitsExe:
    """Fake point executable: advances the fake clock by a per-bits cost
    that a shared `pressure` switch scales (injected latency pressure)."""

    def __init__(self, bits, clock, pressure):
        self.bits = bits
        self.clock = clock
        self.pressure = pressure

    def __call__(self, x):
        base = {8: 2.0, 4: 1.5, 2: 0.8}[self.bits]
        self.clock.advance(base if self.pressure["on"] else 0.1)
        return x


def test_slo_loop_downshifts_bits_then_recovers():
    clock = FakeClock()
    pressure = {"on": True}
    exes = {p.name: BitsExe(p.weight_bits, clock, pressure) for p in POINTS}
    slo = ServiceObjective(p95_latency_s=1.0, window=4, min_samples=4,
                           hold=4, recover_margin=0.5)
    srv = AccelServer(exes["w8"], max_batch=4, max_wait=0.0, clock=clock,
                      policy=RuntimePolicy(POINTS), point_executables=exes,
                      slo=slo)

    def serve_one():
        tk = srv.submit(np.ones((4, 3), np.float32))
        srv.pump(flush=True)
        srv.result(tk)

    # under pressure: w8 costs 2.0s (p95 > 1.0 SLO) -> downshift to w4
    # (1.5s, still violating) -> downshift to w2 (0.8s, inside SLO)
    for _ in range(12):
        serve_one()
    ctl = srv._default.controller
    assert ctl.shifts == [("w8", "w4"), ("w4", "w2")]
    # pressure off: once the 0.8s samples age out of the window, p95 drops
    # under recover_margin * SLO and the controller climbs w2 -> w4 -> w8
    pressure["on"] = False
    for _ in range(12):
        serve_one()
    assert ctl.shifts == [("w8", "w4"), ("w4", "w2"),
                          ("w2", "w4"), ("w4", "w8")]
    # BatchReport.bits telemetry confirms the full trajectory
    bits = [r.bits for r in srv.reports]
    assert bits == [8] * 4 + [4] * 4 + [2] * 8 + [4] * 4 + [8] * 4
    tel = srv.stats()["slo"]
    assert tel["point"] == "w8" and len(tel["shifts"]) == 4


def test_slo_controller_holds_between_shifts():
    ctl = SLOController(POINTS, ServiceObjective(
        p95_latency_s=1.0, window=8, min_samples=2, hold=4,
        recover_margin=0.5))
    for _ in range(3):
        ctl.observe(5.0)
    assert ctl.select().name == "w8"        # hold not yet satisfied
    ctl.observe(5.0)
    assert ctl.select().name == "w4"        # 4th observation may shift
    ctl.observe(5.0)
    ctl.observe(5.0)
    assert ctl.select().name == "w4"        # window cleared + hold again


def test_slo_requires_policy():
    with pytest.raises(ValueError, match="needs a RuntimePolicy"):
        AccelServer(Recorder(), slo=ServiceObjective(p95_latency_s=1.0))


# ---------------------------------------------------------------------------
# unified selector surface: selector= equals the legacy policy=/slo= pair,
# and a computed ParetoFront drives the same closed loop
# ---------------------------------------------------------------------------


def _drive_slo_trajectory(srv, pressure):
    """The canonical downshift-then-recover trajectory against a server."""

    def serve_one():
        tk = srv.submit(np.ones((4, 3), np.float32))
        srv.pump(flush=True)
        srv.result(tk)

    for _ in range(12):
        serve_one()
    pressure["on"] = False
    for _ in range(12):
        serve_one()
    return [r.bits for r in srv.reports]


def test_selector_kwarg_matches_legacy_policy_slo_pair():
    """selector=SLOController(...) reproduces the policy=/slo= trajectory
    bit-for-bit: the legacy pair is sugar over the one selector slot."""
    slo_kw = dict(p95_latency_s=1.0, window=4, min_samples=4, hold=4,
                  recover_margin=0.5)
    traces = []
    for style in ("legacy", "selector"):
        clock = FakeClock()
        pressure = {"on": True}
        exes = {p.name: BitsExe(p.weight_bits, clock, pressure)
                for p in POINTS}
        kw = (dict(policy=RuntimePolicy(POINTS),
                   slo=ServiceObjective(**slo_kw)) if style == "legacy"
              else dict(selector=SLOController(
                  POINTS, ServiceObjective(**slo_kw))))
        srv = AccelServer(exes["w8"], max_batch=4, max_wait=0.0, clock=clock,
                          point_executables=exes, **kw)
        traces.append(_drive_slo_trajectory(srv, pressure))
        assert srv._default.controller is srv.selector   # legacy view intact
    assert traces[0] == traces[1]
    assert traces[0] == [8] * 4 + [4] * 4 + [2] * 8 + [4] * 4 + [8] * 4


def test_selector_excludes_legacy_pair():
    sel = SLOController(POINTS, ServiceObjective(p95_latency_s=1.0))
    with pytest.raises(ValueError, match="not both"):
        AccelServer(Recorder(), selector=sel, policy=RuntimePolicy(POINTS))
    with pytest.raises(ValueError, match="not both"):
        AccelServer(Recorder(), selector=sel,
                    slo=ServiceObjective(p95_latency_s=1.0))


def test_slo_loop_walks_a_computed_pareto_front():
    """The DSE acceptance loop: an explorer-shaped ParetoFront (not the
    hardcoded ladder) feeds serve-time selection, and the SLO controller
    demonstrably shifts across the front's own points."""
    from repro.dse import ParetoFront, ParetoPoint

    def ppt(name, bits, wb, lat, agree):
        return ParetoPoint(WorkingPoint(name, bits, act_bits=8),
                           weight_bytes=wb, fifo_bytes=64, scratch_bytes=0,
                           predicted_latency_s=lat, agreement=agree)

    front = ParetoFront("toy", [ppt("w8", 8, 300, 3e-6, 1.0),
                                ppt("w4", 4, 150, 2e-6, 0.9),
                                ppt("w2", 2, 80, 1e-6, 0.6)])
    # the front round-trips through its wire format before serving, exactly
    # as a deployment loading a committed front artifact would
    front = ParetoFront.from_json(front.to_json())
    clock = FakeClock()
    pressure = {"on": True}
    exes = {p.name: BitsExe(p.weight_bits, clock, pressure)
            for p in front.working_points()}
    sel = front.selector(ServiceObjective(p95_latency_s=1.0, window=4,
                                          min_samples=4, hold=4,
                                          recover_margin=0.5))
    srv = AccelServer(exes["w8"], max_batch=4, max_wait=0.0, clock=clock,
                      point_executables=exes, selector=sel)
    bits = _drive_slo_trajectory(srv, pressure)
    assert bits == [8] * 4 + [4] * 4 + [2] * 8 + [4] * 4 + [8] * 4
    assert sel.shifts == [("w8", "w4"), ("w4", "w2"),
                          ("w2", "w4"), ("w4", "w8")]
    tel = srv.stats()["slo"]
    assert tel["point"] == "w8" and len(tel["shifts"]) == 4


# ---------------------------------------------------------------------------
# telemetry shapes
# ---------------------------------------------------------------------------


def test_multi_tenant_stats_aggregate_and_breakdown():
    srv = AccelServer(max_batch=4, max_wait=0.0)
    srv.add_tenant("a", Recorder(), max_batch=4, max_wait=0.0, weight=2)
    srv.add_tenant("b", Recorder(), max_batch=4, max_wait=0.0)
    for _ in range(3):
        srv.submit(*vals(1), tenant="a")
    srv.submit(*vals(1), tenant="b")
    srv.pump(flush=True)
    s = srv.stats()
    assert set(s["tenants"]) == {"a", "b"}
    assert s["submitted"] == 4
    assert s["tenants"]["a"]["weight"] == 2
    assert s["executed_batches"] == (s["tenants"]["a"]["executed_batches"]
                                     + s["tenants"]["b"]["executed_batches"])
    sa = srv.stats(tenant="a")
    assert sa["submitted"] == 3


def test_report_carries_tenant_name():
    srv = AccelServer(max_batch=4, max_wait=0.0)
    srv.add_tenant("x", Recorder(), max_batch=4, max_wait=0.0)
    srv.submit(*vals(1), tenant="x")
    srv.pump(flush=True)
    rep = srv.tenants["x"].reports[-1]
    assert rep.tenant == "x" and rep.exec_s is not None
