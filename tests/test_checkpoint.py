
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree():
    return {"params": {"a/w": jnp.arange(6.0).reshape(2, 3),
                       "b/w": jnp.ones((4,), jnp.bfloat16)},
            "opt": {"mu": {"a/w": jnp.zeros((2, 3))}},
            "count": {"count": jnp.int32(5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), 3)
    t2, step, extra = ckpt.restore(str(tmp_path))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(t2["params"]["a/w"]),
                                  np.asarray(t["params"]["a/w"]))
    assert t2["params"]["b/w"].dtype == np.dtype("bfloat16") or \
        str(t2["params"]["b/w"].dtype) == "bfloat16"
    assert int(np.asarray(t2["count"]["count"])) == 5


def test_async_checkpointer_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        saver.save(_tree(), s)
    saver.wait()
    saver._gc()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_restore_specific_step(tmp_path):
    for s in (1, 2):
        t = _tree()
        t["count"]["count"] = jnp.int32(s)
        ckpt.save(t, str(tmp_path), s)
    t1, s1, _ = ckpt.restore(str(tmp_path), step=1)
    assert int(np.asarray(t1["count"]["count"])) == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written unsharded restores onto explicit device placement
    (the single-device degenerate case of re-mesh restore)."""
    ckpt.save(_tree(), str(tmp_path), 1)
    shardings = {"params": {"a/w": jax.devices()[0], "b/w": None},
                 "opt": {"mu": {"a/w": None}}, "count": {"count": None}}
    t, _, _ = ckpt.restore(str(tmp_path), shardings=shardings)
    assert isinstance(t["params"]["a/w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(t["params"]["a/w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"))
