"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmatmul.ops import qmatmul, qmatmul_int8_act
from repro.kernels.qmatmul.ref import qmatmul_ref, qmatmul_int8_act_ref
from repro.kernels.conv2d_stream.ops import conv2d_stream
from repro.kernels.conv2d_stream.ref import conv2d_ref
from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
from repro.models.ssm import ssd_chunked


def _quantize(w):
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / 127.0
    codes = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return codes, s


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 384),
                                   (128, 1024, 256), (384, 256, 128)])
@pytest.mark.parametrize("bits", [8, 4, 2])
def test_qmatmul_shapes_bits(M, K, N, bits):
    kx = jax.random.PRNGKey(M * K + N + bits)
    x = jax.random.normal(kx, (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    codes, s = _quantize(w)
    y_k = qmatmul(x, codes, s, bits=bits).astype(jnp.float32)
    y_r = qmatmul_ref(x, codes, s, bits).astype(jnp.float32)
    # bf16 output: <= 1 ulp of the largest magnitude
    tol = float(jnp.max(jnp.abs(y_r))) * 2 ** -7
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_qmatmul_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    codes, s = _quantize(w)
    y = qmatmul(x, codes, s, bits=8)
    assert y.dtype == dtype and y.shape == (128, 128)


def test_qmatmul_batched_and_ragged():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 100), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (100, 50), jnp.float32)
    codes, s = _quantize(w)
    y = qmatmul(x, codes, s, bits=8)
    assert y.shape == (2, 3, 50)
    y_r = qmatmul_ref(x.reshape(6, 100), codes, s, 8).reshape(2, 3, 50)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32), atol=1.0)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_qmatmul_int8_act_bitexact(bits):
    """Integer path accumulates in int32 — must be bit-exact vs the oracle."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 256), jnp.float32)
    xs = jnp.max(jnp.abs(x), axis=1) / 127.0
    xc = jnp.clip(jnp.round(x / xs[:, None]), -127, 127).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 128), jnp.float32)
    codes, s = _quantize(w)
    y_k = qmatmul_int8_act(xc, xs, codes, s, bits=bits)
    y_r = qmatmul_int8_act_ref(xc, xs, codes, s, bits)
    np.testing.assert_array_equal(np.asarray(y_k, np.float32),
                                  np.asarray(y_r, np.float32))


@pytest.mark.parametrize("B,H,W,Cin,Cout,k", [
    (2, 28, 28, 1, 16, 3), (1, 14, 14, 16, 32, 3), (3, 8, 8, 4, 8, 5),
    (2, 7, 7, 32, 16, 3), (1, 28, 28, 3, 8, 1)])
def test_conv2d_stream_shapes(B, H, W, Cin, Cout, k):
    x = jax.random.normal(jax.random.PRNGKey(B + H), (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, k, Cin, Cout)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (Cout,)) * 0.1
    np.testing.assert_allclose(np.asarray(conv2d_stream(x, w, b)),
                               np.asarray(conv2d_ref(x, w, b)),
                               atol=1e-4, rtol=1e-4)


def test_conv2d_stream_matches_model_conv():
    """The stream kernel must match the CNN model's conv (same layer semantics)."""
    from repro.models.cnn import conv2d
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 28, 28, 1))
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 1, 16)) * 0.3
    b = jnp.zeros(16)
    np.testing.assert_allclose(np.asarray(conv2d_stream(x, w, b)),
                               np.asarray(conv2d(x, w, b)), atol=1e-4)


@pytest.mark.parametrize("B,S,H,P,G,N,Q", [
    (2, 128, 4, 16, 2, 8, 32), (1, 64, 2, 8, 1, 16, 16),
    (2, 96, 6, 32, 3, 4, 32), (1, 256, 8, 64, 1, 128, 64)])
def test_ssd_kernel_vs_oracle(B, S, H, P, G, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    D = jax.random.normal(ks[5], (H,))
    y_r, s_r = ssd_chunked(x, dt, A, Bm, C, D, Q)
    y_k, s_k = ssd_chunked_kernel(x, dt, A, Bm, C, D, Q)
    scale = float(jnp.max(jnp.abs(y_r))) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale, np.asarray(y_r) / scale,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4)


def test_ssd_decode_matches_chunked_prefix():
    from repro.models.ssm import ssd_decode_step
    B, S, H, P, G, N, Q = 2, 64, 4, 16, 1, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    D = jax.random.normal(ks[5], (H,))
    y_ref, _ = ssd_chunked(x, dt, A, Bm, C, D, Q)
    st = jnp.zeros((B, H, P, N))
    for t in range(S):
        y_t, st = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], C[:, t], D, st)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_ref[:, -1]),
                               atol=1e-4)
