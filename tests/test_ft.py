"""Fault tolerance: restart-equals-uninterrupted, straggler watchdog, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import DataConfig
from repro.models.params import init_params
from repro.optim.adamw import OptConfig
from repro.runtime import ft
from repro.runtime.train import init_train_state, make_train_step

ARCH = "qwen1.5-0.5b"


def _setup(steps=12):
    cfg = get_config(ARCH).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1,
                                                  total_steps=steps)))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=7)
    return cfg, state, step, data


def test_restart_bit_identical_to_uninterrupted(tmp_path):
    steps = 12
    cfg, state, step, data = _setup(steps)
    # uninterrupted run
    ft.run_training(step, state, data, steps, str(tmp_path / "a"),
                    ckpt_every=4)
    # interrupted run: inject failures at steps 5 and 9
    r2 = ft.run_training(step, state, data, steps, str(tmp_path / "b"),
                         ckpt_every=4,
                         injector=ft.FailureInjector(fail_at=[5, 9]))
    assert r2.restarts == 2
    from repro.ckpt import checkpoint as ckpt
    t1, s1, _ = ckpt.restore(str(tmp_path / "a"))
    t2, s2, _ = ckpt.restore(str(tmp_path / "b"))
    assert s1 == s2 == steps
    for k in t1["params"]:
        np.testing.assert_array_equal(np.asarray(t1["params"][k]),
                                      np.asarray(t2["params"][k]), err_msg=k)


def test_loss_decreases_over_training(tmp_path):
    steps = 15
    cfg, state, step, data = _setup(steps)
    r = ft.run_training(step, state, data, steps, str(tmp_path / "c"),
                        ckpt_every=50)
    losses = [m["loss"] for m in r.metrics_log]
    assert losses[-1] < losses[0], losses


def test_straggler_watchdog_flags_slow_steps():
    wd = ft.StragglerWatchdog(factor=3.0, window=10)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)          # 5x median -> flagged
    assert not wd.observe(11, 0.12)
    assert wd.flagged == [10]


def test_straggler_watchdog_history_is_bounded():
    # regression: times grew unbounded over a long run even though only the
    # last `window` samples ever feed the median
    wd = ft.StragglerWatchdog(factor=3.0, window=8)
    for i in range(10_000):
        wd.observe(i, 0.1)
    assert len(wd.times) == 8
    # the bounded buffer must behave identically to the old last-window slice:
    # after 8 fast steps the median is fast, so a 5x step still flags
    assert wd.observe(10_000, 0.5)
    assert wd.flagged == [10_000]


def test_injector_rate_mode_is_seeded_and_counted():
    def draws(seed):
        inj = ft.FailureInjector(rate=0.3, seed=seed)
        out = []
        for step in range(50):
            try:
                inj.maybe_fail(step)
                out.append(False)
            except RuntimeError:
                out.append(True)
        return out, inj.injected_failures

    a, na = draws(seed=7)
    b, nb = draws(seed=7)
    c, nc = draws(seed=8)
    assert a == b and na == nb          # same seed -> same fault sequence
    assert a != c                        # different seed -> different faults
    assert na == sum(a) > 0


def test_injector_delay_modes():
    slept = []
    inj = ft.FailureInjector(delay_at=[3], delay_s=0.25, sleep=slept.append)
    assert not inj.maybe_delay(2)
    assert inj.maybe_delay(3)
    assert not inj.maybe_delay(3)        # fire-once, like fail_at
    assert slept == [0.25]
    assert inj.injected_delays == 1
    # seeded probabilistic delays, independent of the failure stream
    slept2 = []
    inj2 = ft.FailureInjector(rate=0.0, delay_rate=0.5, delay_s=0.01,
                              seed=3, sleep=slept2.append)
    hits = sum(inj2.maybe_delay(s) for s in range(100))
    assert hits == len(slept2) == inj2.injected_delays
    assert 20 < hits < 80                # seeded draw near the configured rate


def test_injector_fail_at_api_unchanged():
    inj = ft.FailureInjector(fail_at=[2])
    inj.maybe_fail(1)
    try:
        inj.maybe_fail(2)
        assert False, "should have raised"
    except RuntimeError:
        pass
    inj.maybe_fail(2)                    # fire-once: second pass is clean
    assert inj.fired == {2}


def test_injector_validates_config():
    import pytest
    with pytest.raises(ValueError):
        ft.FailureInjector(rate=1.5)
    with pytest.raises(ValueError):
        ft.FailureInjector(delay_rate=-0.1)
    with pytest.raises(ValueError):
        ft.FailureInjector(delay_s=-1.0)


def test_failure_mid_save_keeps_last_good_checkpoint(tmp_path):
    """Atomic rename: a .tmp dir never shadows the last good step."""
    from repro.ckpt import checkpoint as ckpt
    tree = {"params": {"w": jnp.ones(4)}}
    ckpt.save(tree, str(tmp_path), 10)
    # simulate a crashed save: leave a stale tmp dir
    os.makedirs(str(tmp_path / "step_00000020.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 10
    t, s, _ = ckpt.restore(str(tmp_path))
    assert s == 10
