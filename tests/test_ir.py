import numpy as np
import pytest

from repro.core.ir import Graph, Node, TensorInfo
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.configs.mnist_cnn import CONFIG as CNN


def _toy_graph():
    return Graph(
        name="toy",
        nodes=[
            Node("Gemm", "fc1", ["input", "w1", "b1"], ["h"]),
            Node("Relu", "r1", ["h"], ["hr"]),
            Node("Gemm", "fc2", ["hr", "w2", "b2"], ["logits"]),
        ],
        inputs=[TensorInfo("input", (1, 4))],
        outputs=["logits"],
        initializers={"w1": np.zeros((4, 8), np.float32),
                      "b1": np.zeros(8, np.float32),
                      "w2": np.zeros((8, 2), np.float32),
                      "b2": np.zeros(2, np.float32)},
    )


def test_validate_and_topo():
    g = _toy_graph()
    g.validate()
    order = [n.name for n in g.topo_order()]
    assert order.index("fc1") < order.index("r1") < order.index("fc2")


def test_topo_handles_shuffled_nodes():
    g = _toy_graph()
    g.nodes = g.nodes[::-1]
    order = [n.name for n in g.topo_order()]
    assert order.index("fc1") < order.index("fc2")


def test_undefined_input_rejected():
    g = _toy_graph()
    g.nodes[0].inputs[0] = "missing"
    with pytest.raises(ValueError):
        g.validate()


def test_cycle_rejected():
    g = _toy_graph()
    # make fc1 depend on the output of fc2
    g.nodes[0].inputs[0] = "logits"
    with pytest.raises(ValueError):
        g.topo_order()


def test_unsupported_op_rejected():
    with pytest.raises(ValueError):
        Node("FancyOp", "x", [], [])


def test_json_roundtrip(tmp_path):
    g = _toy_graph()
    path = str(tmp_path / "g.json")
    g.save(path)
    g2 = Graph.load(path)
    assert [n.name for n in g2.nodes] == [n.name for n in g.nodes]
    assert g2.initializers["w1"].shape == (4, 8)
    np.testing.assert_array_equal(g2.initializers["w1"], g.initializers["w1"])


def test_producer_consumer_index():
    g = _toy_graph()
    assert g.producer_of("h").name == "fc1"
    assert g.producer_of("input") is None
    assert [n.name for n in g.consumers_of("hr")] == ["fc2"]
    # cached index tracks node-list edits
    g.nodes = g.nodes[:-1]
    assert g.producer_of("logits") is None


def test_topo_order_handles_long_chain():
    """Kahn ordering stays correct (and fast) on a deep chain."""
    nodes = []
    prev = "input"
    for i in range(500):
        nodes.append(Node("Relu", f"r{i}", [prev], [f"t{i}"]))
        prev = f"t{i}"
    g = Graph("deep", nodes[::-1], [TensorInfo("input", (1, 4))], [prev])
    order = [n.name for n in g.topo_order()]
    assert order == [f"r{i}" for i in range(500)]


def test_roundtrip_preserves_pass_annotations(tmp_path):
    from repro.core.passes import infer_shapes, make_assign_precision
    from repro.quant.qtypes import DatatypeConfig
    g = make_assign_precision(DatatypeConfig(16, 8))(infer_shapes(_toy_graph()))
    path = str(tmp_path / "g.json")
    g.save(path)
    g2 = Graph.load(path)
    assert g2.nodes[0].dtconfig == DatatypeConfig(16, 8)
    assert tuple(g2.value_info["logits"].shape) == (1, 2)


def test_cnn_to_ir_matches_paper_topology():
    """Paper: 2 conv blocks (conv, maxpool, batchnorm, relu) + 1 FC."""
    from repro.models import cnn
    import jax
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()})
    ops = [n.op for n in g.topo_order()]
    assert ops == ["Conv", "MaxPool", "BatchNormalization", "Relu"] * 2 + \
        ["Flatten", "Gemm"]


def test_mlp_to_ir():
    sizes = [16, 8, 4]
    params = {f"fc{i}/w": np.zeros((sizes[i], sizes[i + 1]), np.float32)
              for i in range(2)}
    params.update({f"fc{i}/b": np.zeros(sizes[i + 1], np.float32)
                   for i in range(2)})
    g = mlp_to_ir(sizes, params)
    assert [n.op for n in g.topo_order()] == ["Gemm", "Relu", "Gemm"]
